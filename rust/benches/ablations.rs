//! Ablations: concurrency-control strategy and design-choice comparisons.
//!
//! 1. **Strategy** (§ intro / §5): OCC vs mutual exclusion vs
//!    coordination-free vs divide-and-conquer on the same workload —
//!    runtime, cluster counts, duplicates, objective J(C).
//! 2. **Bootstrap** (§4.2): bootstrap on/off — epoch-1 master traffic.
//! 3. **Epoch size**: Pb sweep — rejection/communication trade-off
//!    (larger epochs = more optimism = more rejections, fewer barriers).

use occml::algorithms::objective::dp_objective;
use occml::baselines::{coordfree, dnc, mutex};
use occml::benchlib::{fmt_duration, time_fn, BenchArgs, Table};
use occml::config::{Algo, RunConfig};
use occml::coordinator::driver;
use occml::data::generators::{dp_clusters, GenConfig};
use occml::runtime::native::NativeBackend;
use std::sync::Arc;

fn main() {
    let args = BenchArgs::from_env();
    let n: usize = args.get_or("n", 1 << 15);
    let procs: usize = args.get_or("procs", 8);
    let iters: usize = args.get_or("iters", 3);
    let lambda = 2.0;

    let data = Arc::new(dp_clusters(&GenConfig { n, dim: 16, theta: 1.0, seed: 8 }));
    let backend = Arc::new(NativeBackend::new());

    // -----------------------------------------------------------------
    println!("\n=== strategy ablation: first pass, N={n}, P={procs}, λ={lambda} ===");
    let mut table = Table::new(&["strategy", "time", "centers", "duplicates", "J(C)", "serializable"]);

    let cfg = RunConfig {
        algo: Algo::DpMeans,
        lambda,
        procs,
        block: 1024,
        iterations: 1,
        bootstrap_div: 16,
        n,
        seed: 8,
        ..RunConfig::default()
    };
    let mut occ_out = None;
    let occ_t = time_fn(1, iters, || {
        occ_out = Some(driver::run_with(&cfg, data.clone(), backend.clone()).unwrap());
    });
    let occ = occ_out.unwrap();
    let occml::coordinator::Model::Dp(om) = &occ.model else { panic!() };
    table.row(vec![
        "OCC (ours)".into(),
        fmt_duration(occ_t.mean),
        om.centers.rows.to_string(),
        "0".into(),
        format!("{:.0}", occ.summary.objective.unwrap()),
        "yes (deterministic)".into(),
    ]);

    let mut mx_res = None;
    let mx_t = time_fn(1, iters, || {
        mx_res = Some(mutex::dp_first_pass_mutex(&data, lambda, procs));
    });
    let mx = mx_res.unwrap();
    table.row(vec![
        "mutual exclusion".into(),
        fmt_duration(mx_t.mean),
        mx.centers.rows.to_string(),
        "0".into(),
        format!("{:.0}", dp_objective(&data, &mx.centers, lambda)),
        "yes (nondeterministic)".into(),
    ]);

    let mut cf_res = None;
    let cf_t = time_fn(1, iters, || {
        cf_res = Some(coordfree::dp_first_pass_coordfree(&data, lambda, procs));
    });
    let cf = cf_res.unwrap();
    table.row(vec![
        "coordination-free".into(),
        fmt_duration(cf_t.mean),
        cf.centers.rows.to_string(),
        cf.duplicates.to_string(),
        format!("{:.0}", dp_objective(&data, &cf.centers, lambda)),
        "no".into(),
    ]);

    let mut dc_res = None;
    let dc_t = time_fn(1, iters, || {
        dc_res = Some(dnc::dp_divide_and_conquer(&data, lambda, procs));
    });
    let dc = dc_res.unwrap();
    table.row(vec![
        format!("divide-and-conquer ({} shipped)", dc.intermediate_centers),
        fmt_duration(dc_t.mean),
        dc.centers.rows.to_string(),
        "0".into(),
        format!("{:.0}", dp_objective(&data, &dc.centers, lambda)),
        "no (2-level factor)".into(),
    ]);
    table.print();
    let _ = table.write_csv(std::path::Path::new("target/bench-results/ablation_strategy.csv"));

    // -----------------------------------------------------------------
    println!("\n=== bootstrap ablation (§4.2): epoch-1 master traffic ===");
    let mut table = Table::new(&["bootstrap", "epoch0 proposed", "total rejected", "centers"]);
    for &div in &[0usize, 16] {
        let cfg = RunConfig { bootstrap_div: div, ..cfg.clone() };
        let out = driver::run_with(&cfg, data.clone(), backend.clone()).unwrap();
        let first = out
            .summary
            .epochs
            .iter()
            .find(|e| e.epoch != usize::MAX)
            .map(|e| e.proposed)
            .unwrap_or(0);
        table.row(vec![
            if div == 0 { "off".into() } else { format!("Pb/{div}") },
            first.to_string(),
            out.summary.total_rejected().to_string(),
            out.model.k().to_string(),
        ]);
    }
    table.print();

    // -----------------------------------------------------------------
    println!("\n=== epoch-size ablation: rejections vs barriers (first pass) ===");
    let mut table = Table::new(&["Pb", "epochs", "proposed", "rejected", "time"]);
    for &pb in &[512usize, 2048, 8192, 32768] {
        let cfg = RunConfig {
            block: pb / procs,
            bootstrap_div: 0,
            ..cfg.clone()
        };
        let mut out = None;
        let t = time_fn(0, iters.min(3), || {
            out = Some(driver::run_with(&cfg, data.clone(), backend.clone()).unwrap());
        });
        let out = out.unwrap();
        let epochs = out.summary.epochs.iter().filter(|e| e.epoch != usize::MAX).count();
        table.row(vec![
            pb.to_string(),
            epochs.to_string(),
            out.summary.total_proposed().to_string(),
            out.summary.total_rejected().to_string(),
            fmt_duration(t.mean),
        ]);
    }
    table.print();
    let _ = table.write_csv(std::path::Path::new("target/bench-results/ablation_epoch.csv"));

    // -----------------------------------------------------------------
    println!("\n=== §6 soft-knob sweep: serializability ↔ coordination-free ===");
    // Replay the epoch structure with the soft validator at several knob
    // settings; slack=0 is exact OCC, slack=1/accept=1 is coordination-free.
    use occml::coordinator::soft::{dp_validate_soft, SoftKnob};
    use occml::coordinator::validator::DpProposal;
    use occml::rng::Pcg64;
    let mut table = Table::new(&["slack", "p_accept", "centers", "rejected", "J(C)"]);
    let pb = 1024 * procs;
    for &(slack, pa) in &[(0.0, 0.0), (0.25, 0.5), (0.5, 0.5), (1.0, 0.5), (1.0, 1.0)] {
        let knob = SoftKnob { slack, slack_accept: pa };
        let mut rng = Pcg64::new(99);
        let lambda2 = (lambda * lambda) as f32;
        let mut centers = occml::linalg::Matrix::zeros(0, 16);
        let mut rejected = 0usize;
        let mut t = 0;
        while t * pb < n {
            let lo = t * pb;
            let hi = ((t + 1) * pb).min(n);
            let base = centers.rows;
            let mut props = Vec::new();
            for i in lo..hi {
                let (_, d2) = occml::linalg::nearest(data.point(i), &centers);
                if d2 > lambda2 {
                    props.push(DpProposal { idx: i as u32, center: data.point(i).to_vec() });
                }
            }
            let out = dp_validate_soft(&mut centers, base, &props, lambda, knob, &mut rng);
            rejected += out.rejected;
            t += 1;
        }
        table.row(vec![
            format!("{slack:.2}"),
            format!("{pa:.2}"),
            centers.rows.to_string(),
            rejected.to_string(),
            format!("{:.0}", dp_objective(&data, &centers, lambda)),
        ]);
    }
    table.print();
    println!("(slack 0 = exact OCC; slack 1 / p 1 = coordination-free merge)");
    let _ = table.write_csv(std::path::Path::new("target/bench-results/ablation_soft.csv"));
}
