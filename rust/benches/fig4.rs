//! Figure 4 — normalized runtime scaling of the distributed algorithms.
//!
//! The paper ran Spark on 1/2/4/8 EC2 m2.4xlarge machines (P = 8..64
//! virtual cores) on 2²⁰–2²⁷ points. This image exposes **one CPU core**,
//! so real threads cannot speed anything up; the bench therefore uses the
//! measured-per-block BSP cost model of `occml::sim::modeled` by default
//! (every worker block is executed and timed; only the overlap is modeled —
//! see DESIGN.md §5). Pass `--mode=threads` to time the real thread pool
//! instead (meaningful on multi-core hosts).
//!
//! Shape to reproduce:
//!   4a DP-means — near-perfect scaling in all but the first iteration;
//!   4b OFL — no scaling in epoch 1 (the master validates the whole batch),
//!      improving in later epochs;
//!   4c BP-means — near-perfect scaling like DP-means.
//!
//! Flags: --n=..., --pb=..., --iters=..., --procs=1,2,4,8, --mode=modeled|threads

use occml::benchlib::{BenchArgs, Table};
use occml::config::{Algo, DataSource, RunConfig};
use occml::coordinator::driver;
use occml::runtime::native::NativeBackend;
use occml::sim::modeled::run_modeled;
use std::sync::Arc;

fn main() {
    let args = BenchArgs::from_env();
    let n: usize = args.get_or("n", 1 << 16);
    let pb: usize = args.get_or("pb", 1 << 12);
    let iters: usize = args.get_or("iters", 3);
    let mode = args.get("mode").unwrap_or("modeled").to_string();
    let procs: Vec<usize> = args
        .get("procs")
        .unwrap_or("1,2,4,8")
        .split(',')
        .map(|s| s.trim().parse().expect("bad --procs"))
        .collect();

    // Paper parameters scaled down ~64× (DESIGN.md §5): λ matches the
    // paper's per-figure choices; Pb is held constant across P.
    let experiments: &[(&str, Algo, DataSource, f64, usize)] = &[
        ("fig4a", Algo::DpMeans, DataSource::DpClusters, 4.0, iters),
        ("fig4b", Algo::Ofl, DataSource::DpClusters, 4.0, 1),
        ("fig4c", Algo::BpMeans, DataSource::BpFeatures, 2.0, iters),
    ];

    for (exp, algo, source, lambda, iterations) in experiments {
        println!("\n=== {exp}: {} — N={n}, Pb={pb}, mode={mode} ===", algo.name());
        let base = RunConfig {
            algo: *algo,
            lambda: *lambda,
            iterations: *iterations,
            bootstrap_div: if *algo == Algo::Ofl { 0 } else { 16 },
            source: source.clone(),
            n,
            seed: 4,
            ..RunConfig::default()
        };
        let data = Arc::new(driver::load_or_generate(&base).expect("generate"));
        let backend = NativeBackend::new();

        // For OFL each "row unit" is an epoch; for DP/BP an iteration.
        let probe = RunConfig { procs: procs[0], block: pb / procs[0], ..base.clone() };
        let units = if *algo == Algo::Ofl {
            run_modeled(&probe, &data, &backend).expect("probe").iterations.len()
        } else {
            *iterations
        };
        let unit_name = if *algo == Algo::Ofl { "epoch" } else { "iter" };

        let mut headers = vec!["P".to_string()];
        for u in 0..units.min(8) {
            headers.push(format!("{unit_name}{u}"));
        }
        if units > 8 {
            headers.push("...".into());
        }
        headers.push("total".into());
        headers.push("ideal".into());
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = Table::new(&hdr_refs);

        let mut baseline: Vec<f64> = Vec::new();
        let mut baseline_total = 0.0f64;
        for (pi, &p) in procs.iter().enumerate() {
            let cfg = RunConfig { procs: p, block: pb / p, ..base.clone() };
            let (times, total): (Vec<f64>, f64) = if mode == "threads" {
                let be: Arc<dyn occml::runtime::ComputeBackend> = Arc::new(backend);
                let out = driver::run_with(&cfg, data.clone(), be).expect("run");
                let v: Vec<f64> = (0..out.summary.iterations())
                    .map(|it| out.summary.iteration_time(it).as_secs_f64())
                    .collect();
                let t = out.summary.total_time.as_secs_f64();
                (v, t)
            } else {
                let m = run_modeled(&cfg, &data, &backend).expect("run");
                let v: Vec<f64> = m.iterations.iter().map(|i| i.critical_path.as_secs_f64()).collect();
                let t = m.total().as_secs_f64();
                (v, t)
            };
            if pi == 0 {
                baseline = times.clone();
                baseline_total = total;
            }
            let mut cells = vec![p.to_string()];
            for u in 0..units.min(8) {
                let norm = times.get(u).copied().unwrap_or(f64::NAN)
                    / baseline.get(u).copied().unwrap_or(f64::NAN);
                cells.push(format!("{norm:.3}"));
            }
            if units > 8 {
                cells.push("".into());
            }
            cells.push(format!("{:.3}", total / baseline_total));
            cells.push(format!("{:.3}", procs[0] as f64 / p as f64));
            table.row(cells);
        }
        println!("(normalized runtime vs P={}; `ideal` is perfect 1/P scaling)", procs[0]);
        table.print();
        let csv = format!("target/bench-results/{exp}.csv");
        if table.write_csv(std::path::Path::new(&csv)).is_ok() {
            println!("csv: {csv}");
        }
    }
}
