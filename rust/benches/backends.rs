//! Backend throughput: native Rust kernels vs AOT XLA artifacts (PJRT).
//!
//! Measures the three hot-path primitives per (block, centers) bucket.
//! This is the L1/L3 perf evidence for EXPERIMENTS.md §Perf: the native
//! backend is the CPU roofline reference; the XLA numbers include the
//! pad-copy + literal transfer overhead the bucket design trades for AOT
//! simplicity. Skips XLA when artifacts are missing.

use occml::benchlib::{fmt_duration, time_fn, BenchArgs, Table};
use occml::linalg::Matrix;
use occml::rng::Pcg64;
use occml::runtime::native::NativeBackend;
use occml::runtime::xla::XlaBackend;
use occml::runtime::{Block, ComputeBackend};
use std::path::Path;

fn random_matrix(rng: &mut Pcg64, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
}

fn main() {
    let args = BenchArgs::from_env();
    let iters: usize = args.get_or("iters", 20);
    let d = 16usize;
    let mut rng = Pcg64::new(99);

    let native = NativeBackend::new();
    let xla = XlaBackend::load(Path::new("artifacts"))
        .map_err(|e| eprintln!("xla backend unavailable: {e}"))
        .ok();
    if let Some(x) = &xla {
        x.warmup().expect("warmup");
    }

    let shapes = [(256usize, 64usize), (256, 256), (1024, 64), (1024, 256), (1024, 1024)];

    println!("\n=== nearest (dp_assign): b points × k centers, d={d} ===");
    let mut table = Table::new(&["b", "k", "native", "xla", "native Melem/s", "xla/native"]);
    for &(b, k) in &shapes {
        let pts = random_matrix(&mut rng, b, d);
        let ctr = random_matrix(&mut rng, k, d);
        let block = Block::of(&pts, 0..b);
        let mut idx = vec![0u32; b];
        let mut d2 = vec![0.0f32; b];
        let ns = time_fn(3, iters, || {
            native.nearest(block, &ctr, &mut idx, &mut d2).unwrap();
        });
        let (xs_str, ratio) = if let Some(x) = &xla {
            let xs = time_fn(3, iters, || {
                x.nearest(block, &ctr, &mut idx, &mut d2).unwrap();
            });
            (fmt_duration(xs.mean), format!("{:.2}x", xs.mean.as_secs_f64() / ns.mean.as_secs_f64()))
        } else {
            ("n/a".into(), "n/a".into())
        };
        let melems = (b * k) as f64 / ns.mean.as_secs_f64() / 1e6;
        table.row(vec![
            b.to_string(),
            k.to_string(),
            fmt_duration(ns.mean),
            xs_str,
            format!("{melems:.0}"),
            ratio,
        ]);
    }
    table.print();

    println!("\n=== suffstats: b points into k centers, d={d} ===");
    let mut table = Table::new(&["b", "k", "native", "xla", "xla/native"]);
    for &(b, k) in &shapes {
        let pts = random_matrix(&mut rng, b, d);
        let idx: Vec<u32> = (0..b).map(|_| rng.next_below(k as u64) as u32).collect();
        let block = Block::of(&pts, 0..b);
        let mut sums = Matrix::zeros(k, d);
        let mut counts = vec![0u64; k];
        let ns = time_fn(3, iters, || {
            sums.data.fill(0.0);
            counts.fill(0);
            native.suffstats(block, &idx, &mut sums, &mut counts).unwrap();
        });
        let (xs_str, ratio) = if let Some(x) = &xla {
            let xs = time_fn(3, iters, || {
                sums.data.fill(0.0);
                counts.fill(0);
                x.suffstats(block, &idx, &mut sums, &mut counts).unwrap();
            });
            (fmt_duration(xs.mean), format!("{:.2}x", xs.mean.as_secs_f64() / ns.mean.as_secs_f64()))
        } else {
            ("n/a".into(), "n/a".into())
        };
        table.row(vec![b.to_string(), k.to_string(), fmt_duration(ns.mean), xs_str, ratio]);
    }
    table.print();

    println!("\n=== bp_descend: b points × k features, d={d}, 2 sweeps ===");
    let mut table = Table::new(&["b", "k", "native", "xla", "xla/native"]);
    for &(b, k) in &[(256usize, 64usize), (256, 256), (1024, 64), (1024, 256)] {
        let pts = random_matrix(&mut rng, b, d);
        let feats = random_matrix(&mut rng, k, d);
        let block = Block::of(&pts, 0..b);
        let ns = time_fn(2, iters.min(10), || {
            native.bp_descend(block, &feats, 2).unwrap();
        });
        let (xs_str, ratio) = if let Some(x) = &xla {
            let xs = time_fn(2, iters.min(10), || {
                x.bp_descend(block, &feats, 2).unwrap();
            });
            (fmt_duration(xs.mean), format!("{:.2}x", xs.mean.as_secs_f64() / ns.mean.as_secs_f64()))
        } else {
            ("n/a".into(), "n/a".into())
        };
        table.row(vec![b.to_string(), k.to_string(), fmt_duration(ns.mean), xs_str, ratio]);
    }
    table.print();
}
