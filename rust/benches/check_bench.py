#!/usr/bin/env python3
"""CI regression gate for benches/schedulers.rs.

Usage: check_bench.py BENCH_schedulers.json schedulers_baseline.json

Reads the machine-readable bench output (one row per algo x scheduler x
speculation x sharding x transport x frugal_wire cell) and applies four
gates:

1. Wire bytes (BSP): the dpmeans tcp wire bytes per epoch, relative to the
   run's own full-snapshot (frugal_wire=false) measurement. The baseline
   records the expected frugal/full ratio and the gate trips when the
   measured ratio exceeds twice that record. Byte counts are deterministic
   for a fixed config, so this is a sharp gate, not a timing-noise one.
2. Wire bytes (depth 2): the same ratio for the wave engine at
   speculation=2 — deeper pipelines chain snapshot deltas across in-flight
   waves, and this gate catches the diet silently degrading to full
   re-ships under speculation.
3. Depth structure: the speculation=4 dpmeans tcp row must report
   max_queue_depth == 4 (the pipeline genuinely fills) — a structural,
   deterministic property of the wave engine, not a timing.
4. Conflict packing: the depth-4 bpmeans tcp sharding=conflict row must
   cancel strictly fewer waves than its sharding=hash twin, and no more
   than the recorded baseline (0: the lazy dispatch-time respin policy
   never broadcast-cancels). Cancellation counts are deterministic for a
   fixed config, so this too is structural, not timing.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        bench = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)

    def row(algo, transport, scheduler, frugal, speculation=None, sharding="hash"):
        for r in bench["rows"]:
            key = (r["algo"], r["transport"], r["scheduler"], r["frugal_wire"])
            if key != (algo, transport, scheduler, frugal):
                continue
            if speculation is not None and r.get("speculation") != speculation:
                continue
            if r.get("sharding", "hash") != sharding:
                continue
            return r
        print(
            f"missing bench row {algo}/{transport}/{scheduler}/"
            f"frugal={frugal}/speculation={speculation}/sharding={sharding}",
            file=sys.stderr,
        )
        sys.exit(1)

    failures = 0

    full = row("dpmeans", "tcp", "bsp", False)

    # Gate 1: BSP frugal vs full.
    frugal_bsp = row("dpmeans", "tcp", "bsp", True)
    ratio = frugal_bsp["wire_per_epoch"] / max(full["wire_per_epoch"], 1.0)
    limit = 2.0 * baseline["dpmeans_tcp_wire_per_epoch_ratio_vs_full"]
    print(
        f"dpmeans tcp bsp wire/ep: frugal={frugal_bsp['wire_per_epoch']:.0f} B, "
        f"full={full['wire_per_epoch']:.0f} B, ratio={ratio:.3f} (limit {limit:.3f})"
    )
    if ratio > limit:
        print(f"wire-byte regression (bsp): {ratio:.3f} > {limit:.3f}", file=sys.stderr)
        failures += 1

    # Gate 2: depth-2 wave engine vs the same full baseline.
    depth2 = row("dpmeans", "tcp", "pipelined", True, speculation=2)
    ratio2 = depth2["wire_per_epoch"] / max(full["wire_per_epoch"], 1.0)
    limit2 = 2.0 * baseline["dpmeans_tcp_depth2_wire_per_epoch_ratio_vs_full"]
    print(
        f"dpmeans tcp speculation=2 wire/ep: {depth2['wire_per_epoch']:.0f} B, "
        f"ratio={ratio2:.3f} (limit {limit2:.3f})"
    )
    if ratio2 > limit2:
        print(f"wire-byte regression (depth 2): {ratio2:.3f} > {limit2:.3f}", file=sys.stderr)
        failures += 1

    # Gate 3: the depth sweep exists and depth 4 genuinely fills. (The
    # sweep rows run under the pipelined scheduler kind; speculation=1 is
    # its BSP-equivalent depth.)
    for depth in (1, 2, 4):
        row("dpmeans", "tcp", "pipelined", True, speculation=depth)
    depth4 = row("dpmeans", "tcp", "pipelined", True, speculation=4)
    if depth4.get("max_queue_depth") != 4:
        print(
            f"speculation=4 pipeline never filled: max_queue_depth="
            f"{depth4.get('max_queue_depth')}",
            file=sys.stderr,
        )
        failures += 1
    else:
        print("depth gate: speculation=4 filled the pipeline (max_queue_depth=4)")

    # Gate 4: conflict packing's respin policy on the unpatchable algorithm.
    # The depth-4 bpmeans rows exist for both sharding modes; conflict must
    # cancel strictly fewer waves than hash and stay at the recorded
    # baseline (0 — lazy dispatch-time respins, never broadcast cancels).
    hash4 = row("bpmeans", "tcp", "pipelined", True, speculation=4, sharding="hash")
    conflict4 = row("bpmeans", "tcp", "pipelined", True, speculation=4, sharding="conflict")
    hash_cancelled = hash4.get("cancelled_waves", 0)
    conflict_cancelled = conflict4.get("cancelled_waves", 0)
    allowed = baseline["bpmeans_tcp_depth4_conflict_cancelled_waves_max"]
    print(
        f"bpmeans tcp speculation=4 cancelled_waves: hash={hash_cancelled:.0f}, "
        f"conflict={conflict_cancelled:.0f} (baseline max {allowed:.0f})"
    )
    if conflict_cancelled > allowed:
        print(
            f"conflict packing cancelled waves: {conflict_cancelled:.0f} > "
            f"baseline {allowed:.0f}",
            file=sys.stderr,
        )
        failures += 1
    if conflict_cancelled >= hash_cancelled:
        print(
            f"conflict packing must cancel strictly fewer waves than hash "
            f"({conflict_cancelled:.0f} vs {hash_cancelled:.0f})",
            file=sys.stderr,
        )
        failures += 1

    if failures:
        return 1
    print("bench gates: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
