#!/usr/bin/env python3
"""CI regression gate for benches/schedulers.rs.

Usage: check_bench.py BENCH_schedulers.json schedulers_baseline.json

Reads the machine-readable bench output (one row per algo x scheduler x
speculation x sharding x transport x io x frugal_wire cell) and applies
seven gates:

1. Wire bytes (BSP): the dpmeans tcp wire bytes per epoch, relative to the
   run's own full-snapshot (frugal_wire=false) measurement. The baseline
   records the expected frugal/full ratio and the gate trips when the
   measured ratio exceeds twice that record. Byte counts are deterministic
   for a fixed config, so this is a sharp gate, not a timing-noise one.
2. Wire bytes (depth 2): the same ratio for the wave engine at
   speculation=2 — deeper pipelines chain snapshot deltas across in-flight
   waves, and this gate catches the diet silently degrading to full
   re-ships under speculation.
3. Depth structure: the speculation=4 dpmeans tcp row must report
   max_queue_depth == 4 (the pipeline genuinely fills) — a structural,
   deterministic property of the wave engine, not a timing.
4. Conflict packing: the depth-4 bpmeans tcp sharding=conflict row must
   cancel strictly fewer waves than its sharding=hash twin, and no more
   than the recorded baseline (0: the lazy dispatch-time respin policy
   never broadcast-cancels). Cancellation counts are deterministic for a
   fixed config, so this too is structural, not timing.
5. Reactor vs poll (schema 4): the small-epoch latency experiment's
   io=reactor row must block-and-wake strictly fewer times than its
   io=poll twin (reactor_wakeups — a structural count of event-loop
   blocking points, not a timing) and strictly beat it on p50 per-epoch
   latency. Skipped with a notice on schema-3 artifacts, which predate
   the io column.
6. Streaming admission latency (schema 5): the ingest experiment's
   io=reactor row must strictly beat its io=poll twin on the
   admission->uptake p50 (admission_p50_ms) — the seal-to-scheduler
   wakeup path that `occd serve` rides. Relative within one run, like
   gate 5, so it carries no recorded baseline number. Skipped with a
   notice on schema-4 artifacts, which predate the ingest experiment.
7. Assignment-kernel throughput (schema 6): the assign experiment's
   kernel=panel row must strictly beat its kernel=scalar twin on
   points_per_sec. The bench itself asserts the two kernels agree
   bitwise before timing, so this gate is purely about the cache-tiled
   kernel earning its keep. Relative within one run, like gates 5/6, so
   no recorded baseline number. Skipped with a notice on schema-5
   artifacts, which predate the kernel knob.
8. Peer residency (schema 7): the residency experiment's store=sparse row
   must report a nonzero peak per-peer resident_data_bytes strictly below
   its store=dense twin, and the dense twin must equal the full n*dim*4
   matrix (a dense peer materializes everything on its first shipped
   block). Coverage and shipped bytes are deterministic for a fixed
   config, so this is a sharp structural gate. The bench asserts the
   twins are bit-identical before the footprint is compared. Skipped
   with a notice on schema-6 artifacts, which predate the store knob.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        bench = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)

    def row(algo, transport, scheduler, frugal, speculation=None, sharding="hash",
            io=None, experiment=None):
        # io=None matches any io mode (schema-3 artifacts have no io key);
        # experiment=None matches only the ordinary perf rows, never the
        # dedicated latency-experiment rows.
        for r in bench["rows"]:
            key = (r["algo"], r["transport"], r["scheduler"], r["frugal_wire"])
            if key != (algo, transport, scheduler, frugal):
                continue
            if speculation is not None and r.get("speculation") != speculation:
                continue
            if r.get("sharding", "hash") != sharding:
                continue
            if io is not None and r.get("io") != io:
                continue
            if r.get("experiment") != experiment:
                continue
            return r
        print(
            f"missing bench row {algo}/{transport}/{scheduler}/"
            f"frugal={frugal}/speculation={speculation}/sharding={sharding}"
            f"/io={io}/experiment={experiment}",
            file=sys.stderr,
        )
        sys.exit(1)

    failures = 0

    full = row("dpmeans", "tcp", "bsp", False)

    # Gate 1: BSP frugal vs full.
    frugal_bsp = row("dpmeans", "tcp", "bsp", True)
    ratio = frugal_bsp["wire_per_epoch"] / max(full["wire_per_epoch"], 1.0)
    limit = 2.0 * baseline["dpmeans_tcp_wire_per_epoch_ratio_vs_full"]
    print(
        f"dpmeans tcp bsp wire/ep: frugal={frugal_bsp['wire_per_epoch']:.0f} B, "
        f"full={full['wire_per_epoch']:.0f} B, ratio={ratio:.3f} (limit {limit:.3f})"
    )
    if ratio > limit:
        print(f"wire-byte regression (bsp): {ratio:.3f} > {limit:.3f}", file=sys.stderr)
        failures += 1

    # Gate 2: depth-2 wave engine vs the same full baseline.
    depth2 = row("dpmeans", "tcp", "pipelined", True, speculation=2)
    ratio2 = depth2["wire_per_epoch"] / max(full["wire_per_epoch"], 1.0)
    limit2 = 2.0 * baseline["dpmeans_tcp_depth2_wire_per_epoch_ratio_vs_full"]
    print(
        f"dpmeans tcp speculation=2 wire/ep: {depth2['wire_per_epoch']:.0f} B, "
        f"ratio={ratio2:.3f} (limit {limit2:.3f})"
    )
    if ratio2 > limit2:
        print(f"wire-byte regression (depth 2): {ratio2:.3f} > {limit2:.3f}", file=sys.stderr)
        failures += 1

    # Gate 3: the depth sweep exists and depth 4 genuinely fills. (The
    # sweep rows run under the pipelined scheduler kind; speculation=1 is
    # its BSP-equivalent depth.)
    for depth in (1, 2, 4):
        row("dpmeans", "tcp", "pipelined", True, speculation=depth)
    depth4 = row("dpmeans", "tcp", "pipelined", True, speculation=4)
    if depth4.get("max_queue_depth") != 4:
        print(
            f"speculation=4 pipeline never filled: max_queue_depth="
            f"{depth4.get('max_queue_depth')}",
            file=sys.stderr,
        )
        failures += 1
    else:
        print("depth gate: speculation=4 filled the pipeline (max_queue_depth=4)")

    # Gate 4: conflict packing's respin policy on the unpatchable algorithm.
    # The depth-4 bpmeans rows exist for both sharding modes; conflict must
    # cancel strictly fewer waves than hash and stay at the recorded
    # baseline (0 — lazy dispatch-time respins, never broadcast cancels).
    hash4 = row("bpmeans", "tcp", "pipelined", True, speculation=4, sharding="hash")
    conflict4 = row("bpmeans", "tcp", "pipelined", True, speculation=4, sharding="conflict")
    hash_cancelled = hash4.get("cancelled_waves", 0)
    conflict_cancelled = conflict4.get("cancelled_waves", 0)
    allowed = baseline["bpmeans_tcp_depth4_conflict_cancelled_waves_max"]
    print(
        f"bpmeans tcp speculation=4 cancelled_waves: hash={hash_cancelled:.0f}, "
        f"conflict={conflict_cancelled:.0f} (baseline max {allowed:.0f})"
    )
    if conflict_cancelled > allowed:
        print(
            f"conflict packing cancelled waves: {conflict_cancelled:.0f} > "
            f"baseline {allowed:.0f}",
            file=sys.stderr,
        )
        failures += 1
    if conflict_cancelled >= hash_cancelled:
        print(
            f"conflict packing must cancel strictly fewer waves than hash "
            f"({conflict_cancelled:.0f} vs {hash_cancelled:.0f})",
            file=sys.stderr,
        )
        failures += 1

    # Gate 5: the readiness reactor must strictly beat the legacy poll
    # baseline on the small-epoch latency experiment — fewer event-loop
    # wakeups (structural: every blocking point ticks the counter under
    # both modes) and a lower p50 per-epoch latency.
    if bench.get("schema", 0) >= 4:
        reactor = row("dpmeans", "tcp", "pipelined", True, speculation=2,
                      io="reactor", experiment="latency")
        poll = row("dpmeans", "tcp", "pipelined", True, speculation=2,
                   io="poll", experiment="latency")
        rw, pw = reactor["reactor_wakeups"], poll["reactor_wakeups"]
        rp50, pp50 = reactor["latency_p50_ms"], poll["latency_p50_ms"]
        print(
            f"io gate: reactor wakeups={rw:.0f} p50={rp50:.3f} ms vs "
            f"poll wakeups={pw:.0f} p50={pp50:.3f} ms"
        )
        if rw >= pw:
            print(
                f"reactor must block-and-wake strictly fewer times than poll "
                f"({rw:.0f} vs {pw:.0f})",
                file=sys.stderr,
            )
            failures += 1
        if rp50 >= pp50:
            print(
                f"reactor p50 epoch latency must strictly beat poll "
                f"({rp50:.3f} ms vs {pp50:.3f} ms)",
                file=sys.stderr,
            )
            failures += 1
    else:
        print("io gate: skipped (schema < 4 artifact has no io column)")

    # Gate 6: the streaming ingest experiment — the reactor's cross-thread
    # seal wakeup must strictly beat the poll plane's idle-slice sleep on
    # the admission->uptake p50. Relative within one run (both twins ran
    # on the same machine seconds apart), so no recorded baseline number.
    if bench.get("schema", 0) >= 5:
        ing_reactor = row("dpmeans", "tcp", "pipelined", True, speculation=2,
                          io="reactor", experiment="ingest")
        ing_poll = row("dpmeans", "tcp", "pipelined", True, speculation=2,
                       io="poll", experiment="ingest")
        ra50, pa50 = ing_reactor["admission_p50_ms"], ing_poll["admission_p50_ms"]
        print(
            f"ingest gate: reactor admission p50={ra50:.3f} ms vs "
            f"poll admission p50={pa50:.3f} ms"
        )
        if ra50 >= pa50:
            print(
                f"reactor admission->uptake p50 must strictly beat poll "
                f"({ra50:.3f} ms vs {pa50:.3f} ms)",
                file=sys.stderr,
            )
            failures += 1
    else:
        print("ingest gate: skipped (schema < 5 artifact has no ingest experiment)")

    # Gate 7: the cache-tiled panel kernel must strictly beat the scalar
    # reference on assignment throughput. Bit-identity across kernels is
    # asserted inside the bench before timing, so a regression here is a
    # pure performance loss, never a correctness trade.
    if bench.get("schema", 0) >= 6:
        def kernel_row(kernel):
            for r in bench["rows"]:
                if r.get("experiment") == "assign" and r.get("kernel") == kernel:
                    return r
            print(f"missing assign row for kernel={kernel}", file=sys.stderr)
            sys.exit(1)

        panel = kernel_row("panel")
        scalar = kernel_row("scalar")
        pps, sps = panel["points_per_sec"], scalar["points_per_sec"]
        print(
            f"kernel gate: panel={pps:.0f} points/sec vs scalar={sps:.0f} points/sec "
            f"({panel['points']:.0f} pts x {panel['centers']:.0f} centers, "
            f"d={panel['dim']:.0f})"
        )
        if pps <= sps:
            print(
                f"panel kernel must strictly beat scalar on points/sec "
                f"({pps:.0f} vs {sps:.0f})",
                file=sys.stderr,
            )
            failures += 1
    else:
        print("kernel gate: skipped (schema < 6 artifact has no assign experiment)")

    # Gate 8: the out-of-core block store must earn its keep — a sparse
    # peer's peak resident footprint stays strictly below the dense
    # matrix the old data plane materialized, with the dense twin pinned
    # at exactly n*dim*4 so the comparison can never drift.
    if bench.get("schema", 0) >= 7:
        def store_row(store):
            for r in bench["rows"]:
                if r.get("experiment") == "residency" and r.get("store") == store:
                    return r
            print(f"missing residency row for store={store}", file=sys.stderr)
            sys.exit(1)

        sparse = store_row("sparse")
        dense = store_row("dense")
        sres, dres = sparse["resident_data_bytes"], dense["resident_data_bytes"]
        full_matrix = sparse["n"] * sparse["dim"] * 4
        print(
            f"residency gate: sparse={sres:.0f} B vs dense={dres:.0f} B "
            f"(n={sparse['n']:.0f}, dim={sparse['dim']:.0f}, "
            f"matrix={full_matrix:.0f} B)"
        )
        if dres != full_matrix:
            print(
                f"dense peer residency must equal the full matrix "
                f"({dres:.0f} vs {full_matrix:.0f})",
                file=sys.stderr,
            )
            failures += 1
        if sres <= 0 or sres >= dres:
            print(
                f"sparse peer residency must be nonzero and strictly below dense "
                f"({sres:.0f} vs {dres:.0f})",
                file=sys.stderr,
            )
            failures += 1
    else:
        print("residency gate: skipped (schema < 7 artifact has no residency experiment)")

    if failures:
        return 1
    print("bench gates: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
