#!/usr/bin/env python3
"""CI wire-byte regression gate for benches/schedulers.rs.

Usage: check_bench.py BENCH_schedulers.json schedulers_baseline.json

Reads the machine-readable bench output (one row per algo x scheduler x
transport x frugal_wire cell) and gates the dpmeans tcp wire bytes per
epoch against the run's own full-snapshot measurement: the baseline file
records the expected frugal/full ratio (frugal_wire=true bytes divided by
the frugal_wire=false bytes of the same config — the in-run stand-in for
the pre-diet wire cost, since inproc moves zero bytes and cannot anchor a
ratio), and the gate trips when the measured ratio exceeds twice that
record. Byte counts are deterministic for a fixed config, so this is a
sharp gate, not a timing-noise one.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    with open(sys.argv[1]) as f:
        bench = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)

    def row(algo, transport, scheduler, frugal):
        for r in bench["rows"]:
            key = (r["algo"], r["transport"], r["scheduler"], r["frugal_wire"])
            if key == (algo, transport, scheduler, frugal):
                return r
        print(
            f"missing bench row {algo}/{transport}/{scheduler}/frugal={frugal}",
            file=sys.stderr,
        )
        sys.exit(1)

    frugal = row("dpmeans", "tcp", "bsp", True)
    full = row("dpmeans", "tcp", "bsp", False)
    ratio = frugal["wire_per_epoch"] / max(full["wire_per_epoch"], 1.0)
    limit = 2.0 * baseline["dpmeans_tcp_wire_per_epoch_ratio_vs_full"]
    print(
        f"dpmeans tcp wire/ep: frugal={frugal['wire_per_epoch']:.0f} B, "
        f"full={full['wire_per_epoch']:.0f} B, ratio={ratio:.3f} (limit {limit:.3f})"
    )
    if ratio > limit:
        print(
            f"wire-byte regression: frugal/full ratio {ratio:.3f} exceeds {limit:.3f}",
            file=sys.stderr,
        )
        return 1
    print("wire-byte gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
