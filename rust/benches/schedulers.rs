//! BSP vs pipelined scheduler × inproc vs TCP transport: wall-clock and
//! wire cost on the fig3-style workloads.
//!
//! Runs each algorithm end to end on its §4 synthetic workload under both
//! epoch schedulers and both cluster transports, reporting total
//! wall-clock, the master-validation time that overlapped worker compute
//! (`validate_overlap_ms` summed over epochs), BP-means' speculative
//! respins, and the transport overhead columns:
//!
//! * `wire/ep` — bytes over the wire per epoch under the default
//!   wire-frugal shipping (snapshot deltas + validator row subsets);
//! * `full/ep` — the same run with `frugal_wire = false`, i.e. the PR 3
//!   embed-everything wire shape, measured as the before/after baseline
//!   (tcp rows only; the bench *asserts* the dpmeans diet is a strict
//!   improvement);
//! * `delta/ep`, `ds/ep` — snapshot-delta and dataset bytes per epoch;
//! * `gwait` — gather idle-wait summed over epochs (the straggler tail the
//!   out-of-order gather exposes).
//!
//! Before reporting, the bench *asserts* every scheduler/transport/wire
//! combination produced a bit-identical model — the speedups and savings
//! are only meaningful because the answer is unchanged.
//!
//! Besides the console table (+ CSV), the bench writes a machine-readable
//! `target/bench-results/BENCH_schedulers.json` so the perf trajectory is
//! tracked across PRs — one row per `(algo, scheduler, speculation,
//! sharding, transport, frugal_wire)` cell, including a `speculation ∈
//! {1, 2, 4}` depth sweep of the wave engine with `commit_lag_ms`,
//! `cancelled_waves` and `max_queue_depth` columns, and a depth-4
//! `sharding = conflict` row per algo/transport; schema documented in the
//! README and consumed by the CI `bench-smoke` job. The bench asserts the
//! depth-4 dpmeans tcp run genuinely overlaps (pipeline filled to 4,
//! nonzero overlapped validation) while staying bit-identical, and that
//! the depth-4 bpmeans conflict row cancels strictly fewer waves than its
//! hash twin (the conflict-packing acceptance bar: lazy respins, zero
//! cancellations).
//!
//! Schema 4 adds the I/O-plane columns (`io`, `reactor_wakeups`,
//! `writev_batches`) and a **small-epoch latency experiment**: the same
//! dpmeans tcp pipeline run twice — `io = "reactor"` vs the legacy
//! `io = "poll"` sleep-slice baseline — on tiny epochs where the event
//! loop's fixed cost dominates, reporting p50/p95 per-epoch latency. The
//! bench asserts the twins are bit-identical and that the reactor wakes
//! strictly fewer times *and* strictly beats poll on p50 epoch latency
//! (gate 5 in `check_bench.py` holds the line across PRs).
//!
//! Schema 5 adds a **streaming ingest experiment** (`experiment =
//! "ingest"`): a paced producer feeds mini-epochs through a `LiveSource`
//! into `run_streaming` — the `occd serve` admission path minus the TCP
//! gateway — measuring the admission→uptake wait under `io = "reactor"`
//! vs `io = "poll"` (`admission_p50_ms` / `admission_p95_ms` columns).
//! The reactor's cross-thread wakeup must strictly beat the poll plane's
//! idle-slice sleep on p50 (gate 6 in `check_bench.py`), with the
//! streamed twins bit-identical.
//!
//! Schema 6 adds an **assignment-kernel throughput experiment**
//! (`experiment = "assign"`): one nearest-center sweep of a point block
//! against an L2-busting k×d snapshot, `kernel = "panel"` (tiled, cached
//! norms) vs `kernel = "scalar"` (flat reference), through the same
//! `ComputeBackend::nearest_with` dispatch the workers use, reporting
//! `points_per_sec` per kernel. The kernels must agree bitwise before
//! timing; gate 7 in `check_bench.py` asserts panel strictly wins.
//!
//! Schema 7 adds a **peer-residency experiment** (`experiment =
//! "residency"`): the same sharded multi-worker tcp dpmeans run twice —
//! `store = "sparse"` (offset-keyed, panel-aligned block store) vs
//! `store = "dense"` (the full n×d session matrix) — reporting the
//! coordinator's peak per-peer `resident_data_bytes` gauge per variant.
//! The twins must be bit-identical; gate 8 in `check_bench.py` asserts the
//! sparse peer footprint stays strictly below the dense `n·d·4` matrix.
//!
//! Defaults keep single-machine runtime in seconds; pass `--n=…`, `--pb=…`,
//! `--procs=…`, `--reps=…` to scale up.

use occml::benchlib::{fmt_duration, BenchArgs, Table};
use occml::config::{
    Algo, DataSource, IoKind, RunConfig, SchedulerKind, ShardingKind, TransportKind,
};
use occml::coordinator::{driver, Model};
use occml::metrics::json::{obj, Json};
use occml::runtime::native::NativeBackend;
use std::sync::Arc;
use std::time::Duration;

fn models_identical(a: &Model, b: &Model) -> bool {
    match (a, b) {
        (Model::Dp(x), Model::Dp(y)) => {
            x.centers.data == y.centers.data && x.assignments == y.assignments
        }
        (Model::Ofl(x), Model::Ofl(y)) => {
            x.centers.data == y.centers.data
                && x.assignments == y.assignments
                && x.opened_by == y.opened_by
        }
        (Model::Bp(x), Model::Bp(y)) => {
            x.features.data == y.features.data && x.assignments == y.assignments
        }
        _ => false,
    }
}

/// One JSON row of `BENCH_schedulers.json` (schema 4: adds the I/O-plane
/// columns `io`, `reactor_wakeups` and `writev_batches` to the schema 3
/// columns `sharding`, `components_max` and `effective_speculation_max`;
/// the separate latency rows carry `experiment = "latency"` plus
/// `latency_p50_ms`/`latency_p95_ms`).
#[allow(clippy::too_many_arguments)]
fn json_row(
    algo: &str,
    scheduler: SchedulerKind,
    speculation: usize,
    sharding: ShardingKind,
    transport: TransportKind,
    io: IoKind,
    frugal: bool,
    out: &driver::RunOutput,
) -> Json {
    let s = &out.summary;
    let epochs = s.epochs.len().max(1);
    obj(vec![
        ("algo", Json::Str(algo.to_string())),
        ("scheduler", Json::Str(scheduler.name().to_string())),
        ("speculation", Json::Num(speculation as f64)),
        ("sharding", Json::Str(sharding.name().to_string())),
        ("transport", Json::Str(transport.name().to_string())),
        ("io", Json::Str(io.name().to_string())),
        ("frugal_wire", Json::Bool(frugal)),
        ("wall_ms", Json::Num(s.total_time.as_secs_f64() * 1e3)),
        ("epochs", Json::Num(epochs as f64)),
        ("wire_bytes", Json::Num(s.total_wire_bytes() as f64)),
        ("unique_payload_bytes", Json::Num(s.total_unique_payload_bytes() as f64)),
        ("delta_bytes", Json::Num(s.total_delta_bytes() as f64)),
        ("dataset_bytes", Json::Num(s.total_dataset_bytes() as f64)),
        ("full_snapshot_fallbacks", Json::Num(s.total_full_snapshot_fallbacks() as f64)),
        ("wire_per_epoch", Json::Num(s.total_wire_bytes() as f64 / epochs as f64)),
        ("delta_per_epoch", Json::Num(s.total_delta_bytes() as f64 / epochs as f64)),
        ("ds_per_epoch", Json::Num(s.total_dataset_bytes() as f64 / epochs as f64)),
        ("ser_ms", Json::Num(s.total_ser_time().as_secs_f64() * 1e3)),
        ("gather_wait_ms", Json::Num(s.total_gather_wait().as_secs_f64() * 1e3)),
        ("overlap_ms", Json::Num(s.total_overlap().as_secs_f64() * 1e3)),
        ("respins", Json::Num(s.total_respins() as f64)),
        ("cancelled_waves", Json::Num(s.total_cancelled_waves() as f64)),
        ("commit_lag_ms", Json::Num(s.total_commit_lag().as_secs_f64() * 1e3)),
        ("max_queue_depth", Json::Num(s.max_queue_depth() as f64)),
        ("components_max", Json::Num(s.max_largest_component() as f64)),
        ("effective_speculation_max", Json::Num(s.max_effective_speculation() as f64)),
        ("reactor_wakeups", Json::Num(s.transport.reactor_wakeups as f64)),
        ("writev_batches", Json::Num(s.transport.writev_batches as f64)),
    ])
}

fn main() {
    let args = BenchArgs::from_env();
    let n: usize = args.get_or("n", 16_384);
    let pb: usize = args.get_or("pb", 1024);
    let procs: usize = args.get_or("procs", 4);
    let reps: usize = args.get_or("reps", 3);
    let block = (pb / procs).max(1);

    let experiments: &[(&str, Algo, DataSource, f64, usize)] = &[
        ("dpmeans", Algo::DpMeans, DataSource::DpClusters, 2.0, 3),
        ("ofl", Algo::Ofl, DataSource::DpClusters, 2.0, 1),
        ("bpmeans", Algo::BpMeans, DataSource::BpFeatures, 1.0, 3),
    ];

    println!(
        "\n=== scheduler × transport: N={n}, P={procs}, b={block} (Pb={}) — best of {reps} ===",
        procs * block
    );
    // Failed invariants are collected and asserted only after the JSON
    // artifact is written, so a failing run still ships its diagnostics.
    let mut failures: Vec<String> = Vec::new();
    let mut table = Table::new(&[
        "algo",
        "transport",
        "bsp",
        "pipelined",
        "speedup",
        "overlap_ms",
        "wire/ep",
        "full/ep",
        "delta/ep",
        "ds/ep",
        "gwait",
        "respins",
        "identical",
    ]);
    let mut rows: Vec<Json> = Vec::new();

    for (name, algo, source, lambda, iterations) in experiments {
        let base = RunConfig {
            algo: *algo,
            lambda: *lambda,
            procs,
            block,
            iterations: *iterations,
            bootstrap_div: if *algo == Algo::Ofl { 0 } else { 16 },
            source: source.clone(),
            n,
            seed: 12,
            ..RunConfig::default()
        };
        let data = Arc::new(driver::load_or_generate(&base).expect("generate"));

        let run_best = |transport: TransportKind,
                        kind: SchedulerKind,
                        speculation: usize,
                        sharding: ShardingKind,
                        frugal: bool,
                        r: usize| {
            let cfg = RunConfig {
                transport,
                scheduler: kind,
                speculation,
                sharding,
                frugal_wire: frugal,
                ..base.clone()
            };
            let mut best: Option<driver::RunOutput> = None;
            for _ in 0..r {
                let out = driver::run_with(&cfg, data.clone(), Arc::new(NativeBackend::new()))
                    .expect("run");
                let better = match &best {
                    None => true,
                    Some(b) => out.summary.total_time < b.summary.total_time,
                };
                if better {
                    best = Some(out);
                }
            }
            best.expect("at least one rep")
        };

        let mut reference: Option<driver::RunOutput> = None;
        for transport in [TransportKind::InProc, TransportKind::Tcp] {
            let bsp = run_best(transport, SchedulerKind::Bsp, 1, ShardingKind::Hash, true, reps);
            let pip =
                run_best(transport, SchedulerKind::Pipelined, 2, ShardingKind::Hash, true, reps);
            let mut identical = models_identical(&bsp.model, &pip.model)
                && reference
                    .as_ref()
                    .map(|r| models_identical(&r.model, &bsp.model))
                    .unwrap_or(true);

            // The per-depth sweep: one row per speculation depth so the
            // trajectory (and check_bench.py's depth gate) can see how
            // commit lag, cancellations and queue depth scale with K.
            // Depth 2 already ran above as the table's pipelined column.
            for depth in [1usize, 4] {
                let out = run_best(
                    transport,
                    SchedulerKind::Pipelined,
                    depth,
                    ShardingKind::Hash,
                    true,
                    1,
                );
                identical = identical && models_identical(&bsp.model, &out.model);
                if depth == 4 {
                    // The per-sharding twin: the same depth-4 run under
                    // conflict-aware component packing. Bit-identity is the
                    // invariant; the cancelled-waves contrast is the win
                    // (asserted below for bpmeans, the unpatchable case).
                    let conflict = run_best(
                        transport,
                        SchedulerKind::Pipelined,
                        depth,
                        ShardingKind::Conflict,
                        true,
                        1,
                    );
                    identical = identical && models_identical(&bsp.model, &conflict.model);
                    if *name == "bpmeans" {
                        let hash_cancelled = out.summary.total_cancelled_waves();
                        let conflict_cancelled = conflict.summary.total_cancelled_waves();
                        if conflict_cancelled != 0 {
                            failures.push(format!(
                                "bpmeans {} speculation=4 conflict packing must never cancel \
                                 waves (lazy respin), got {conflict_cancelled}",
                                transport.name()
                            ));
                        }
                        if conflict_cancelled >= hash_cancelled {
                            failures.push(format!(
                                "bpmeans {} speculation=4: conflict packing must cancel \
                                 strictly fewer waves than hash ({conflict_cancelled} vs \
                                 {hash_cancelled})",
                                transport.name()
                            ));
                        }
                    }
                    rows.push(json_row(
                        name,
                        SchedulerKind::Pipelined,
                        depth,
                        ShardingKind::Conflict,
                        transport,
                        IoKind::from_env(),
                        true,
                        &conflict,
                    ));
                }
                if *name == "dpmeans" && transport == TransportKind::Tcp && depth == 4 {
                    // The acceptance bar for the wave engine: at depth 4
                    // the dpmeans tcp bench must genuinely overlap —
                    // pipeline filled to 4 epochs, nonzero overlapped
                    // validation — with the model still bit-identical
                    // (checked just above).
                    if out.summary.max_queue_depth() != 4 {
                        failures.push(format!(
                            "dpmeans tcp speculation=4 never filled the pipeline \
                             (max queue_depth {})",
                            out.summary.max_queue_depth()
                        ));
                    }
                    if out.summary.total_overlap().as_nanos() == 0 {
                        failures.push(
                            "dpmeans tcp speculation=4 recorded zero overlap_time".into(),
                        );
                    }
                }
                rows.push(json_row(
                    name,
                    SchedulerKind::Pipelined,
                    depth,
                    ShardingKind::Hash,
                    transport,
                    IoKind::from_env(),
                    true,
                    &out,
                ));
            }

            // The before/after baseline: the same tcp run with the PR 3
            // embed-everything wire shape. Bytes are deterministic, so one
            // rep measures them exactly.
            let full = if transport == TransportKind::Tcp {
                let f = run_best(transport, SchedulerKind::Bsp, 1, ShardingKind::Hash, false, 1);
                identical = identical && models_identical(&bsp.model, &f.model);
                rows.push(json_row(
                    name,
                    SchedulerKind::Bsp,
                    1,
                    ShardingKind::Hash,
                    transport,
                    IoKind::from_env(),
                    false,
                    &f,
                ));
                Some(f)
            } else {
                None
            };
            if !identical {
                // Deferred: the JSON artifact must land even on a failing
                // run — it is most valuable exactly then (CI uploads it
                // with `if: always()`).
                failures.push(format!(
                    "{name}/{}: schedulers, transports or wire modes disagree — \
                     serializability broke",
                    transport.name()
                ));
            }

            let tb = bsp.summary.total_time;
            let tp = pip.summary.total_time;
            let overlap: Duration = pip.summary.total_overlap();
            // Transport overhead per epoch, averaged across both runs.
            let epochs = (bsp.summary.epochs.len() + pip.summary.epochs.len()).max(1);
            let wire = bsp.summary.total_wire_bytes() + pip.summary.total_wire_bytes();
            let delta = bsp.summary.total_delta_bytes() + pip.summary.total_delta_bytes();
            let ds = bsp.summary.total_dataset_bytes() + pip.summary.total_dataset_bytes();
            let gwait = bsp.summary.total_gather_wait() + pip.summary.total_gather_wait();
            let full_per_ep = full.as_ref().map(|f| {
                f.summary.total_wire_bytes() as f64 / f.summary.epochs.len().max(1) as f64
            });
            if *name == "dpmeans" {
                // The acceptance bar: the wire diet must beat the PR 3
                // full-snapshot numbers on the dpmeans config, strictly.
                let frugal_per_ep =
                    bsp.summary.total_wire_bytes() as f64 / bsp.summary.epochs.len().max(1) as f64;
                if let Some(full_ep) = full_per_ep {
                    if frugal_per_ep >= full_ep {
                        failures.push(format!(
                            "dpmeans tcp wire bytes per epoch must be strictly below the \
                             full-snapshot baseline ({frugal_per_ep:.0} vs {full_ep:.0})"
                        ));
                    }
                }
            }
            table.row(vec![
                (*name).to_string(),
                transport.name().to_string(),
                fmt_duration(tb),
                fmt_duration(tp),
                format!("{:.2}x", tb.as_secs_f64() / tp.as_secs_f64().max(1e-12)),
                format!("{:.1}", overlap.as_secs_f64() * 1e3),
                format!("{} B", wire as usize / epochs),
                full_per_ep.map(|f| format!("{f:.0} B")).unwrap_or_else(|| "-".into()),
                format!("{} B", delta as usize / epochs),
                format!("{} B", ds as usize / epochs),
                format!("{:.1} ms", gwait.as_secs_f64() * 1e3),
                pip.summary.total_respins().to_string(),
                identical.to_string(),
            ]);
            rows.push(json_row(
                name,
                SchedulerKind::Bsp,
                1,
                ShardingKind::Hash,
                transport,
                IoKind::from_env(),
                true,
                &bsp,
            ));
            rows.push(json_row(
                name,
                SchedulerKind::Pipelined,
                2,
                ShardingKind::Hash,
                transport,
                IoKind::from_env(),
                true,
                &pip,
            ));
            if reference.is_none() {
                reference = Some(bsp);
            }
        }
    }
    table.print();
    let csv = "target/bench-results/schedulers.csv";
    if table.write_csv(std::path::Path::new(csv)).is_ok() {
        println!("csv: {csv}");
    }

    // --- Small-epoch latency: io = "reactor" vs io = "poll" -------------
    // Tiny epochs (Pb = procs·128 out of a 4096-point workload) make the
    // event loop's fixed per-epoch cost — blocking wakeups, write
    // syscalls — the dominant term, which is exactly what the readiness
    // reactor targets. Both twins must stay bit-identical; the reactor
    // must win strictly on wakeups and on p50 epoch latency (violations
    // are deferred like every other invariant so the JSON artifact still
    // lands on a failing run).
    {
        let lat_n: usize = args.get_or("lat_n", 4096).min(n);
        let lat_block = 128;
        let lat_base = RunConfig {
            algo: Algo::DpMeans,
            lambda: 2.0,
            procs,
            block: lat_block,
            iterations: 3,
            bootstrap_div: 16,
            source: DataSource::DpClusters,
            n: lat_n,
            seed: 12,
            transport: TransportKind::Tcp,
            scheduler: SchedulerKind::Pipelined,
            speculation: 2,
            ..RunConfig::default()
        };
        let data = Arc::new(driver::load_or_generate(&lat_base).expect("generate"));
        let mut lat_table =
            Table::new(&["io", "wall", "lat_p50", "lat_p95", "wakeups", "writev", "identical"]);
        let mut twins: Vec<(IoKind, driver::RunOutput, f64, f64)> = Vec::new();
        for io in [IoKind::Reactor, IoKind::Poll] {
            let cfg = RunConfig { io, ..lat_base.clone() };
            let mut best: Option<driver::RunOutput> = None;
            for _ in 0..reps {
                let out = driver::run_with(&cfg, data.clone(), Arc::new(NativeBackend::new()))
                    .expect("run");
                let better = match &best {
                    None => true,
                    Some(b) => out.summary.total_time < b.summary.total_time,
                };
                if better {
                    best = Some(out);
                }
            }
            let out = best.expect("at least one rep");
            // Worker epochs only — the `usize::MAX` recompute phases are a
            // different workload shape and would skew the percentiles.
            let mut lats: Vec<f64> = out
                .summary
                .epochs
                .iter()
                .filter(|e| e.epoch != usize::MAX)
                .map(|e| e.total_time.as_secs_f64() * 1e3)
                .collect();
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let pct = |p: f64| {
                if lats.is_empty() {
                    0.0
                } else {
                    lats[((lats.len() - 1) as f64 * p).round() as usize]
                }
            };
            let (p50, p95) = (pct(0.50), pct(0.95));
            twins.push((io, out, p50, p95));
        }
        let identical = models_identical(&twins[0].1.model, &twins[1].1.model);
        if !identical {
            failures.push(
                "latency: io=reactor and io=poll models diverged — serializability broke".into(),
            );
        }
        let (rw, pw) = (
            twins[0].1.summary.transport.reactor_wakeups,
            twins[1].1.summary.transport.reactor_wakeups,
        );
        if rw >= pw {
            failures.push(format!(
                "io=reactor must block-and-wake strictly fewer times than io=poll ({rw} vs {pw})"
            ));
        }
        if twins[0].2 >= twins[1].2 {
            failures.push(format!(
                "io=reactor p50 epoch latency must strictly beat io=poll \
                 ({:.3} ms vs {:.3} ms)",
                twins[0].2, twins[1].2
            ));
        }
        println!(
            "\n=== small-epoch latency: io=reactor vs io=poll (dpmeans tcp pipelined/2, \
             N={lat_n}, b={lat_block}) — best of {reps} ==="
        );
        for (io, out, p50, p95) in &twins {
            let t = &out.summary.transport;
            lat_table.row(vec![
                io.name().to_string(),
                fmt_duration(out.summary.total_time),
                format!("{p50:.2} ms"),
                format!("{p95:.2} ms"),
                t.reactor_wakeups.to_string(),
                t.writev_batches.to_string(),
                identical.to_string(),
            ]);
            rows.push(obj(vec![
                ("experiment", Json::Str("latency".to_string())),
                ("algo", Json::Str("dpmeans".to_string())),
                ("scheduler", Json::Str(SchedulerKind::Pipelined.name().to_string())),
                ("speculation", Json::Num(2.0)),
                ("sharding", Json::Str(ShardingKind::Hash.name().to_string())),
                ("transport", Json::Str(TransportKind::Tcp.name().to_string())),
                ("io", Json::Str(io.name().to_string())),
                ("frugal_wire", Json::Bool(true)),
                ("wall_ms", Json::Num(out.summary.total_time.as_secs_f64() * 1e3)),
                ("epochs", Json::Num(out.summary.epochs.len() as f64)),
                ("latency_p50_ms", Json::Num(*p50)),
                ("latency_p95_ms", Json::Num(*p95)),
                ("reactor_wakeups", Json::Num(t.reactor_wakeups as f64)),
                ("writev_batches", Json::Num(t.writev_batches as f64)),
            ]));
        }
        lat_table.print();
    }

    // --- Streaming ingest latency: admission → uptake per io plane -------
    // The schema-5 experiment drives `run_streaming` directly: a paced
    // producer seals one mini-epoch at a time into a `LiveSource` — the
    // same publish-dataset-then-announce-then-wake path `occd serve` uses,
    // minus the TCP gateway — and spins until the engine takes it before
    // sealing the next. `admission_wait` is therefore a pure wakeup-path
    // measurement (sealed → the scheduler's `poll_epoch` uptake), not a
    // queueing artifact. The reactor's cross-thread wakeup must strictly
    // beat the poll plane's idle-slice sleep on p50 (gate 6 in
    // `check_bench.py`), and the twins must stay bit-identical.
    {
        use occml::coordinator::serve::{LiveSource, SealedBatch, WakerSlot};
        use occml::data::{DataCell, Dataset};
        use occml::linalg::Matrix;
        use occml::metrics::MetricsSink;
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::time::Instant;

        let ing_n: usize = args.get_or("ing_n", 2048).min(n);
        let ing_batch: usize = 64;
        let ing_base = RunConfig {
            algo: Algo::DpMeans,
            lambda: 2.0,
            procs,
            block: 32,
            iterations: 1,
            bootstrap_div: 0,
            seed: 12,
            dim: 16,
            transport: TransportKind::Tcp,
            scheduler: SchedulerKind::Pipelined,
            speculation: 2,
            ..RunConfig::default()
        };
        // One shared point pool; every run streams the identical batches.
        let gen_cfg =
            RunConfig { n: ing_n, source: DataSource::DpClusters, ..ing_base.clone() };
        let pool = Arc::new(driver::load_or_generate(&gen_cfg).expect("generate"));
        let mut ing_table =
            Table::new(&["io", "wall", "adm_p50", "adm_p95", "wakeups", "identical"]);
        let mut ing_twins: Vec<(IoKind, driver::RunOutput)> = Vec::new();
        for io in [IoKind::Reactor, IoKind::Poll] {
            let cfg = RunConfig { io, ..ing_base.clone() };
            let mut best: Option<driver::RunOutput> = None;
            for _ in 0..reps {
                let cell =
                    Arc::new(DataCell::new(Arc::new(Dataset::new(Matrix::zeros(0, pool.dim()), None))));
                let (tx, rx) = std::sync::mpsc::channel();
                let depth = Arc::new(AtomicUsize::new(0));
                let waker = Arc::new(WakerSlot::new());
                let mut source = LiveSource::new(rx, depth.clone());
                let producer = {
                    let (cell, depth, waker, pool) =
                        (cell.clone(), depth.clone(), waker.clone(), pool.clone());
                    std::thread::spawn(move || {
                        let d = pool.dim();
                        let mut lo = 0;
                        while lo < pool.len() {
                            let hi = (lo + ing_batch).min(pool.len());
                            // Grown generation published BEFORE the epoch
                            // is announced — the serve admission protocol.
                            cell.set(Arc::new(Dataset::with_norms(
                                Matrix {
                                    rows: hi,
                                    cols: d,
                                    data: pool.points.data[..hi * d].to_vec(),
                                },
                                None,
                                pool.norms[..hi].to_vec(),
                            )));
                            let qd = depth.fetch_add(1, Ordering::SeqCst) + 1;
                            if tx
                                .send(SealedBatch {
                                    span: lo..hi,
                                    sealed_at: Instant::now(),
                                    queue_depth: qd,
                                })
                                .is_err()
                            {
                                return;
                            }
                            waker.wake();
                            // Paced: wait for uptake so the recorded wait
                            // isolates the wakeup path, not queue depth.
                            while depth.load(Ordering::SeqCst) > 0 {
                                std::thread::yield_now();
                            }
                            lo = hi;
                        }
                        // `tx` drops here → the source ends → the engine
                        // drains and finalizes.
                    })
                };
                let mut sink = MetricsSink::Null;
                let out = driver::run_streaming(&cfg, cell, &mut source, &mut sink, |w| {
                    waker.set(w)
                })
                .expect("streaming bench run");
                producer.join().expect("producer thread");
                let better = match &best {
                    None => true,
                    Some(b) => {
                        out.summary.admission_wait_p50() < b.summary.admission_wait_p50()
                    }
                };
                if better {
                    best = Some(out);
                }
            }
            ing_twins.push((io, best.expect("at least one rep")));
        }
        let identical = models_identical(&ing_twins[0].1.model, &ing_twins[1].1.model);
        if !identical {
            failures.push(
                "ingest: io=reactor and io=poll streamed models diverged — the admitted \
                 order no longer determines the model"
                    .into(),
            );
        }
        let ms = |d: Option<Duration>| d.map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0);
        let (r50, p50) = (
            ms(ing_twins[0].1.summary.admission_wait_p50()),
            ms(ing_twins[1].1.summary.admission_wait_p50()),
        );
        if r50 >= p50 {
            failures.push(format!(
                "io=reactor admission→uptake p50 must strictly beat io=poll \
                 ({r50:.3} ms vs {p50:.3} ms)"
            ));
        }
        println!(
            "\n=== streaming ingest latency: io=reactor vs io=poll (dpmeans tcp \
             pipelined/2, N={ing_n}, batch={ing_batch}) — best of {reps} ==="
        );
        for (io, out) in &ing_twins {
            let s = &out.summary;
            let (a50, a95) = (ms(s.admission_wait_p50()), ms(s.admission_wait_p95()));
            ing_table.row(vec![
                io.name().to_string(),
                fmt_duration(s.total_time),
                format!("{a50:.3} ms"),
                format!("{a95:.3} ms"),
                s.transport.reactor_wakeups.to_string(),
                identical.to_string(),
            ]);
            rows.push(obj(vec![
                ("experiment", Json::Str("ingest".to_string())),
                ("algo", Json::Str("dpmeans".to_string())),
                ("scheduler", Json::Str(SchedulerKind::Pipelined.name().to_string())),
                ("speculation", Json::Num(2.0)),
                ("sharding", Json::Str(ShardingKind::Hash.name().to_string())),
                ("transport", Json::Str(TransportKind::Tcp.name().to_string())),
                ("io", Json::Str(io.name().to_string())),
                ("frugal_wire", Json::Bool(true)),
                ("wall_ms", Json::Num(s.total_time.as_secs_f64() * 1e3)),
                ("epochs", Json::Num(s.epochs.len() as f64)),
                ("admission_p50_ms", Json::Num(a50)),
                ("admission_p95_ms", Json::Num(a95)),
                ("max_ingest_queue_depth", Json::Num(s.max_ingest_queue_depth() as f64)),
                ("reactor_wakeups", Json::Num(s.transport.reactor_wakeups as f64)),
            ]));
        }
        ing_table.print();
    }

    // --- Assignment-kernel throughput: kernel = "panel" vs "scalar" ------
    // The schema-6 experiment times the worker-side hot loop in isolation:
    // one nearest-center sweep of a point block against a k×d snapshot too
    // large for L2, through the same `ComputeBackend::nearest_with`
    // dispatch the cluster workers use. The panel kernel re-uses each
    // ≤32-center tile across a 64-point panel (plus the memoized norms);
    // the scalar reference re-streams all k×d center bytes per point.
    // Bit-identity of (idx, d²) across kernels is asserted BEFORE timing —
    // the speedup is only meaningful because the answer is unchanged.
    // Gate 7 in `check_bench.py` asserts panel strictly wins points/sec.
    {
        use occml::config::KernelKind;
        use occml::data::generators::{dp_clusters, GenConfig};
        use occml::linalg::panel::center_norms;
        use occml::runtime::{Block, ComputeBackend};
        use std::time::Instant;

        let asn: usize = args.get_or("asn", 4096).min(n);
        let (ak, ad) = (8192usize, 64usize);
        let points = dp_clusters(&GenConfig { n: asn, dim: ad, theta: 1.0, seed: 7 });
        let centers = dp_clusters(&GenConfig { n: ak, dim: ad, theta: 1.0, seed: 99 }).points;
        let cnorms = center_norms(&centers);

        let sweep = |kernel: KernelKind| {
            let backend = NativeBackend::with_kernel(kernel);
            let (mut idx, mut d2) = (vec![0u32; asn], vec![0.0f32; asn]);
            // One warm sweep outside the clock, then best of `reps`.
            backend
                .nearest_with(
                    Block::of_dataset(&points, 0..asn),
                    &centers,
                    Some(&cnorms),
                    &mut idx,
                    &mut d2,
                )
                .expect("assign sweep");
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t = Instant::now();
                backend
                    .nearest_with(
                        Block::of_dataset(&points, 0..asn),
                        &centers,
                        Some(&cnorms),
                        &mut idx,
                        &mut d2,
                    )
                    .expect("assign sweep");
                best = best.min(t.elapsed().as_secs_f64());
            }
            (idx, d2, best)
        };
        let (pi, pd, pt) = sweep(KernelKind::Panel);
        let (si, sd, st) = sweep(KernelKind::Scalar);
        if pi != si || pd.iter().zip(&sd).any(|(a, b)| a.to_bits() != b.to_bits()) {
            failures.push(
                "assign: panel and scalar kernels disagree bitwise — tiling leaked into \
                 the arithmetic"
                    .into(),
            );
        }
        let mut asn_table = Table::new(&["kernel", "sweep", "points/sec"]);
        println!(
            "\n=== assignment kernel throughput: {asn} points × {ak} centers, d={ad} — \
             best of {reps} ==="
        );
        for (kernel, secs) in [(KernelKind::Panel, pt), (KernelKind::Scalar, st)] {
            let pps = asn as f64 / secs.max(1e-12);
            asn_table.row(vec![
                kernel.name().to_string(),
                format!("{:.2} ms", secs * 1e3),
                format!("{pps:.0}"),
            ]);
            rows.push(obj(vec![
                ("experiment", Json::Str("assign".to_string())),
                ("kernel", Json::Str(kernel.name().to_string())),
                ("points", Json::Num(asn as f64)),
                ("centers", Json::Num(ak as f64)),
                ("dim", Json::Num(ad as f64)),
                ("wall_ms", Json::Num(secs * 1e3)),
                ("points_per_sec", Json::Num(pps)),
            ]));
        }
        asn_table.print();
    }

    // --- Peer data-plane residency: store = "sparse" vs "dense" ----------
    // The schema-7 experiment measures what the out-of-core block store
    // buys: the same sharded multi-worker tcp dpmeans run under both store
    // variants, comparing the coordinator's peak per-peer
    // `resident_data_bytes` gauge. A dense peer materializes the whole
    // n×d matrix on its first shipped block; a sparse peer holds only the
    // panel-aligned blocks covering its shipped ranges, so under an equal
    // split across `procs` workers its footprint is ~1/procs of the
    // matrix. Bit-identity across variants is the invariant (the store is
    // a memory-layout knob, never arithmetic); gate 8 in `check_bench.py`
    // holds the strictly-below line across PRs. Shipped bytes and coverage
    // are deterministic, so one rep measures the gauge exactly.
    {
        use occml::config::StoreKind;

        let res_n: usize = args.get_or("res_n", 8192).min(n);
        let res_base = RunConfig {
            algo: Algo::DpMeans,
            lambda: 2.0,
            procs,
            block: (res_n / (procs * 4)).max(1),
            iterations: 2,
            bootstrap_div: 16,
            source: DataSource::DpClusters,
            n: res_n,
            seed: 12,
            transport: TransportKind::Tcp,
            ..RunConfig::default()
        };
        let data = Arc::new(driver::load_or_generate(&res_base).expect("generate"));
        let mut res_table =
            Table::new(&["store", "wall", "resident", "dense nd4", "identical"]);
        let mut res_twins: Vec<(StoreKind, driver::RunOutput)> = Vec::new();
        for store in [StoreKind::Sparse, StoreKind::Dense] {
            let cfg = RunConfig { store, ..res_base.clone() };
            let out = driver::run_with(&cfg, data.clone(), Arc::new(NativeBackend::new()))
                .expect("residency run");
            res_twins.push((store, out));
        }
        let identical = models_identical(&res_twins[0].1.model, &res_twins[1].1.model);
        if !identical {
            failures.push(
                "residency: store=sparse and store=dense models diverged — the block \
                 store leaked into the arithmetic"
                    .into(),
            );
        }
        let dense_full = (res_n * data.dim() * 4) as u64;
        let sparse_resident = res_twins[0].1.summary.transport.resident_data_bytes;
        if sparse_resident == 0 || sparse_resident >= dense_full {
            failures.push(format!(
                "store=sparse peak peer residency must be nonzero and strictly below the \
                 dense matrix ({sparse_resident} vs {dense_full})"
            ));
        }
        println!(
            "\n=== peer data-plane residency: store=sparse vs store=dense (dpmeans tcp, \
             N={res_n}, P={procs}) ==="
        );
        for (store, out) in &res_twins {
            let resident = out.summary.transport.resident_data_bytes;
            res_table.row(vec![
                store.name().to_string(),
                fmt_duration(out.summary.total_time),
                format!("{resident} B"),
                format!("{dense_full} B"),
                identical.to_string(),
            ]);
            rows.push(obj(vec![
                ("experiment", Json::Str("residency".to_string())),
                ("algo", Json::Str("dpmeans".to_string())),
                ("store", Json::Str(store.name().to_string())),
                ("transport", Json::Str(TransportKind::Tcp.name().to_string())),
                ("sharding", Json::Str(ShardingKind::Hash.name().to_string())),
                ("n", Json::Num(res_n as f64)),
                ("dim", Json::Num(data.dim() as f64)),
                ("wall_ms", Json::Num(out.summary.total_time.as_secs_f64() * 1e3)),
                ("resident_data_bytes", Json::Num(resident as f64)),
            ]));
        }
        res_table.print();
    }

    // Machine-readable results for cross-PR perf tracking (schema in the
    // README; consumed by CI's bench-smoke regression gate).
    let doc = obj(vec![
        ("schema", Json::Num(7.0)),
        ("bench", Json::Str("schedulers".to_string())),
        (
            "params",
            obj(vec![
                ("n", Json::Num(n as f64)),
                ("pb", Json::Num((procs * block) as f64)),
                ("procs", Json::Num(procs as f64)),
                ("reps", Json::Num(reps as f64)),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    let json_path = std::path::Path::new("target/bench-results/BENCH_schedulers.json");
    if let Some(dir) = json_path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(json_path, doc.to_string_compact()) {
        Ok(()) => println!("json: {}", json_path.display()),
        Err(e) => eprintln!("json: write failed: {e}"),
    }
    println!(
        "(identical=true is asserted across schedulers AND transports AND wire modes: every \
         path validates in the same Thm 3.1 serial order; wire/ep vs full/ep is what snapshot \
         delta-shipping + validator row subsets save on the tcp message boundary — inproc rows \
         show 0 and '-')"
    );
    // Now fail the run if any invariant broke — with the artifact on disk.
    assert!(failures.is_empty(), "bench invariants failed:\n{}", failures.join("\n"));
}
