//! BSP vs pipelined scheduler × inproc vs TCP transport: wall-clock on the
//! fig3-style workloads.
//!
//! Runs each algorithm end to end on its §4 synthetic workload under both
//! epoch schedulers and both cluster transports, reporting total
//! wall-clock, the master-validation time that overlapped worker compute
//! (`validate_overlap_ms` summed over epochs), BP-means' speculative
//! respins, and the transport overhead columns: bytes over the wire,
//! master-side serialization time, and dataset bytes shipped per epoch
//! (`wire/ep`, `ser/ep`, `ds/ep` — ser stays low because one wave's shared
//! snapshot is encoded once and spliced into every peer frame). Before
//! reporting, the bench *asserts* every scheduler/transport combination
//! produced a bit-identical model — the speedups and overheads are only
//! meaningful because the answer is unchanged.
//!
//! The inproc rows are the PR-1 fast path (same channels, same `Arc`
//! snapshots — the transport layer adds one virtual call per wave), so
//! inproc bsp vs pipelined also serves as the regression reference.
//!
//! Defaults keep single-machine runtime in seconds; pass `--n=…`, `--pb=…`,
//! `--procs=…`, `--reps=…` to scale up.

use occml::benchlib::{fmt_duration, BenchArgs, Table};
use occml::config::{Algo, DataSource, RunConfig, SchedulerKind, TransportKind};
use occml::coordinator::{driver, Model};
use occml::runtime::native::NativeBackend;
use std::sync::Arc;
use std::time::Duration;

fn models_identical(a: &Model, b: &Model) -> bool {
    match (a, b) {
        (Model::Dp(x), Model::Dp(y)) => {
            x.centers.data == y.centers.data && x.assignments == y.assignments
        }
        (Model::Ofl(x), Model::Ofl(y)) => {
            x.centers.data == y.centers.data
                && x.assignments == y.assignments
                && x.opened_by == y.opened_by
        }
        (Model::Bp(x), Model::Bp(y)) => {
            x.features.data == y.features.data && x.assignments == y.assignments
        }
        _ => false,
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let n: usize = args.get_or("n", 16_384);
    let pb: usize = args.get_or("pb", 1024);
    let procs: usize = args.get_or("procs", 4);
    let reps: usize = args.get_or("reps", 3);
    let block = (pb / procs).max(1);

    let experiments: &[(&str, Algo, DataSource, f64, usize)] = &[
        ("dpmeans", Algo::DpMeans, DataSource::DpClusters, 2.0, 3),
        ("ofl", Algo::Ofl, DataSource::DpClusters, 2.0, 1),
        ("bpmeans", Algo::BpMeans, DataSource::BpFeatures, 1.0, 3),
    ];

    println!(
        "\n=== scheduler × transport: N={n}, P={procs}, b={block} (Pb={}) — best of {reps} ===",
        procs * block
    );
    let mut table = Table::new(&[
        "algo",
        "transport",
        "bsp",
        "pipelined",
        "speedup",
        "overlap_ms",
        "wire/ep",
        "ser/ep",
        "ds/ep",
        "respins",
        "identical",
    ]);

    for (name, algo, source, lambda, iterations) in experiments {
        let base = RunConfig {
            algo: *algo,
            lambda: *lambda,
            procs,
            block,
            iterations: *iterations,
            bootstrap_div: if *algo == Algo::Ofl { 0 } else { 16 },
            source: source.clone(),
            n,
            seed: 12,
            ..RunConfig::default()
        };
        let data = Arc::new(driver::load_or_generate(&base).expect("generate"));

        let run_best = |transport: TransportKind, kind: SchedulerKind| {
            let cfg = RunConfig { transport, scheduler: kind, ..base.clone() };
            let mut best: Option<driver::RunOutput> = None;
            for _ in 0..reps {
                let out = driver::run_with(&cfg, data.clone(), Arc::new(NativeBackend::new()))
                    .expect("run");
                let better = match &best {
                    None => true,
                    Some(b) => out.summary.total_time < b.summary.total_time,
                };
                if better {
                    best = Some(out);
                }
            }
            best.expect("at least one rep")
        };

        let mut reference: Option<driver::RunOutput> = None;
        for transport in [TransportKind::InProc, TransportKind::Tcp] {
            let bsp = run_best(transport, SchedulerKind::Bsp);
            let pip = run_best(transport, SchedulerKind::Pipelined);
            let identical = models_identical(&bsp.model, &pip.model)
                && reference
                    .as_ref()
                    .map(|r| models_identical(&r.model, &bsp.model))
                    .unwrap_or(true);
            assert!(
                identical,
                "{name}/{}: schedulers or transports disagree — serializability broke",
                transport.name()
            );

            let tb = bsp.summary.total_time;
            let tp = pip.summary.total_time;
            let overlap: Duration = pip.summary.total_overlap();
            // Transport overhead per epoch, averaged across both runs.
            let epochs = (bsp.summary.epochs.len() + pip.summary.epochs.len()).max(1);
            let wire =
                bsp.summary.total_wire_bytes() + pip.summary.total_wire_bytes();
            let ser = bsp.summary.total_ser_time() + pip.summary.total_ser_time();
            let ds = bsp.summary.total_dataset_bytes() + pip.summary.total_dataset_bytes();
            table.row(vec![
                (*name).to_string(),
                transport.name().to_string(),
                fmt_duration(tb),
                fmt_duration(tp),
                format!("{:.2}x", tb.as_secs_f64() / tp.as_secs_f64().max(1e-12)),
                format!("{:.1}", overlap.as_secs_f64() * 1e3),
                format!("{} B", wire as usize / epochs),
                format!("{:.2} ms", ser.as_secs_f64() * 1e3 / epochs as f64),
                format!("{} B", ds as usize / epochs),
                pip.summary.total_respins().to_string(),
                identical.to_string(),
            ]);
            if reference.is_none() {
                reference = Some(bsp);
            }
        }
    }
    table.print();
    let csv = "target/bench-results/schedulers.csv";
    if table.write_csv(std::path::Path::new(csv)).is_ok() {
        println!("csv: {csv}");
    }
    println!(
        "(identical=true is asserted across schedulers AND transports: every path validates in \
         the same Thm 3.1 serial order; wire/ep and ser/ep are what the tcp message boundary \
         costs — inproc rows show 0)"
    );
}
