//! Figure 3 (+ Figure 6 / Thm 3.3) — rejections are independent of N.
//!
//! Regenerates the paper's §4.1 simulated experiment: the first iteration
//! of OCC DP-means (3a), OFL (3b) and BP-means (3c), with N swept 256..2560
//! (step 256) and Pb ∈ {16, 32, 64, 128, 256}, measuring the empirical mean
//! of `M_N − k_N` (proposed but not accepted) over many repeats. Fig 6 is
//! the same sweep on the separable-cluster generator of App C.1, where the
//! Thm 3.3 bound `rejections ≤ Pb` holds surely.
//!
//! Paper shape to reproduce: for each Pb, the curve is FLAT in N and sits
//! at or below Pb. Repeats default to 25 (paper: 400) to keep single-core
//! runtime in minutes; pass `--reps=400` for the paper-exact count.

use occml::benchlib::{BenchArgs, Table};
use occml::data::generators::{bp_features, dp_clusters, separable_clusters, GenConfig};
use occml::sim;

fn main() {
    let args = BenchArgs::from_env();
    let reps: usize = args.get_or("reps", 25);
    let ns: Vec<usize> = (1..=10).map(|i| i * 256).collect();
    let pbs = [16usize, 32, 64, 128, 256];

    let experiments: &[(&str, &str)] = &[
        ("fig3a", "OCC DP-means, DP-mixture data"),
        ("fig3b", "OCC OFL, DP-mixture data"),
        ("fig3c", "OCC BP-means, BP-feature data"),
        ("fig6", "OCC DP-means, separable data (Thm 3.3 regime)"),
        ("fig6-ofl", "OCC OFL, separable data"),
    ];

    for (exp, desc) in experiments {
        println!("\n=== {exp}: {desc} — E[M_N − k_N] over {reps} reps ===");
        let mut table = Table::new(&["N", "Pb=16", "Pb=32", "Pb=64", "Pb=128", "Pb=256"]);
        let mut worst_ratio = 0.0f64; // max over cells of rejections / Pb
        for &n in &ns {
            let mut cells = vec![n.to_string()];
            for &pb in &pbs {
                let mut rej = 0.0f64;
                for rep in 0..reps {
                    let seed = (rep as u64) * 7919 + n as u64 * 13 + pb as u64;
                    let gen = GenConfig { n, dim: 16, theta: 1.0, seed };
                    let r = match *exp {
                        "fig3a" => sim::sim_dpmeans(&dp_clusters(&gen), 1.0, pb),
                        "fig3b" => sim::sim_ofl(&dp_clusters(&gen), 1.0, pb, seed ^ 0xF1),
                        "fig3c" => sim::sim_bpmeans(&bp_features(&gen), 1.0, pb),
                        "fig6" => sim::sim_dpmeans(&separable_clusters(&gen), 1.0, pb),
                        "fig6-ofl" => sim::sim_ofl(&separable_clusters(&gen), 1.0, pb, seed ^ 0xF1),
                        _ => unreachable!(),
                    };
                    rej += r.rejections() as f64;
                }
                let mean = rej / reps as f64;
                worst_ratio = worst_ratio.max(mean / pb as f64);
                cells.push(format!("{mean:.1}"));
            }
            table.row(cells);
        }
        table.print();
        println!("max E[M_N − k_N] / Pb across the sweep: {worst_ratio:.3} (paper: ≤ 1, flat in N)");
        let csv = format!("target/bench-results/{exp}.csv");
        if table.write_csv(std::path::Path::new(&csv)).is_ok() {
            println!("csv: {csv}");
        }
    }

    // Thm 3.3 strict check on the separable regime.
    println!("\n=== Thm 3.3 strict bound check (separable, sure bound) ===");
    let mut violations = 0usize;
    let mut checks = 0usize;
    for rep in 0..reps.min(50) {
        for &pb in &pbs {
            let n = 1024;
            let gen = GenConfig { n, dim: 16, theta: 1.0, seed: rep as u64 * 31 + pb as u64 };
            let data = separable_clusters(&gen);
            let k_latent = data.distinct_components(n).unwrap();
            let r = sim::sim_dpmeans(&data, 1.0, pb);
            checks += 1;
            if r.master_points > pb + k_latent {
                violations += 1;
            }
        }
    }
    println!("master_points ≤ Pb + K_N in {}/{checks} runs", checks - violations);
    assert_eq!(violations, 0, "Thm 3.3 bound violated on separable data");
}
