//! Typed configuration for `occml` runs.
//!
//! Configs are loaded from a TOML-subset file (see [`toml`]) and/or set by
//! CLI flags; [`RunConfig::from_doc`] performs the typed extraction with
//! validation, and `occd` merges flag overrides on top.

pub mod toml;

use crate::error::{Error, Result};
use std::path::PathBuf;

/// Which algorithm a run executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// DP-means clustering (Alg 1 / Alg 3).
    DpMeans,
    /// Online facility location (Alg 4, Meyerson).
    Ofl,
    /// BP-means latent features (Alg 7 / Alg 6).
    BpMeans,
}

impl Algo {
    /// Parse an algorithm name.
    pub fn parse(s: &str) -> Result<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "dpmeans" | "dp-means" | "dp" => Ok(Algo::DpMeans),
            "ofl" | "facility" => Ok(Algo::Ofl),
            "bpmeans" | "bp-means" | "bp" => Ok(Algo::BpMeans),
            other => Err(Error::config(format!("unknown algo `{other}` (dpmeans|ofl|bpmeans)"))),
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Algo::DpMeans => "dpmeans",
            Algo::Ofl => "ofl",
            Algo::BpMeans => "bpmeans",
        }
    }
}

/// Which numeric backend executes the per-epoch hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust blocked kernels.
    Native,
    /// AOT-compiled XLA artifacts via PJRT.
    Xla,
}

impl BackendKind {
    /// Parse a backend name.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Ok(BackendKind::Native),
            "xla" | "pjrt" => Ok(BackendKind::Xla),
            other => Err(Error::config(format!("unknown backend `{other}` (native|xla)"))),
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Which epoch-scheduling policy the coordinator uses.
///
/// Both policies produce bit-identical models (the pipelined scheduler
/// preserves the Theorem 3.1 serial order exactly — see
/// [`crate::coordinator::scheduler`]); they differ only in how much of the
/// master's validation work overlaps worker compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Bulk-synchronous (the paper's Fig 5 structure): workers idle while
    /// the master validates, and vice versa.
    Bsp,
    /// Software-pipelined: epoch `t+1`'s worker compute overlaps epoch `t`'s
    /// master-side validation, with a bounded two-deep pipeline.
    Pipelined,
}

impl SchedulerKind {
    /// Parse a scheduler name.
    pub fn parse(s: &str) -> Result<SchedulerKind> {
        match s.to_ascii_lowercase().as_str() {
            "bsp" | "barrier" => Ok(SchedulerKind::Bsp),
            "pipelined" | "pipeline" | "wave" | "speculative" => Ok(SchedulerKind::Pipelined),
            other => {
                Err(Error::config(format!("unknown scheduler `{other}` (bsp|pipelined)")))
            }
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Bsp => "bsp",
            SchedulerKind::Pipelined => "pipelined",
        }
    }
}

/// How the wave engine packs an epoch's points into per-worker job ranges
/// (and how the validation plane groups conflict keys into shards).
///
/// Both modes produce bit-identical models — packing only decides *which*
/// worker computes each point's kernel, never the kernel's output, and
/// validation replays point-index order either way. They differ in how the
/// engine reacts to unpatchable conflicts: see
/// [`crate::coordinator::scheduler`] for the respin-policy contrast.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardingKind {
    /// Blind near-equal contiguous split (the PR 5 behavior): worker `p`
    /// gets the `p`-th slice of the epoch span regardless of what state
    /// rows its points read.
    Hash,
    /// Conflict-aware: union-find over the per-point conflict keys groups
    /// the epoch into connected components, and whole components are
    /// packed onto workers (CYCLADES-style), so concurrent jobs rarely
    /// read the same state rows. Validator shard lists become
    /// component-aligned too.
    Conflict,
}

impl ShardingKind {
    /// Parse a sharding-mode name.
    pub fn parse(s: &str) -> Result<ShardingKind> {
        match s.to_ascii_lowercase().as_str() {
            "hash" | "blind" => Ok(ShardingKind::Hash),
            "conflict" | "component" | "components" => Ok(ShardingKind::Conflict),
            other => {
                Err(Error::config(format!("unknown sharding `{other}` (hash|conflict)")))
            }
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            ShardingKind::Hash => "hash",
            ShardingKind::Conflict => "conflict",
        }
    }
}

/// Resolved wave-engine speculation policy: either the classic fixed
/// depth-`K` knob, or the EWMA-adaptive controller selected by
/// `speculation = "auto"`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpeculationSpec {
    /// Pin the in-flight depth to exactly `K` epochs.
    Fixed(usize),
    /// Drive the depth per epoch from an EWMA of observed conflict rates,
    /// within the `[1, max]` band: deep while acceptances hold, shallow
    /// when conflicts spike.
    Auto {
        /// Upper bound of the adaptive band (`speculation_max`).
        max: usize,
    },
}

/// Which transport moves jobs, replies and snapshots between the master
/// and its peers (compute workers and validator shards).
///
/// Both transports produce bit-identical models
/// (`rust/tests/transport_equivalence.rs`); they differ only in whether the
/// cluster's message boundary is crossed by pointer (`Arc`) or by bytes
/// (the `coordinator::wire` format over loopback sockets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process peers: `mpsc` channels and `Arc`-shared snapshots — the
    /// zero-copy fast path.
    InProc,
    /// Localhost TCP peers: every job, reply and snapshot is serialized
    /// through the length-prefixed wire format — the single-host stand-in
    /// for a real multi-machine cluster.
    Tcp,
}

impl TransportKind {
    /// Parse a transport name.
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" | "in-proc" | "threads" | "local" => Ok(TransportKind::InProc),
            "tcp" | "socket" | "loopback" => Ok(TransportKind::Tcp),
            other => {
                Err(Error::config(format!("unknown transport `{other}` (inproc|tcp)")))
            }
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
    /// Default transport: the `OCCML_TRANSPORT` environment override if
    /// set (the CI loopback job exports `OCCML_TRANSPORT=tcp` to run the
    /// whole tier-1 suite over sockets), in-proc otherwise.
    ///
    /// An *invalid* value panics rather than falling back: the env var
    /// exists precisely to force a transport under test, and silently
    /// running in-proc would keep a CI job green while testing nothing.
    pub fn from_env() -> TransportKind {
        match std::env::var("OCCML_TRANSPORT") {
            Ok(s) => TransportKind::parse(&s)
                .unwrap_or_else(|e| panic!("OCCML_TRANSPORT: {e}")),
            Err(std::env::VarError::NotUnicode(v)) => {
                panic!("OCCML_TRANSPORT is set but not valid unicode: {v:?}")
            }
            Err(std::env::VarError::NotPresent) => TransportKind::InProc,
        }
    }
}

/// How the coordinator's event loop blocks while it waits for the
/// cluster: the readiness reactor (the default) or the legacy
/// sleep-slice poller, kept as the A/B baseline.
///
/// Both modes produce bit-identical models
/// (`rust/tests/transport_equivalence.rs`); the knob changes *when the
/// process sleeps*, never what bytes move or in what order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    /// Block on OS readiness (`coordinator::reactor`: epoll on Linux,
    /// poll(2) elsewhere) — peer sockets, listeners and the validation
    /// thread's commit wakeup all land in one wait, so the event loop
    /// wakes exactly when there is work.
    Reactor,
    /// Legacy polling: 100–200 µs sleep slices between readiness
    /// checks. Latency is quantized by the sleep; retained so benches
    /// and CI can measure what the reactor buys.
    Poll,
}

impl IoKind {
    /// Parse an io-mode name.
    pub fn parse(s: &str) -> Result<IoKind> {
        match s.to_ascii_lowercase().as_str() {
            "reactor" | "epoll" | "readiness" => Ok(IoKind::Reactor),
            "poll" | "sleep" | "legacy" => Ok(IoKind::Poll),
            other => Err(Error::config(format!("unknown io `{other}` (reactor|poll)"))),
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            IoKind::Reactor => "reactor",
            IoKind::Poll => "poll",
        }
    }
    /// Default io mode: the `OCCML_IO` environment override if set (CI
    /// uses it to sweep the poll baseline across the whole suite),
    /// reactor otherwise.
    ///
    /// Like `OCCML_TRANSPORT`, an *invalid* value panics rather than
    /// falling back: the env var exists to force a mode under test.
    pub fn from_env() -> IoKind {
        match std::env::var("OCCML_IO") {
            Ok(s) => IoKind::parse(&s).unwrap_or_else(|e| panic!("OCCML_IO: {e}")),
            Err(std::env::VarError::NotUnicode(v)) => {
                panic!("OCCML_IO is set but not valid unicode: {v:?}")
            }
            Err(std::env::VarError::NotPresent) => IoKind::Reactor,
        }
    }
}

/// Which assignment-distance kernel the native backend runs: the
/// cache-tiled panel kernel (the default) or the same-schedule scalar
/// reference, kept as the A/B baseline.
///
/// Both kernels evaluate the canonical reduction schedule
/// (`linalg::sqdist_norms` over the 8-lane `dot`) pair by pair, so they
/// are bit-identical by construction (`linalg::panel` property-tests
/// this); the knob changes *memory traversal*, never arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Tile point panels against L1-resident center tiles
    /// (`linalg::panel::nearest_panel`) with cached point/center norms —
    /// each center tile is loaded once per panel instead of once per
    /// point.
    Panel,
    /// Flat point-major reference loop (`linalg::panel::nearest_scalar`):
    /// identical per-pair arithmetic, re-streams all `k×d` center bytes
    /// per point. Retained so benches and CI can measure what the tiling
    /// buys.
    Scalar,
}

impl KernelKind {
    /// Parse a kernel name.
    pub fn parse(s: &str) -> Result<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "panel" | "tiled" | "blocked" => Ok(KernelKind::Panel),
            "scalar" | "reference" => Ok(KernelKind::Scalar),
            other => Err(Error::config(format!("unknown kernel `{other}` (panel|scalar)"))),
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Panel => "panel",
            KernelKind::Scalar => "scalar",
        }
    }
    /// Default kernel: the `OCCML_KERNEL` environment override if set (CI
    /// uses it to sweep the scalar reference across the whole suite),
    /// panel otherwise.
    ///
    /// Like `OCCML_IO`, an *invalid* value panics rather than falling
    /// back: the env var exists to force a kernel under test.
    pub fn from_env() -> KernelKind {
        match std::env::var("OCCML_KERNEL") {
            Ok(s) => KernelKind::parse(&s).unwrap_or_else(|e| panic!("OCCML_KERNEL: {e}")),
            Err(std::env::VarError::NotUnicode(v)) => {
                panic!("OCCML_KERNEL is set but not valid unicode: {v:?}")
            }
            Err(std::env::VarError::NotPresent) => KernelKind::Panel,
        }
    }
}

/// Which peer-side dataset store a worker session assembles its shipped
/// blocks into: the offset-keyed sparse block store (the default — a
/// peer's resident footprint is O(covered rows)) or the dense `n × d`
/// matrix, kept as the A/B baseline.
///
/// Bit-identical either way: block boundaries are panel boundaries
/// (`data::store::BLOCK_POINTS == linalg::panel::PANEL_POINTS`), so the
/// knob changes allocation shape and memory traversal, never arithmetic
/// or compare order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    /// Offset-keyed 64-row blocks (`data::store::BlockStore`), allocated
    /// only where shipped spans landed — resident bytes are O(covered
    /// rows), the unlock for datasets that only fit sharded.
    Sparse,
    /// One dense `n × d` matrix allocated on the first shipped block and
    /// filled sparsely. Retained so benches and CI can measure what the
    /// block store saves (`resident_data_bytes`).
    Dense,
}

impl StoreKind {
    /// Parse a store name.
    pub fn parse(s: &str) -> Result<StoreKind> {
        match s.to_ascii_lowercase().as_str() {
            "sparse" | "block" | "blocks" => Ok(StoreKind::Sparse),
            "dense" | "full" => Ok(StoreKind::Dense),
            other => Err(Error::config(format!("unknown store `{other}` (sparse|dense)"))),
        }
    }
    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            StoreKind::Sparse => "sparse",
            StoreKind::Dense => "dense",
        }
    }
    /// Default store: the `OCCML_STORE` environment override if set (CI
    /// uses it to sweep the dense baseline across the whole suite),
    /// sparse otherwise.
    ///
    /// Like `OCCML_KERNEL`, an *invalid* value panics rather than falling
    /// back: the env var exists to force a store under test.
    pub fn from_env() -> StoreKind {
        match std::env::var("OCCML_STORE") {
            Ok(s) => StoreKind::parse(&s).unwrap_or_else(|e| panic!("OCCML_STORE: {e}")),
            Err(std::env::VarError::NotUnicode(v)) => {
                panic!("OCCML_STORE is set but not valid unicode: {v:?}")
            }
            Err(std::env::VarError::NotPresent) => StoreKind::Sparse,
        }
    }
}

/// Data source for a run.
#[derive(Debug, Clone, PartialEq)]
pub enum DataSource {
    /// Synthetic DP-mixture clusters (§4 "Clustering").
    DpClusters,
    /// Synthetic BP latent features (§4 "Feature modeling").
    BpFeatures,
    /// Separable clusters (App C.1).
    Separable,
    /// Load from an `.occb` file.
    File(PathBuf),
}

impl DataSource {
    /// Parse a source spec: generator name or `file:<path>`.
    pub fn parse(s: &str) -> Result<DataSource> {
        if let Some(path) = s.strip_prefix("file:") {
            return Ok(DataSource::File(PathBuf::from(path)));
        }
        match s.to_ascii_lowercase().as_str() {
            "dp" | "dp-clusters" | "clusters" => Ok(DataSource::DpClusters),
            "bp" | "bp-features" | "features" => Ok(DataSource::BpFeatures),
            "separable" => Ok(DataSource::Separable),
            other => Err(Error::config(format!(
                "unknown data source `{other}` (dp|bp|separable|file:<path>)"
            ))),
        }
    }
}

/// Full configuration for one `occd run`.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Algorithm to run.
    pub algo: Algo,
    /// Distance threshold λ (paper: 1 or 2 depending on experiment).
    pub lambda: f64,
    /// Number of worker "processors" P.
    pub procs: usize,
    /// Points per processor per epoch, `b`.
    pub block: usize,
    /// Passes over the data (DP/BP; OFL is single-pass).
    pub iterations: usize,
    /// Bootstrap: pre-process `first Pb / bootstrap_div` points serially
    /// before epoch 1 (§4.2 uses 16). `0` disables bootstrapping.
    pub bootstrap_div: usize,
    /// Numeric backend for the hot path.
    pub backend: BackendKind,
    /// Epoch scheduling policy (BSP barrier vs the speculative wave
    /// engine).
    pub scheduler: SchedulerKind,
    /// Speculation depth `K` for the wave engine: how many epochs may be
    /// resident in the pipeline at once under `scheduler = "pipelined"`.
    /// `1` reproduces BSP, `2` (the default) is the classic two-stage
    /// pipeline, higher depths hide longer validation tails. Models are
    /// bit-identical at every depth (`scheduler = "bsp"` ignores this and
    /// pins depth 1).
    pub speculation: usize,
    /// `true` when `speculation = "auto"` was given: the wave engine drives
    /// the in-flight depth per epoch from an EWMA of observed conflict
    /// rates inside `[1, speculation_max]` instead of pinning it to
    /// [`RunConfig::speculation`]. See [`RunConfig::speculation_spec`].
    pub speculation_auto: bool,
    /// Upper bound of the adaptive band under `speculation = "auto"`
    /// (ignored by the fixed integer knob).
    pub speculation_max: usize,
    /// How epochs are packed into per-worker job ranges: blind contiguous
    /// `hash` splits, or CYCLADES-style `conflict` components (union-find
    /// over the per-point conflict keys). Bit-identical either way.
    pub sharding: ShardingKind,
    /// Cluster transport (in-process channels vs loopback TCP sockets).
    pub transport: TransportKind,
    /// Event-loop blocking mode: readiness reactor (default) vs the
    /// legacy sleep-slice poller. Bit-identical either way; only the
    /// waits change.
    pub io: IoKind,
    /// Assignment-distance kernel: cache-tiled panel (default) vs the
    /// same-schedule scalar reference. Bit-identical either way; only
    /// the memory traversal changes.
    pub kernel: KernelKind,
    /// Peer-side dataset store: offset-keyed sparse blocks (default) vs
    /// the dense `n × d` matrix baseline. Bit-identical either way; only
    /// the resident footprint changes (`resident_data_bytes`).
    pub store: StoreKind,
    /// Validator-shard peers on the validation plane. `0` (the default)
    /// means "half of `procs`, min 1" — see
    /// [`RunConfig::effective_validators`].
    pub validator_shards: usize,
    /// Remote compute-peer addresses (`host:port` of running `occd worker`
    /// processes). Non-empty lists require `transport = "tcp"` and define
    /// the compute-plane size ([`RunConfig::normalize`] sets `procs` from
    /// the list); empty (the default) spawns loopback peers in-process.
    pub peers: Vec<String>,
    /// Remote validator-peer addresses; same contract as `peers`, for the
    /// validation plane (`validator_shards` is set from the list).
    pub validator_peers: Vec<String>,
    /// Bounded reconnect budget when a remote peer drops mid-run: how many
    /// reconnect attempts (250 ms apart) the coordinator makes before the
    /// wave surfaces a typed error. `0` fails fast on the first drop.
    pub reconnect_attempts: usize,
    /// Wire-frugal TCP shipping (the default): snapshots travel as
    /// versioned delta frames against each peer session's cache, and
    /// validator peers receive only the proposal rows their conflict-key
    /// range reads. `false` restores the embed-everything wire shape —
    /// kept as the A/B baseline for `benches/schedulers.rs`. Either way
    /// the model is bit-identical; only the bytes on the wire change.
    pub frugal_wire: bool,
    /// Directory holding AOT artifacts (XLA backend).
    pub artifacts_dir: PathBuf,
    /// RNG seed.
    pub seed: u64,
    /// Data source.
    pub source: DataSource,
    /// Number of points (generators only).
    pub n: usize,
    /// Dimensionality (generators only).
    pub dim: usize,
    /// Stick-breaking concentration θ.
    pub theta: f64,
    /// Where to write JSONL metrics (stdout if `None`).
    pub metrics_path: Option<PathBuf>,
    /// Streaming ingest (`occd serve`): points per mini-epoch before the
    /// admission stage seals a batch. `0` (the default) means "one epoch's
    /// worth" — `P·b`, so a saturated firehose reproduces the static epoch
    /// geometry exactly. See [`RunConfig::effective_batch_points`].
    pub batch_points: usize,
    /// Streaming ingest: latency SLA in milliseconds — a non-full pending
    /// batch is sealed once its oldest point has waited this long, so a
    /// trickling client still sees bounded admission→commit latency.
    pub batch_latency_ms: u64,
    /// Streaming ingest: bound on sealed-but-unconsumed mini-epochs. When
    /// the admission queue is this deep, further ingest chunks are refused
    /// with a typed `Throttled` ack until the wave engine catches up.
    pub ingest_queue: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algo: Algo::DpMeans,
            lambda: 1.0,
            procs: 4,
            block: 256,
            iterations: 3,
            bootstrap_div: 16,
            backend: BackendKind::Native,
            scheduler: SchedulerKind::Bsp,
            speculation: 2,
            speculation_auto: false,
            speculation_max: 8,
            sharding: ShardingKind::Hash,
            transport: TransportKind::from_env(),
            io: IoKind::from_env(),
            kernel: KernelKind::from_env(),
            store: StoreKind::from_env(),
            validator_shards: 0,
            peers: Vec::new(),
            validator_peers: Vec::new(),
            reconnect_attempts: 3,
            frugal_wire: true,
            artifacts_dir: PathBuf::from("artifacts"),
            seed: 0,
            source: DataSource::DpClusters,
            n: 16_384,
            dim: 16,
            theta: 1.0,
            metrics_path: None,
            batch_points: env_usize("OCCML_BATCH_POINTS", 0),
            batch_latency_ms: env_usize("OCCML_BATCH_LATENCY_MS", 50) as u64,
            ingest_queue: env_usize("OCCML_INGEST_QUEUE", 64),
        }
    }
}

/// Environment-overridable numeric default (the `OCCML_TRANSPORT` pattern
/// for the streaming knobs: CI sweeps them without touching configs). An
/// invalid value panics rather than falling back — the var exists to force
/// a setting under test.
fn env_usize(name: &str, default: usize) -> usize {
    match std::env::var(name) {
        Ok(s) => s
            .trim()
            .parse::<usize>()
            .unwrap_or_else(|_| panic!("{name}: cannot parse `{s}` as an integer")),
        Err(std::env::VarError::NotUnicode(v)) => {
            panic!("{name} is set but not valid unicode: {v:?}")
        }
        Err(std::env::VarError::NotPresent) => default,
    }
}

impl RunConfig {
    /// Extract a run config from a parsed document (keys under `[run]` and
    /// `[data]`), starting from defaults.
    pub fn from_doc(doc: &toml::Document) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(s) = doc.get_str("run.algo") {
            cfg.algo = Algo::parse(s)?;
        }
        if let Some(v) = doc.get_float("run.lambda") {
            cfg.lambda = v;
        }
        if let Some(v) = doc.get_int("run.procs") {
            cfg.procs = usize::try_from(v).map_err(|_| Error::config("run.procs must be ≥ 0"))?;
        }
        if let Some(v) = doc.get_int("run.block") {
            cfg.block = usize::try_from(v).map_err(|_| Error::config("run.block must be ≥ 0"))?;
        }
        if let Some(v) = doc.get_int("run.iterations") {
            cfg.iterations = v.max(1) as usize;
        }
        if let Some(v) = doc.get_int("run.bootstrap_div") {
            cfg.bootstrap_div = v.max(0) as usize;
        }
        if let Some(s) = doc.get_str("run.backend") {
            cfg.backend = BackendKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("run.scheduler") {
            cfg.scheduler = SchedulerKind::parse(s)?;
        }
        match doc.get("run.speculation") {
            None => {}
            Some(toml::Value::Int(v)) => {
                cfg.speculation = usize::try_from(*v)
                    .map_err(|_| Error::config("run.speculation must be ≥ 1"))?;
                cfg.speculation_auto = false;
            }
            Some(toml::Value::Str(s)) if s.eq_ignore_ascii_case("auto") => {
                cfg.speculation_auto = true;
            }
            Some(other) => {
                return Err(Error::config(format!(
                    "run.speculation must be an integer depth or \"auto\", got {other:?}"
                )))
            }
        }
        if let Some(v) = doc.get_int("run.speculation_max") {
            cfg.speculation_max = usize::try_from(v)
                .map_err(|_| Error::config("run.speculation_max must be ≥ 1"))?;
        }
        if let Some(s) = doc.get_str("run.sharding") {
            cfg.sharding = ShardingKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("run.transport") {
            cfg.transport = TransportKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("run.io") {
            cfg.io = IoKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("run.kernel") {
            cfg.kernel = KernelKind::parse(s)?;
        }
        if let Some(s) = doc.get_str("run.store") {
            cfg.store = StoreKind::parse(s)?;
        }
        if let Some(v) = doc.get_int("run.validator_shards") {
            cfg.validator_shards = usize::try_from(v)
                .map_err(|_| Error::config("run.validator_shards must be ≥ 0"))?;
        }
        if let Some(v) = doc.get("run.peers") {
            cfg.peers = parse_peer_list("run.peers", v)?;
        }
        if let Some(v) = doc.get("run.validator_peers") {
            cfg.validator_peers = parse_peer_list("run.validator_peers", v)?;
        }
        if let Some(v) = doc.get_int("run.reconnect_attempts") {
            cfg.reconnect_attempts = usize::try_from(v)
                .map_err(|_| Error::config("run.reconnect_attempts must be ≥ 0"))?;
        }
        if let Some(v) = doc.get_bool("run.frugal_wire") {
            cfg.frugal_wire = v;
        }
        if let Some(s) = doc.get_str("run.artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(s);
        }
        if let Some(v) = doc.get_int("run.seed") {
            cfg.seed = v as u64;
        }
        if let Some(s) = doc.get_str("run.metrics") {
            cfg.metrics_path = Some(PathBuf::from(s));
        }
        if let Some(v) = doc.get_int("run.batch_points") {
            cfg.batch_points = usize::try_from(v)
                .map_err(|_| Error::config("run.batch_points must be ≥ 0"))?;
        }
        if let Some(v) = doc.get_int("run.batch_latency_ms") {
            cfg.batch_latency_ms = u64::try_from(v)
                .map_err(|_| Error::config("run.batch_latency_ms must be ≥ 0"))?;
        }
        if let Some(v) = doc.get_int("run.ingest_queue") {
            cfg.ingest_queue = usize::try_from(v)
                .map_err(|_| Error::config("run.ingest_queue must be ≥ 1"))?;
        }
        if let Some(s) = doc.get_str("data.source") {
            cfg.source = DataSource::parse(s)?;
        }
        if let Some(v) = doc.get_int("data.n") {
            cfg.n = v.max(1) as usize;
        }
        if let Some(v) = doc.get_int("data.dim") {
            cfg.dim = v.max(1) as usize;
        }
        if let Some(v) = doc.get_float("data.theta") {
            cfg.theta = v;
        }
        cfg.normalize();
        cfg.validate()?;
        Ok(cfg)
    }

    /// Derive plane sizes from peer address lists: a non-empty `peers`
    /// list *is* the compute plane, so `procs` follows it (and likewise
    /// `validator_shards` from `validator_peers`). Called by the TOML and
    /// CLI loaders before [`RunConfig::validate`]; embedders constructing
    /// a `RunConfig` by hand should call it too, or keep the counts
    /// consistent themselves — `validate` rejects a mismatch.
    pub fn normalize(&mut self) {
        if !self.peers.is_empty() {
            self.procs = self.peers.len();
        }
        if !self.validator_peers.is_empty() {
            self.validator_shards = self.validator_peers.len();
        }
    }

    /// Validate invariants that would otherwise surface as panics deep in a run.
    pub fn validate(&self) -> Result<()> {
        if self.lambda <= 0.0 {
            return Err(Error::config(format!("lambda must be > 0, got {}", self.lambda)));
        }
        if self.procs == 0 {
            return Err(Error::config("procs must be ≥ 1"));
        }
        if self.block == 0 {
            return Err(Error::config("block must be ≥ 1"));
        }
        if self.dim == 0 || self.dim > 4096 {
            return Err(Error::config(format!("dim out of range: {}", self.dim)));
        }
        if self.validator_shards > 1024 {
            return Err(Error::config(format!(
                "validator_shards out of range (≤ 1024): {}",
                self.validator_shards
            )));
        }
        if self.speculation == 0 || self.speculation > 64 {
            return Err(Error::config(format!(
                "speculation out of range (1 ..= 64): {}",
                self.speculation
            )));
        }
        if self.speculation_max == 0 || self.speculation_max > 64 {
            return Err(Error::config(format!(
                "speculation_max out of range (1 ..= 64): {}",
                self.speculation_max
            )));
        }
        for addr in self.peers.iter().chain(&self.validator_peers) {
            let valid = match addr.rsplit_once(':') {
                Some((host, port)) => !host.is_empty() && port.parse::<u16>().is_ok(),
                None => false,
            };
            if !valid {
                return Err(Error::config(format!(
                    "peer address `{addr}` is not host:port"
                )));
            }
        }
        if (!self.peers.is_empty() || !self.validator_peers.is_empty())
            && self.transport != TransportKind::Tcp
        {
            return Err(Error::config(
                "peers / validator_peers require transport = \"tcp\"",
            ));
        }
        if !self.peers.is_empty() && self.procs != self.peers.len() {
            return Err(Error::config(format!(
                "procs = {} but peers lists {} addresses — the peer list defines the \
                 compute plane (call RunConfig::normalize or drop procs)",
                self.procs,
                self.peers.len()
            )));
        }
        if !self.validator_peers.is_empty()
            && self.validator_shards != self.validator_peers.len()
        {
            return Err(Error::config(format!(
                "validator_shards = {} but validator_peers lists {} addresses",
                self.validator_shards,
                self.validator_peers.len()
            )));
        }
        if self.reconnect_attempts > 10_000 {
            return Err(Error::config(format!(
                "reconnect_attempts out of range (≤ 10000): {}",
                self.reconnect_attempts
            )));
        }
        if self.ingest_queue == 0 || self.ingest_queue > 1 << 20 {
            return Err(Error::config(format!(
                "ingest_queue out of range (1 ..= 2^20): {}",
                self.ingest_queue
            )));
        }
        if self.batch_latency_ms > 600_000 {
            return Err(Error::config(format!(
                "batch_latency_ms out of range (≤ 600000): {}",
                self.batch_latency_ms
            )));
        }
        Ok(())
    }

    /// Points per epoch, `P·b`.
    pub fn points_per_epoch(&self) -> usize {
        self.procs * self.block
    }

    /// Mini-epoch size the admission stage seals at: the explicit
    /// `batch_points` knob, or one static epoch's worth (`P·b`) when it is
    /// left at `0`.
    pub fn effective_batch_points(&self) -> usize {
        if self.batch_points == 0 {
            self.points_per_epoch()
        } else {
            self.batch_points
        }
    }

    /// The admission latency SLA as a [`std::time::Duration`].
    pub fn batch_latency(&self) -> std::time::Duration {
        std::time::Duration::from_millis(self.batch_latency_ms)
    }

    /// Resolved speculation policy: [`SpeculationSpec::Auto`] when
    /// `speculation = "auto"` was given (band `[1, speculation_max]`),
    /// the fixed integer depth otherwise.
    pub fn speculation_spec(&self) -> SpeculationSpec {
        if self.speculation_auto {
            SpeculationSpec::Auto { max: self.speculation_max }
        } else {
            SpeculationSpec::Fixed(self.speculation)
        }
    }

    /// Validator peers on the validation plane. `0` ⇒ half the workers
    /// (min 1): under the pipelined scheduler validation overlaps the next
    /// wave's compute on all `P` workers, so a full-`P` validation plane
    /// would oversubscribe the machine during exactly the window the
    /// overlap exists to exploit (the PR 1 thread-cap rationale, applied
    /// to peers). Set `validator_shards` explicitly to override.
    pub fn effective_validators(&self) -> usize {
        if self.validator_shards == 0 {
            (self.procs / 2).max(1)
        } else {
            self.validator_shards
        }
    }
}

/// Split a comma-separated `host:port` list, trimming whitespace and
/// dropping empty entries — the one splitting/cleaning rule shared by the
/// CLI `--peers` flags and both TOML forms.
pub fn split_peer_list(s: &str) -> Vec<String> {
    s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect()
}

/// Extract a peer address list from a TOML value: an array of strings
/// (`peers = ["h:1", "h:2"]`) or one comma-separated string (`peers =
/// "h:1,h:2"`, the CLI-parity form). Entries are trimmed in both forms.
fn parse_peer_list(key: &str, v: &toml::Value) -> Result<Vec<String>> {
    match v {
        toml::Value::Array(items) => items
            .iter()
            .map(|item| {
                item.as_str()
                    .map(|s| s.trim().to_string())
                    .ok_or_else(|| Error::config(format!("{key} entries must be strings")))
            })
            .collect(),
        toml::Value::Str(s) => Ok(split_peer_list(s)),
        _ => Err(Error::config(format!(
            "{key} must be an array of \"host:port\" strings"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_enums() {
        assert_eq!(Algo::parse("DP-Means").unwrap(), Algo::DpMeans);
        assert_eq!(Algo::parse("ofl").unwrap(), Algo::Ofl);
        assert_eq!(Algo::parse("bp").unwrap(), Algo::BpMeans);
        assert!(Algo::parse("kmeans").is_err());
        assert_eq!(BackendKind::parse("XLA").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("gpu").is_err());
        assert_eq!(SchedulerKind::parse("BSP").unwrap(), SchedulerKind::Bsp);
        assert_eq!(SchedulerKind::parse("pipelined").unwrap(), SchedulerKind::Pipelined);
        assert_eq!(SchedulerKind::parse("speculative").unwrap(), SchedulerKind::Pipelined);
        assert_eq!(ShardingKind::parse("Hash").unwrap(), ShardingKind::Hash);
        assert_eq!(ShardingKind::parse("conflict").unwrap(), ShardingKind::Conflict);
        assert_eq!(ShardingKind::parse("components").unwrap(), ShardingKind::Conflict);
        assert!(ShardingKind::parse("random").is_err());
        assert_eq!(
            DataSource::parse("file:/tmp/a.occb").unwrap(),
            DataSource::File(PathBuf::from("/tmp/a.occb"))
        );
    }

    #[test]
    fn from_doc_extracts_and_validates() {
        let doc = toml::parse(
            r#"
            [run]
            algo = "ofl"
            lambda = 2.0
            procs = 8
            block = 512
            backend = "native"
            seed = 9

            [data]
            source = "separable"
            n = 4096
            dim = 16
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.algo, Algo::Ofl);
        assert_eq!(cfg.lambda, 2.0);
        assert_eq!(cfg.procs, 8);
        assert_eq!(cfg.block, 512);
        assert_eq!(cfg.source, DataSource::Separable);
        assert_eq!(cfg.points_per_epoch(), 8 * 512);
    }

    #[test]
    fn invalid_configs_rejected() {
        let doc = toml::parse("[run]\nlambda = -1.0\n").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
        let doc = toml::parse("[run]\nprocs = 0\n").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
        let doc = toml::parse("[run]\nalgo = \"nope\"\n").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn speculation_knob_extracts_and_validates() {
        assert_eq!(RunConfig::default().speculation, 2, "default = classic two-stage pipeline");
        let doc = toml::parse(
            "[run]\nscheduler = \"pipelined\"\nspeculation = 4\n",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.scheduler, SchedulerKind::Pipelined);
        assert_eq!(cfg.speculation, 4);
        // speculation = 1 is valid (BSP-equivalent) ...
        assert_eq!(
            RunConfig::from_doc(&toml::parse("[run]\nspeculation = 1\n").unwrap())
                .unwrap()
                .speculation,
            1
        );
        // ... zero and absurd depths are not.
        assert!(RunConfig::from_doc(&toml::parse("[run]\nspeculation = 0\n").unwrap()).is_err());
        assert!(
            RunConfig::from_doc(&toml::parse("[run]\nspeculation = 1000\n").unwrap()).is_err()
        );
        // "wave" parses as an alias of the speculative engine.
        assert_eq!(SchedulerKind::parse("wave").unwrap(), SchedulerKind::Pipelined);
    }

    #[test]
    fn sharding_and_adaptive_speculation_knobs_extract() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.sharding, ShardingKind::Hash, "hash split is the default");
        assert!(!cfg.speculation_auto);
        assert_eq!(cfg.speculation_max, 8);
        assert_eq!(cfg.speculation_spec(), SpeculationSpec::Fixed(2));

        let doc = toml::parse(
            "[run]\nscheduler = \"pipelined\"\nsharding = \"conflict\"\n\
             speculation = \"auto\"\nspeculation_max = 6\n",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sharding, ShardingKind::Conflict);
        assert!(cfg.speculation_auto);
        assert_eq!(cfg.speculation_spec(), SpeculationSpec::Auto { max: 6 });

        // An integer depth still parses and pins the fixed policy; case
        // does not matter for "auto".
        let cfg = RunConfig::from_doc(&toml::parse("[run]\nspeculation = 4\n").unwrap()).unwrap();
        assert!(!cfg.speculation_auto);
        assert_eq!(cfg.speculation_spec(), SpeculationSpec::Fixed(4));
        assert!(RunConfig::from_doc(&toml::parse("[run]\nspeculation = \"AUTO\"\n").unwrap())
            .unwrap()
            .speculation_auto);

        // Junk speculation values are typed errors naming the accepted forms.
        let err = RunConfig::from_doc(&toml::parse("[run]\nspeculation = \"fast\"\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("speculation") && err.contains("auto"), "{err}");
        let err = RunConfig::from_doc(&toml::parse("[run]\nspeculation = true\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("speculation"), "{err}");

        // Unknown sharding names the value and the choices.
        let err = RunConfig::from_doc(&toml::parse("[run]\nsharding = \"random\"\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("random") && err.contains("hash") && err.contains("conflict"), "{err}");

        // speculation_max shares the 1 ..= 64 band.
        let doc = toml::parse("[run]\nspeculation_max = 0\n").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
        let doc = toml::parse("[run]\nspeculation_max = 1000\n").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn transport_parses_and_rejects() {
        assert_eq!(TransportKind::parse("inproc").unwrap(), TransportKind::InProc);
        assert_eq!(TransportKind::parse("TCP").unwrap(), TransportKind::Tcp);
        assert_eq!(TransportKind::parse("loopback").unwrap(), TransportKind::Tcp);
        let err = TransportKind::parse("infiniband").unwrap_err().to_string();
        assert!(err.contains("infiniband") && err.contains("inproc") && err.contains("tcp"));
    }

    #[test]
    fn io_mode_parses_rejects_and_extracts() {
        assert_eq!(IoKind::parse("reactor").unwrap(), IoKind::Reactor);
        assert_eq!(IoKind::parse("EPOLL").unwrap(), IoKind::Reactor);
        assert_eq!(IoKind::parse("poll").unwrap(), IoKind::Poll);
        assert_eq!(IoKind::parse("legacy").unwrap(), IoKind::Poll);
        let err = IoKind::parse("uring").unwrap_err().to_string();
        assert!(err.contains("uring") && err.contains("reactor") && err.contains("poll"));
        assert_eq!(IoKind::Reactor.name(), "reactor");
        assert_eq!(IoKind::Poll.name(), "poll");
        // Extracts from TOML; absent key keeps the default.
        let doc = toml::parse("[run]\nio = \"poll\"\n").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().io, IoKind::Poll);
        let doc = toml::parse("[run]\nprocs = 2\n").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().io, IoKind::from_env());
        assert!(RunConfig::from_doc(&toml::parse("[run]\nio = \"rdma\"\n").unwrap()).is_err());
    }

    #[test]
    fn kernel_knob_parses_rejects_and_extracts() {
        assert_eq!(KernelKind::parse("panel").unwrap(), KernelKind::Panel);
        assert_eq!(KernelKind::parse("TILED").unwrap(), KernelKind::Panel);
        assert_eq!(KernelKind::parse("scalar").unwrap(), KernelKind::Scalar);
        assert_eq!(KernelKind::parse("reference").unwrap(), KernelKind::Scalar);
        let err = KernelKind::parse("gpu").unwrap_err().to_string();
        assert!(err.contains("gpu") && err.contains("panel") && err.contains("scalar"));
        assert_eq!(KernelKind::Panel.name(), "panel");
        assert_eq!(KernelKind::Scalar.name(), "scalar");
        // Extracts from TOML; absent key keeps the default.
        let doc = toml::parse("[run]\nkernel = \"scalar\"\n").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().kernel, KernelKind::Scalar);
        let doc = toml::parse("[run]\nprocs = 2\n").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().kernel, KernelKind::from_env());
        assert!(RunConfig::from_doc(&toml::parse("[run]\nkernel = \"simd\"\n").unwrap()).is_err());
    }

    #[test]
    fn store_knob_parses_rejects_and_extracts() {
        assert_eq!(StoreKind::parse("sparse").unwrap(), StoreKind::Sparse);
        assert_eq!(StoreKind::parse("BLOCKS").unwrap(), StoreKind::Sparse);
        assert_eq!(StoreKind::parse("dense").unwrap(), StoreKind::Dense);
        assert_eq!(StoreKind::parse("full").unwrap(), StoreKind::Dense);
        let err = StoreKind::parse("mmap").unwrap_err().to_string();
        assert!(err.contains("mmap") && err.contains("sparse") && err.contains("dense"));
        assert_eq!(StoreKind::Sparse.name(), "sparse");
        assert_eq!(StoreKind::Dense.name(), "dense");
        // Extracts from TOML; absent key keeps the default.
        let doc = toml::parse("[run]\nstore = \"dense\"\n").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().store, StoreKind::Dense);
        let doc = toml::parse("[run]\nprocs = 2\n").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().store, StoreKind::from_env());
        assert!(RunConfig::from_doc(&toml::parse("[run]\nstore = \"disk\"\n").unwrap()).is_err());
    }

    #[test]
    fn transport_and_shards_extract_from_doc() {
        let doc = toml::parse(
            "[run]\ntransport = \"tcp\"\nvalidator_shards = 3\nprocs = 5\n",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.transport, TransportKind::Tcp);
        assert_eq!(cfg.validator_shards, 3);
        assert_eq!(cfg.effective_validators(), 3);
        let doc = toml::parse("[run]\nprocs = 5\n").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.validator_shards, 0);
        assert_eq!(cfg.effective_validators(), 2, "0 shards means half the workers");
        let doc = toml::parse("[run]\nprocs = 1\n").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().effective_validators(), 1);
        // Wire-frugal shipping defaults on and extracts from TOML.
        assert!(RunConfig::default().frugal_wire);
        let doc = toml::parse("[run]\nfrugal_wire = false\n").unwrap();
        assert!(!RunConfig::from_doc(&doc).unwrap().frugal_wire);
        assert!(RunConfig::from_doc(&toml::parse("[run]\ntransport = \"rdma\"\n").unwrap())
            .is_err());
        assert!(RunConfig::from_doc(
            &toml::parse("[run]\nvalidator_shards = 2000\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn peer_lists_extract_and_derive_plane_sizes() {
        let doc = toml::parse(
            "[run]\ntransport = \"tcp\"\npeers = [\"10.0.0.1:7100\", \"10.0.0.2:7100\"]\n\
             validator_peers = [\"10.0.0.3:7100\"]\nreconnect_attempts = 7\n",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.peers.len(), 2);
        assert_eq!(cfg.procs, 2, "the peer list defines the compute plane");
        assert_eq!(cfg.validator_shards, 1);
        assert_eq!(cfg.effective_validators(), 1);
        assert_eq!(cfg.reconnect_attempts, 7);
        // Comma-separated string form (CLI parity).
        let doc = toml::parse(
            "[run]\ntransport = \"tcp\"\npeers = \"a:1, b:2, c:3\"\n",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.peers, vec!["a:1", "b:2", "c:3"]);
        assert_eq!(cfg.procs, 3);
        // Array entries are trimmed like the other forms.
        let doc = toml::parse(
            "[run]\ntransport = \"tcp\"\npeers = [\" a:1\", \"b:2 \"]\n",
        )
        .unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().peers, vec!["a:1", "b:2"]);
        assert_eq!(split_peer_list(" a:1, ,b:2 ,"), vec!["a:1", "b:2"]);
    }

    #[test]
    fn streaming_knobs_extract_and_validate() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.batch_points, 0, "0 = one epoch's worth");
        assert_eq!(cfg.effective_batch_points(), cfg.points_per_epoch());
        assert_eq!(cfg.batch_latency_ms, 50);
        assert_eq!(cfg.batch_latency(), std::time::Duration::from_millis(50));
        assert_eq!(cfg.ingest_queue, 64);

        let doc = toml::parse(
            "[run]\nbatch_points = 128\nbatch_latency_ms = 5\ningest_queue = 4\n",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.batch_points, 128);
        assert_eq!(cfg.effective_batch_points(), 128);
        assert_eq!(cfg.batch_latency_ms, 5);
        assert_eq!(cfg.ingest_queue, 4);

        // A zero-deep admission queue cannot admit anything.
        assert!(RunConfig::from_doc(&toml::parse("[run]\ningest_queue = 0\n").unwrap()).is_err());
        // Absurd SLA values are configuration mistakes, not policies.
        assert!(RunConfig::from_doc(
            &toml::parse("[run]\nbatch_latency_ms = 700000\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn peer_lists_reject_bad_shapes() {
        // Peers without the TCP transport.
        assert!(RunConfig::from_doc(
            &toml::parse("[run]\ntransport = \"inproc\"\npeers = [\"h:1\"]\n").unwrap()
        )
        .is_err());
        // Not host:port.
        assert!(RunConfig::from_doc(
            &toml::parse("[run]\ntransport = \"tcp\"\npeers = [\"nohost\"]\n").unwrap()
        )
        .is_err());
        assert!(RunConfig::from_doc(
            &toml::parse("[run]\ntransport = \"tcp\"\npeers = [\"h:notaport\"]\n").unwrap()
        )
        .is_err());
        // Non-string array entries.
        assert!(RunConfig::from_doc(
            &toml::parse("[run]\ntransport = \"tcp\"\npeers = [1, 2]\n").unwrap()
        )
        .is_err());
        // Hand-built config with an inconsistent procs is rejected.
        let mut cfg = RunConfig {
            transport: TransportKind::Tcp,
            peers: vec!["h:1".into(), "h:2".into()],
            ..RunConfig::default()
        };
        cfg.procs = 4;
        assert!(cfg.validate().is_err());
        cfg.normalize();
        assert_eq!(cfg.procs, 2);
        cfg.validate().unwrap();
    }
}
