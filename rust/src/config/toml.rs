//! A TOML-subset parser (no external crates are available offline).
//!
//! Supported syntax — everything `occml` config files use:
//!
//! * `[table]` and `[table.subtable]` headers
//! * `key = value` with string (`"…"`), integer, float, boolean and
//!   homogeneous-array (`[1, 2, 3]`) values
//! * `#` comments and blank lines
//!
//! Values are stored flat under dotted keys (`"run.lambda"`), which is all
//! the typed-config layer needs.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// 64-bit integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous or mixed array.
    Array(Vec<Value>),
}

impl Value {
    /// As string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// As integer (floats with zero fraction qualify).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }
    /// As float (integers coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// As boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// As array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: flat map from dotted key to value.
#[derive(Debug, Clone, Default)]
pub struct Document {
    /// Dotted-key → value map.
    pub values: BTreeMap<String, Value>,
}

impl Document {
    /// Fetch a value by dotted key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }
    /// String by key.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }
    /// Integer by key.
    pub fn get_int(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_int)
    }
    /// Float by key.
    pub fn get_float(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_float)
    }
    /// Boolean by key.
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc = Document::default();
    let mut prefix = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated table header"))?
                .trim();
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-') {
                return Err(err(lineno, "invalid table name"));
            }
            prefix = format!("{name}.");
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err(lineno, "expected key = value"))?;
        let key = line[..eq].trim();
        if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
            return Err(err(lineno, &format!("invalid key `{key}`")));
        }
        let vtext = line[eq + 1..].trim();
        let value = parse_value(vtext).map_err(|m| err(lineno, &m))?;
        let full = format!("{prefix}{key}");
        if doc.values.contains_key(&full) {
            return Err(err(lineno, &format!("duplicate key `{full}`")));
        }
        doc.values.insert(full, value);
    }
    Ok(doc)
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside a quoted string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> std::result::Result<Value, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = t.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string (escapes unsupported)".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = t.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    let clean = t.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{t}`"))
}

/// Split on commas that are not inside nested brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_tables_and_arrays() {
        let doc = parse(
            r#"
            # top-level
            name = "dpmeans"
            n = 1_024
            lambda = 1.5
            verbose = true

            [run]
            procs = 8
            buckets = [256, 1024, 4096]
            tags = ["a", "b"]

            [run.inner]
            x = -3
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("dpmeans"));
        assert_eq!(doc.get_int("n"), Some(1024));
        assert_eq!(doc.get_float("lambda"), Some(1.5));
        assert_eq!(doc.get_bool("verbose"), Some(true));
        assert_eq!(doc.get_int("run.procs"), Some(8));
        assert_eq!(doc.get_int("run.inner.x"), Some(-3));
        let arr = doc.get("run.buckets").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_int(), Some(1024));
        assert_eq!(
            doc.get("run.tags").unwrap().as_array().unwrap()[0].as_str(),
            Some("a")
        );
    }

    #[test]
    fn int_float_coercions() {
        let doc = parse("a = 3\nb = 2.0\n").unwrap();
        assert_eq!(doc.get_float("a"), Some(3.0));
        assert_eq!(doc.get_int("b"), Some(2));
        assert_eq!(doc.get_int("a"), Some(3));
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = parse("s = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken\n").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        assert!(parse("[unterminated\n").is_err());
        assert!(parse("k = \"unterminated\n").is_err());
        assert!(parse("k = [1, 2\n").is_err());
        assert!(parse("k = nonsense\n").is_err());
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
        // Same key in different tables is fine.
        assert!(parse("[x]\na = 1\n[y]\na = 2\n").is_ok());
    }

    #[test]
    fn empty_array_ok() {
        let doc = parse("a = []\n").unwrap();
        assert_eq!(doc.get("a").unwrap().as_array().unwrap().len(), 0);
    }
}
