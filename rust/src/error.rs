//! Crate-wide error type.
//!
//! `occml` uses a single [`Error`] enum for everything that can fail at the
//! library boundary; internal hot paths are written to be infallible.

use thiserror::Error;

/// Crate-wide error type for `occml`.
#[derive(Debug, Error)]
pub enum Error {
    /// Configuration file / CLI flag problems.
    #[error("config error: {0}")]
    Config(String),

    /// Malformed or unsupported data file.
    #[error("data error: {0}")]
    Data(String),

    /// Dimension / shape mismatch between operands.
    #[error("shape error: {0}")]
    Shape(String),

    /// The XLA/PJRT runtime failed (artifact missing, compile error, ...).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Numerical failure (singular system, NaN in state, ...).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// A worker or master thread failed / a channel was disconnected.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand for a config error with formatted message.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand for a shape error with formatted message.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Shorthand for a runtime error with formatted message.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        let e = Error::config("bad key");
        assert_eq!(e.to_string(), "config error: bad key");
        let e = Error::shape("2 != 3");
        assert_eq!(e.to_string(), "shape error: 2 != 3");
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(e.to_string().contains("nope"));
    }
}
