//! Crate-wide error type.
//!
//! `occml` uses a single [`Error`] enum for everything that can fail at the
//! library boundary; internal hot paths are written to be infallible. The
//! `Display`/`std::error::Error` impls are hand-rolled so the crate builds
//! with zero dependencies (no `thiserror` offline).

use std::fmt;

/// Crate-wide error type for `occml`.
#[derive(Debug)]
pub enum Error {
    /// Configuration file / CLI flag problems.
    Config(String),

    /// Malformed or unsupported data file.
    Data(String),

    /// Dimension / shape mismatch between operands.
    Shape(String),

    /// The XLA/PJRT runtime failed (artifact missing, compile error, ...).
    Runtime(String),

    /// Numerical failure (singular system, NaN in state, ...).
    Numerical(String),

    /// A worker or master thread failed / a channel was disconnected.
    Coordinator(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand for a config error with formatted message.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand for a shape error with formatted message.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Shorthand for a runtime error with formatted message.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_category_and_message() {
        let e = Error::config("bad key");
        assert_eq!(e.to_string(), "config error: bad key");
        let e = Error::shape("2 != 3");
        assert_eq!(e.to_string(), "shape error: 2 != 3");
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(e.to_string().contains("nope"));
    }

    #[test]
    fn io_error_exposes_source() {
        use std::error::Error as _;
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert!(e.source().is_some());
        assert!(Error::config("x").source().is_none());
    }
}
