//! `occd` — the occml command-line launcher.
//!
//! Subcommands:
//!
//! * `run`       — run OCC DP-means / OFL / BP-means end to end
//! * `serve`     — streaming ingest gateway: admit a TCP firehose of
//!   points into mini-epochs and learn online (see the README runbook)
//! * `firehose`  — stream synthetic points into a running `occd serve`
//! * `worker`    — serve the compute/validator peer loop for a remote
//!   coordinator (the multi-host building block; see the README runbook)
//! * `gen-data`  — generate a synthetic dataset to an `.occb` file
//! * `simulate`  — the §4.1 first-iteration rejection sweeps (Fig 3 / 6)
//! * `scaling`   — the §4.2 normalized-runtime scaling experiment (Fig 4)
//! * `info`      — show backend/artifact status
//!
//! `occd <cmd> --help` lists flags. Flags override `--config <file>` values.

use occml::algorithms::objective;
use occml::cli::{App, Command, Dispatch, Parsed};
use occml::config::{
    toml, Algo, BackendKind, DataSource, RunConfig, SchedulerKind, ShardingKind, TransportKind,
};
use occml::coordinator::{driver, Model};
use occml::data::generators::{self, GenConfig};
use occml::error::{Error, Result};
use occml::{benchlib, sim};
use std::path::PathBuf;
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match real_main(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("occd: {e}");
            std::process::exit(1);
        }
    }
}

fn app() -> App {
    App::new("occd", "optimistic concurrency control for distributed unsupervised learning")
        .command(
            Command::new("run", "run an OCC algorithm end to end")
                .flag("config", "TOML config file", None)
                .flag("algo", "dpmeans | ofl | bpmeans", Some("dpmeans"))
                .flag("lambda", "distance threshold λ", Some("1.0"))
                .flag("procs", "worker processors P", Some("4"))
                .flag("block", "points per processor per epoch b", Some("256"))
                .flag("iterations", "passes over the data", Some("3"))
                .flag("bootstrap-div", "bootstrap divisor (0 = off)", Some("16"))
                .flag("backend", "native | xla", Some("native"))
                .flag("scheduler", "bsp | pipelined", Some("bsp"))
                .flag(
                    "speculation",
                    "wave-engine depth K under --scheduler pipelined (1 = BSP), or `auto`",
                    Some("2"),
                )
                .flag("speculation-max", "depth ceiling for --speculation auto", Some("8"))
                .flag(
                    "sharding",
                    "epoch-to-worker packing: hash | conflict (conflict components)",
                    Some("hash"),
                )
                .flag("transport", "inproc | tcp", Some("inproc"))
                .flag("io", "reactor | poll (event-loop blocking mode)", Some("reactor"))
                .flag("kernel", "panel | scalar (assignment distance kernel)", Some("panel"))
                .flag("store", "sparse | dense (peer-side dataset block store)", Some("sparse"))
                .flag("validator-shards", "validator peers (0 = procs/2, min 1)", Some("0"))
                .flag("peers", "comma-separated host:port of occd worker compute peers", None)
                .flag(
                    "validator-peers",
                    "comma-separated host:port of occd worker validator peers",
                    None,
                )
                .flag(
                    "reconnect-attempts",
                    "reconnect budget for a dropped remote peer (0 = fail fast)",
                    Some("3"),
                )
                .flag(
                    "frugal-wire",
                    "tcp wire diet: snapshot deltas + validator row subsets (true|false)",
                    Some("true"),
                )
                .flag("artifacts", "artifacts directory", Some("artifacts"))
                .flag("data", "dp | bp | separable | file:<path>", Some("dp"))
                .flag("n", "points to generate", Some("16384"))
                .flag("dim", "dimensionality", Some("16"))
                .flag("theta", "stick-breaking concentration", Some("1.0"))
                .flag("seed", "RNG seed", Some("0"))
                .flag("metrics", "metrics JSONL path (- for stdout)", None)
                .switch("quiet", "suppress the run report"),
        )
        .command(
            Command::new("serve", "streaming ingest gateway: admit a point firehose, learn online")
                .flag("config", "TOML config file", None)
                .flag("listen", "host:port for ingest clients (port 0 = ephemeral)", Some("127.0.0.1:0"))
                .flag("algo", "dpmeans | ofl | bpmeans", Some("dpmeans"))
                .flag("lambda", "distance threshold λ", Some("1.0"))
                .flag("procs", "worker processors P", Some("4"))
                .flag("block", "points per processor per epoch b", Some("256"))
                .flag("backend", "native | xla", Some("native"))
                .flag("scheduler", "bsp | pipelined", Some("bsp"))
                .flag(
                    "speculation",
                    "wave-engine depth K under --scheduler pipelined (1 = BSP), or `auto`",
                    Some("2"),
                )
                .flag("io", "reactor | poll (event-loop blocking mode)", Some("reactor"))
                .flag("kernel", "panel | scalar (assignment distance kernel)", Some("panel"))
                .flag("store", "sparse | dense (peer-side dataset block store)", Some("sparse"))
                .flag("validator-shards", "validator peers (0 = procs/2, min 1)", Some("0"))
                .flag("peers", "comma-separated host:port of occd worker compute peers", None)
                .flag(
                    "validator-peers",
                    "comma-separated host:port of occd worker validator peers",
                    None,
                )
                .flag("batch-points", "points per mini-epoch (0 = P·b)", Some("0"))
                .flag(
                    "batch-latency-ms",
                    "seal a partial mini-epoch after this wait (the admission SLA)",
                    Some("50"),
                )
                .flag(
                    "ingest-queue",
                    "sealed mini-epochs the engine may lag before clients are throttled",
                    Some("64"),
                )
                .flag("dim", "dimensionality", Some("16"))
                .flag("seed", "RNG seed", Some("0"))
                .flag("metrics", "metrics JSONL path (- for stdout)", None)
                .switch("quiet", "suppress the run report"),
        )
        .command(
            Command::new("firehose", "stream synthetic points into a running `occd serve`")
                .flag("connect", "host:port of the gateway", Some("127.0.0.1:7400"))
                .flag("data", "dp | bp | separable | file:<path>", Some("dp"))
                .flag("n", "points to stream", Some("16384"))
                .flag("dim", "dimensionality", Some("16"))
                .flag("theta", "stick-breaking concentration", Some("1.0"))
                .flag("seed", "RNG seed", Some("0"))
                .flag("chunk", "points per ingest frame", Some("512"))
                .switch("query", "fetch the final model snapshot after the EOS ack")
                .switch("quiet", "suppress the session report"),
        )
        .command(
            Command::new("worker", "serve peer jobs for a remote occd coordinator")
                .flag("listen", "host:port to listen on (port 0 = ephemeral)", Some("127.0.0.1:0"))
                .flag("backend", "native | xla", Some("native"))
                .flag("artifacts", "artifacts directory (xla backend)", Some("artifacts"))
                .flag("store", "sparse | dense (session dataset block store)", Some("sparse"))
                .switch("persist", "keep serving new coordinator sessions after one ends"),
        )
        .command(
            Command::new("gen-data", "generate a synthetic dataset")
                .flag("data", "dp | bp | separable", Some("dp"))
                .flag("n", "points", Some("16384"))
                .flag("dim", "dimensionality", Some("16"))
                .flag("theta", "stick-breaking concentration", Some("1.0"))
                .flag("seed", "RNG seed", Some("0"))
                .flag("out", "output .occb path", Some("data.occb"))
                .flag("csv", "also export CSV here", None),
        )
        .command(
            Command::new("simulate", "first-iteration rejection sweeps (Fig 3 / Fig 6)")
                .flag("exp", "fig3a | fig3b | fig3c | fig6", Some("fig3a"))
                .flag("reps", "repetitions per point", Some("400"))
                .flag("out", "CSV output path", None),
        )
        .command(
            Command::new("scaling", "normalized-runtime scaling (Fig 4)")
                .flag("algo", "dpmeans | ofl | bpmeans", Some("dpmeans"))
                .flag("n", "points", Some("131072"))
                .flag("pb", "points per epoch (P·b, held constant)", Some("8192"))
                .flag("procs", "comma-separated worker counts", Some("1,2,4,8"))
                .flag("iterations", "passes (dp/bp)", Some("3"))
                .flag("backend", "native | xla", Some("native"))
                .flag("scheduler", "bsp | pipelined", Some("bsp"))
                .flag("speculation", "wave-engine depth K (pipelined)", Some("2"))
                .flag("transport", "inproc | tcp", Some("inproc"))
                .flag("seed", "RNG seed", Some("0")),
        )
        .command(
            Command::new("info", "backend / artifact status")
                .flag("artifacts", "artifacts directory", Some("artifacts")),
        )
}

fn real_main(argv: &[String]) -> Result<i32> {
    let app = app();
    match app.dispatch(argv)? {
        Dispatch::Help(text) => {
            println!("{text}");
            Ok(0)
        }
        Dispatch::Run(cmd, parsed) => match cmd.name {
            "run" => cmd_run(&parsed),
            "serve" => cmd_serve(&parsed),
            "firehose" => cmd_firehose(&parsed),
            "worker" => cmd_worker(&parsed),
            "gen-data" => cmd_gen_data(&parsed),
            "simulate" => cmd_simulate(&parsed),
            "scaling" => cmd_scaling(&parsed),
            "info" => cmd_info(&parsed),
            other => Err(Error::config(format!("unhandled command {other}"))),
        },
    }
}

fn build_config(p: &Parsed) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = p.get("config") {
        let text = std::fs::read_to_string(path)?;
        RunConfig::from_doc(&toml::parse(&text)?)?
    } else {
        RunConfig::default()
    };
    if let Some(v) = p.get("algo") {
        cfg.algo = Algo::parse(v)?;
    }
    if let Some(v) = p.get_parse::<f64>("lambda")? {
        cfg.lambda = v;
    }
    if let Some(v) = p.get_parse::<usize>("procs")? {
        cfg.procs = v;
    }
    if let Some(v) = p.get_parse::<usize>("block")? {
        cfg.block = v;
    }
    if let Some(v) = p.get_parse::<usize>("iterations")? {
        cfg.iterations = v;
    }
    if let Some(v) = p.get_parse::<usize>("bootstrap-div")? {
        cfg.bootstrap_div = v;
    }
    if let Some(v) = p.get("backend") {
        cfg.backend = BackendKind::parse(v)?;
    }
    if let Some(v) = p.get("scheduler") {
        cfg.scheduler = SchedulerKind::parse(v)?;
    }
    if let Some(v) = p.get("speculation") {
        if v.eq_ignore_ascii_case("auto") {
            cfg.speculation_auto = true;
        } else {
            cfg.speculation = v
                .parse::<usize>()
                .map_err(|_| Error::config(format!("--speculation: cannot parse `{v}`")))?;
            cfg.speculation_auto = false;
        }
    }
    if let Some(v) = p.get_parse::<usize>("speculation-max")? {
        cfg.speculation_max = v;
    }
    if let Some(v) = p.get("sharding") {
        cfg.sharding = ShardingKind::parse(v)?;
    }
    if let Some(v) = p.get("transport") {
        cfg.transport = TransportKind::parse(v)?;
    }
    if let Some(v) = p.get("io") {
        cfg.io = occml::config::IoKind::parse(v)?;
    }
    if let Some(v) = p.get("kernel") {
        cfg.kernel = occml::config::KernelKind::parse(v)?;
    }
    if let Some(v) = p.get("store") {
        cfg.store = occml::config::StoreKind::parse(v)?;
    }
    if let Some(v) = p.get_parse::<usize>("validator-shards")? {
        cfg.validator_shards = v;
    }
    if let Some(v) = p.get("peers") {
        cfg.peers = occml::config::split_peer_list(v);
    }
    if let Some(v) = p.get("validator-peers") {
        cfg.validator_peers = occml::config::split_peer_list(v);
    }
    if let Some(v) = p.get_parse::<usize>("reconnect-attempts")? {
        cfg.reconnect_attempts = v;
    }
    if let Some(v) = p.get_parse::<bool>("frugal-wire")? {
        cfg.frugal_wire = v;
    }
    if let Some(v) = p.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(v);
    }
    if let Some(v) = p.get("data") {
        cfg.source = DataSource::parse(v)?;
    }
    if let Some(v) = p.get_parse::<usize>("n")? {
        cfg.n = v;
    }
    if let Some(v) = p.get_parse::<usize>("dim")? {
        cfg.dim = v;
    }
    if let Some(v) = p.get_parse::<f64>("theta")? {
        cfg.theta = v;
    }
    if let Some(v) = p.get_parse::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = p.get("metrics") {
        cfg.metrics_path = Some(PathBuf::from(v));
    }
    if let Some(v) = p.get_parse::<usize>("batch-points")? {
        cfg.batch_points = v;
    }
    if let Some(v) = p.get_parse::<u64>("batch-latency-ms")? {
        cfg.batch_latency_ms = v;
    }
    if let Some(v) = p.get_parse::<usize>("ingest-queue")? {
        cfg.ingest_queue = v;
    }
    cfg.normalize();
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(p: &Parsed) -> Result<i32> {
    let cfg = build_config(p)?;
    let out = driver::run(&cfg)?;
    if !p.switch("quiet") {
        let kind = match &out.model {
            Model::Dp(_) => "clusters",
            Model::Ofl(_) => "facilities",
            Model::Bp(_) => "features",
        };
        println!("algo        : {}", cfg.algo.name());
        println!("backend     : {}", cfg.backend.name());
        println!("scheduler   : {}", cfg.scheduler.name());
        if cfg.scheduler == SchedulerKind::Pipelined {
            if cfg.speculation_auto {
                println!("speculation : auto (max {})", cfg.speculation_max);
            } else {
                println!("speculation : {}", cfg.speculation);
            }
        }
        println!("sharding    : {}", cfg.sharding.name());
        println!("transport   : {}", cfg.transport.name());
        if cfg.transport == TransportKind::Tcp {
            println!("io          : {}", cfg.io.name());
            println!("store       : {}", cfg.store.name());
        }
        println!("kernel      : {}", cfg.kernel.name());
        println!("points      : {}", cfg.n);
        println!("P x b       : {} x {} = {} per epoch", cfg.procs, cfg.block, cfg.points_per_epoch());
        println!("{kind:<12}: {}", out.model.k());
        println!("proposed    : {}", out.summary.total_proposed());
        println!("accepted    : {}", out.summary.total_accepted());
        println!("rejected    : {}", out.summary.total_rejected());
        if cfg.sharding == ShardingKind::Conflict {
            println!(
                "components  : largest {} points (max over epochs)",
                out.summary.max_largest_component()
            );
        }
        if cfg.speculation_auto {
            println!(
                "auto depth  : {}..={} in effect",
                out.summary.min_effective_speculation(),
                out.summary.max_effective_speculation()
            );
        }
        if let Some(j) = out.summary.objective {
            println!("objective J : {j:.4}");
        }
        if cfg.transport == TransportKind::Tcp {
            println!("handshake   : {}", benchlib::fmt_duration(out.summary.transport.handshake_time));
            println!("dataset     : {} bytes shipped", out.summary.transport.dataset_bytes);
        }
        println!("wall clock  : {}", benchlib::fmt_duration(out.summary.total_time));
    }
    Ok(0)
}

/// `occd serve` — bind the ingest gateway and learn online from whatever
/// firehose connects. Blocks until a client ends the stream (or the last
/// client departs), then reports like `run`.
fn cmd_serve(p: &Parsed) -> Result<i32> {
    let cfg = build_config(p)?;
    let listen = p.get("listen").unwrap_or("127.0.0.1:0");
    let listener = bind_with_retry(listen)?;
    let addr = listener
        .local_addr()
        .map_err(|e| Error::config(format!("serve local_addr: {e}")))?;
    println!("occd serve ({}) listening on {addr}", cfg.algo.name());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    let out = occml::coordinator::serve::serve(&cfg, listener)?;
    if !p.switch("quiet") {
        let kind = match &out.model {
            Model::Dp(_) => "clusters",
            Model::Ofl(_) => "facilities",
            Model::Bp(_) => "features",
        };
        let streamed: usize = out
            .summary
            .epochs
            .iter()
            .filter(|e| e.epoch != usize::MAX)
            .map(|e| e.points)
            .sum();
        let batches = out.summary.epochs.iter().filter(|e| e.epoch != usize::MAX).count();
        println!("algo        : {}", cfg.algo.name());
        println!("scheduler   : {}", cfg.scheduler.name());
        println!("io          : {}", cfg.io.name());
        println!("streamed    : {streamed} points in {batches} mini-epochs");
        println!("{kind:<12}: {}", out.model.k());
        if let (Some(p50), Some(p95)) =
            (out.summary.admission_wait_p50(), out.summary.admission_wait_p95())
        {
            println!(
                "adm→commit  : p50 {} / p95 {}",
                benchlib::fmt_duration(p50),
                benchlib::fmt_duration(p95)
            );
        }
        println!("queue depth : {} max (bound {})", out.summary.max_ingest_queue_depth(), cfg.ingest_queue);
        if let Some(j) = out.summary.objective {
            println!("objective J : {j:.4}");
        }
        println!("wall clock  : {}", benchlib::fmt_duration(out.summary.total_time));
    }
    Ok(0)
}

/// `occd firehose` — the synthetic ingest client: stream a generated
/// dataset into a gateway chunk by chunk, honoring `Throttled` acks by
/// re-sending, then end the stream and wait for the final ack.
fn cmd_firehose(p: &Parsed) -> Result<i32> {
    use occml::coordinator::wire::{self, Ingest, IngestStatus};
    use std::io::Write as _;

    let gen_cfg = RunConfig {
        source: DataSource::parse(p.get("data").unwrap_or("dp"))?,
        n: p.get_parse("n")?.unwrap_or(16384),
        dim: p.get_parse("dim")?.unwrap_or(16),
        theta: p.get_parse("theta")?.unwrap_or(1.0),
        seed: p.get_parse("seed")?.unwrap_or(0),
        ..RunConfig::default()
    };
    let ds = driver::load_or_generate(&gen_cfg)?;
    let chunk = p.get_parse::<usize>("chunk")?.unwrap_or(512).max(1);
    let addr = p.get("connect").unwrap_or("127.0.0.1:7400");
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| Error::config(format!("firehose connect {addr}: {e}")))?;
    stream.set_nodelay(true).ok();

    // Blocking-read one complete frame off the session.
    let mut inbuf: Vec<u8> = Vec::new();
    fn read_frame(
        stream: &mut std::net::TcpStream,
        inbuf: &mut Vec<u8>,
    ) -> Result<(u16, Vec<u8>)> {
        use std::io::Read as _;
        let mut tmp = [0u8; 64 * 1024];
        loop {
            if let Some(f) = occml::coordinator::wire::poll_frame(inbuf)? {
                return Ok(f);
            }
            let n = stream.read(&mut tmp).map_err(Error::Io)?;
            if n == 0 {
                return Err(Error::config("gateway closed the connection mid-session"));
            }
            inbuf.extend_from_slice(&tmp[..n]);
        }
    }

    let d = ds.dim();
    let started = std::time::Instant::now();
    let mut seq = 0u64;
    let mut throttled = 0u64;
    let mut lo = 0usize;
    while lo < ds.len() {
        let hi = (lo + chunk).min(ds.len());
        let points = occml::linalg::Matrix {
            rows: hi - lo,
            cols: d,
            data: ds.points.data[lo * d..hi * d].to_vec(),
        };
        loop {
            let frame = wire::ingest_frame(&Ingest { seq, points: points.clone() })?;
            stream.write_all(&frame).map_err(Error::Io)?;
            let (kind, payload) = read_frame(&mut stream, &mut inbuf)?;
            if kind != wire::KIND_INGEST_ACK {
                return Err(Error::config(format!("expected ingest ack, got frame kind {kind}")));
            }
            let ack = wire::decode_ingest_ack(&payload)?;
            match ack.status {
                IngestStatus::Accepted => break,
                IngestStatus::Throttled => {
                    // Client-side backoff: the gateway told us the engine
                    // is `detail` mini-epochs behind; ease off and re-send.
                    throttled += 1;
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                IngestStatus::Rejected => {
                    return Err(Error::config(format!(
                        "chunk {seq} rejected: {}",
                        ack.message
                    )))
                }
            }
        }
        seq += 1;
        lo = hi;
    }

    // End of stream; the ack arrives only once the model is final.
    let eos = wire::ingest_frame(&Ingest { seq, points: occml::linalg::Matrix::zeros(0, d) })?;
    stream.write_all(&eos).map_err(Error::Io)?;
    let (kind, payload) = read_frame(&mut stream, &mut inbuf)?;
    if kind != wire::KIND_INGEST_ACK {
        return Err(Error::config(format!("expected final ack, got frame kind {kind}")));
    }
    let fin = wire::decode_ingest_ack(&payload)?;
    if fin.status != IngestStatus::Accepted {
        return Err(Error::config(format!("stream not accepted: {}", fin.message)));
    }

    let model_k = if p.switch("query") {
        stream.write_all(&wire::query_frame()?).map_err(Error::Io)?;
        let (kind, payload) = read_frame(&mut stream, &mut inbuf)?;
        if kind != wire::KIND_SNAPSHOT {
            return Err(Error::config(format!("expected snapshot, got frame kind {kind}")));
        }
        let (_, m) = wire::decode_snapshot(&payload)?;
        Some(m.rows)
    } else {
        None
    };

    if !p.switch("quiet") {
        println!("streamed    : {} points in {} chunks of ≤{}", ds.len(), seq, chunk);
        println!("admitted    : {} (gateway total)", fin.detail);
        println!("throttled   : {throttled} re-sends");
        if let Some(k) = model_k {
            println!("model rows  : {k}");
        }
        println!("wall clock  : {}", benchlib::fmt_duration(started.elapsed()));
    }
    Ok(0)
}

/// `occd worker` — the multi-host building block: bind a listener and serve
/// the compute/validator peer loop for remote coordinators. The coordinator
/// decides the role and shard assignment through the `Hello` handshake and
/// ships the dataset ranges the peer's jobs read, so a worker needs no
/// local data and no algorithm flags: one binary, pointed at by a
/// `peers = ["host:port", ...]` entry on the coordinator side.
fn cmd_worker(p: &Parsed) -> Result<i32> {
    let cfg = RunConfig {
        backend: BackendKind::parse(p.get("backend").unwrap_or("native"))?,
        artifacts_dir: PathBuf::from(p.get("artifacts").unwrap_or("artifacts")),
        ..RunConfig::default()
    };
    let backend = driver::make_backend(&cfg)?;
    let listen = p.get("listen").unwrap_or("127.0.0.1:0");
    // A fixed port can sit in TIME_WAIT from a just-killed predecessor (the
    // replacement-worker flow of the coordinator's reconnect policy), so
    // retry EADDRINUSE for a bounded window instead of failing the spawn.
    let listener = bind_with_retry(listen)?;
    let addr = listener
        .local_addr()
        .map_err(|e| Error::config(format!("worker local_addr: {e}")))?;
    println!("occd worker listening on {addr}");
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    let persist = p.switch("persist");
    let store = match p.get("store") {
        Some(v) => occml::config::StoreKind::parse(v)?,
        None => occml::config::StoreKind::from_env(),
    };
    loop {
        let (stream, peer) = listener
            .accept()
            .map_err(|e| Error::config(format!("worker accept: {e}")))?;
        match occml::coordinator::tcp::serve_peer_with(stream, backend.clone(), store) {
            Ok(()) => eprintln!("occd worker: session from {peer} ended"),
            Err(e) => eprintln!("occd worker: session from {peer} failed: {e}"),
        }
        if !persist {
            break;
        }
    }
    Ok(0)
}

/// Bind a listener, retrying `EADDRINUSE` for ~15 s (fixed ports only
/// matter to the reconnect flow; everything else binds first try).
fn bind_with_retry(listen: &str) -> Result<std::net::TcpListener> {
    let mut last: Option<std::io::Error> = None;
    for _ in 0..60 {
        match std::net::TcpListener::bind(listen) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
                last = Some(e);
                std::thread::sleep(std::time::Duration::from_millis(250));
            }
            Err(e) => return Err(Error::config(format!("worker bind {listen}: {e}"))),
        }
    }
    Err(Error::config(format!(
        "worker bind {listen}: {}",
        last.expect("at least one attempt")
    )))
}

fn cmd_gen_data(p: &Parsed) -> Result<i32> {
    let gen = GenConfig {
        n: p.get_parse("n")?.unwrap_or(16384),
        dim: p.get_parse("dim")?.unwrap_or(16),
        theta: p.get_parse("theta")?.unwrap_or(1.0),
        seed: p.get_parse("seed")?.unwrap_or(0),
    };
    let source = DataSource::parse(p.get("data").unwrap_or("dp"))?;
    let ds = match source {
        DataSource::DpClusters => generators::dp_clusters(&gen),
        DataSource::BpFeatures => generators::bp_features(&gen),
        DataSource::Separable => generators::separable_clusters(&gen),
        DataSource::File(_) => return Err(Error::config("gen-data needs a generator source")),
    };
    let out = PathBuf::from(p.get("out").unwrap_or("data.occb"));
    occml::data::io::write_occb(&ds, &out)?;
    println!("wrote {} points (dim {}) to {}", ds.len(), ds.dim(), out.display());
    if let Some(csv) = p.get("csv") {
        occml::data::io::write_csv(&ds, &PathBuf::from(csv))?;
        println!("csv: {csv}");
    }
    Ok(0)
}

fn cmd_simulate(p: &Parsed) -> Result<i32> {
    let exp = p.get("exp").unwrap_or("fig3a").to_string();
    let reps = p.get_parse::<usize>("reps")?.unwrap_or(400);
    let mut table = benchlib::Table::new(&["exp", "N", "Pb", "mean_rejections", "mean_accepted", "bound_Pb"]);
    let ns: Vec<usize> = (1..=10).map(|i| i * 256).collect();
    let pbs = [16usize, 32, 64, 128, 256];
    for &n in &ns {
        for &pb in &pbs {
            let (mut rej, mut acc) = (0.0f64, 0.0f64);
            for rep in 0..reps {
                let seed = (rep as u64) * 7919 + n as u64;
                let gen = GenConfig { n, dim: 16, theta: 1.0, seed };
                let r = match exp.as_str() {
                    "fig3a" => sim::sim_dpmeans(&generators::dp_clusters(&gen), 1.0, pb),
                    "fig3b" => sim::sim_ofl(&generators::dp_clusters(&gen), 1.0, pb, seed ^ 0xF1),
                    "fig3c" => sim::sim_bpmeans(&generators::bp_features(&gen), 1.0, pb),
                    "fig6" => sim::sim_dpmeans(&generators::separable_clusters(&gen), 1.0, pb),
                    other => return Err(Error::config(format!("unknown exp `{other}`"))),
                };
                rej += r.rejections() as f64;
                acc += r.accepted as f64;
            }
            table.row(vec![
                exp.clone(),
                n.to_string(),
                pb.to_string(),
                format!("{:.2}", rej / reps as f64),
                format!("{:.2}", acc / reps as f64),
                pb.to_string(),
            ]);
        }
    }
    table.print();
    if let Some(out) = p.get("out") {
        table.write_csv(std::path::Path::new(out))?;
        println!("csv: {out}");
    }
    Ok(0)
}

fn cmd_scaling(p: &Parsed) -> Result<i32> {
    let algo = Algo::parse(p.get("algo").unwrap_or("dpmeans"))?;
    let n = p.get_parse::<usize>("n")?.unwrap_or(131072);
    let pb = p.get_parse::<usize>("pb")?.unwrap_or(8192);
    let iters = p.get_parse::<usize>("iterations")?.unwrap_or(3);
    let backend = BackendKind::parse(p.get("backend").unwrap_or("native"))?;
    let scheduler = SchedulerKind::parse(p.get("scheduler").unwrap_or("bsp"))?;
    let speculation = p.get_parse::<usize>("speculation")?.unwrap_or(2);
    let seed = p.get_parse::<u64>("seed")?.unwrap_or(0);
    let procs: Vec<usize> = p
        .get("procs")
        .unwrap_or("1,2,4,8")
        .split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|_| Error::config("bad --procs")))
        .collect::<Result<_>>()?;

    let source = match algo {
        Algo::BpMeans => DataSource::BpFeatures,
        _ => DataSource::DpClusters,
    };
    let mut base_cfg = RunConfig {
        algo,
        lambda: 2.0,
        iterations: if algo == Algo::Ofl { 1 } else { iters },
        backend,
        scheduler,
        speculation,
        seed,
        source,
        n,
        ..RunConfig::default() // transport: the env-aware default
    };
    if let Some(v) = p.get("transport") {
        base_cfg.transport = TransportKind::parse(v)?;
    }
    let data = Arc::new(driver::load_or_generate(&base_cfg)?);
    let be = driver::make_backend(&base_cfg)?;

    let mut table =
        benchlib::Table::new(&["algo", "P", "b", "iteration", "time", "normalized_vs_P1"]);
    let mut baseline: Vec<f64> = Vec::new();
    for &p_count in &procs {
        let cfg = RunConfig { procs: p_count, block: pb / p_count, ..base_cfg.clone() };
        let out = driver::run_with(&cfg, data.clone(), be.clone())?;
        for it in 0..out.summary.iterations() {
            let t = out.summary.iteration_time(it).as_secs_f64();
            if p_count == procs[0] {
                baseline.push(t);
            }
            let norm = baseline.get(it).map(|b| t / b).unwrap_or(f64::NAN);
            table.row(vec![
                algo.name().into(),
                p_count.to_string(),
                (pb / p_count).to_string(),
                it.to_string(),
                benchlib::fmt_duration(std::time::Duration::from_secs_f64(t)),
                format!("{norm:.3}"),
            ]);
        }
    }
    table.print();
    Ok(0)
}

fn cmd_info(p: &Parsed) -> Result<i32> {
    let dir = PathBuf::from(p.get("artifacts").unwrap_or("artifacts"));
    println!("occml {} — backends:", env!("CARGO_PKG_VERSION"));
    println!("  native: available");
    match occml::runtime::manifest::Manifest::load(&dir) {
        Ok(m) => {
            println!("  xla   : {} artifacts (dim {}) in {}", m.entries.len(), m.dim, dir.display());
            for e in &m.entries {
                println!("    {:<11} b={:<5} k={:<5} {}", e.kind.name(), e.b, e.k, e.file.display());
            }
        }
        Err(e) => println!("  xla   : unavailable — {e}"),
    }
    // Smoke the PJRT client.
    match xla_smoke() {
        Ok(msg) => println!("  pjrt  : {msg}"),
        Err(e) => println!("  pjrt  : failed — {e}"),
    }
    Ok(0)
}

#[cfg(feature = "xla")]
fn xla_smoke() -> Result<String> {
    let client =
        xla::PjRtClient::cpu().map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e:?}")))?;
    Ok(format!("{} ({} devices)", client.platform_name(), client.device_count()))
}

#[cfg(not(feature = "xla"))]
fn xla_smoke() -> Result<String> {
    Err(Error::runtime("built without the `xla` feature"))
}

/// Objective helper re-exported for integration smoke (keeps the import used
/// in all build configurations).
#[allow(dead_code)]
fn _objective_touch(data: &occml::data::Dataset, m: &occml::linalg::Matrix) -> f64 {
    objective::dp_objective(data, m, 1.0)
}
