//! Dataset (de)serialization.
//!
//! `.occb` is a tiny little-endian binary format:
//!
//! ```text
//! magic  "OCCB1\0\0\0"   (8 bytes)
//! n      u64            number of points
//! d      u64            dimensionality
//! flags  u64            bit 0: labels present
//! data   n*d f32        row-major points
//! labels n   u32        (iff flag bit 0)
//! ```
//!
//! CSV export is provided for plotting / external tooling.

use super::Dataset;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"OCCB1\0\0\0";

/// Write a dataset to `path` in `.occb` format.
pub fn write_occb(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(ds.len() as u64).to_le_bytes())?;
    w.write_all(&(ds.dim() as u64).to_le_bytes())?;
    let flags: u64 = if ds.labels.is_some() { 1 } else { 0 };
    w.write_all(&flags.to_le_bytes())?;
    for &v in &ds.points.data {
        w.write_all(&v.to_le_bytes())?;
    }
    if let Some(labels) = &ds.labels {
        for &l in labels {
            w.write_all(&l.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read a dataset from `path` in `.occb` format.
pub fn read_occb(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Data(format!("{}: bad magic", path.display())));
    }
    let n = read_u64(&mut r)? as usize;
    let d = read_u64(&mut r)? as usize;
    let flags = read_u64(&mut r)?;
    if n.checked_mul(d).is_none() || n * d > (1 << 33) {
        return Err(Error::Data(format!("{}: implausible size {n}x{d}", path.display())));
    }
    let mut data = vec![0.0f32; n * d];
    let mut buf = [0u8; 4];
    for v in data.iter_mut() {
        r.read_exact(&mut buf)?;
        *v = f32::from_le_bytes(buf);
    }
    let labels = if flags & 1 != 0 {
        let mut ls = vec![0u32; n];
        for l in ls.iter_mut() {
            r.read_exact(&mut buf)?;
            *l = u32::from_le_bytes(buf);
        }
        Some(ls)
    } else {
        None
    };
    Ok(Dataset::new(Matrix::from_vec(n, d, data), labels))
}

/// Export points (and labels, if any) as CSV with a header row.
pub fn write_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let d = ds.dim();
    for j in 0..d {
        if j > 0 {
            write!(w, ",")?;
        }
        write!(w, "x{j}")?;
    }
    if ds.labels.is_some() {
        write!(w, ",label")?;
    }
    writeln!(w)?;
    for i in 0..ds.len() {
        let row = ds.point(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                write!(w, ",")?;
            }
            write!(w, "{v}")?;
        }
        if let Some(labels) = &ds.labels {
            write!(w, ",{}", labels[i])?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{dp_clusters, GenConfig};

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("occml-test-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn occb_roundtrip_with_labels() {
        let ds = dp_clusters(&GenConfig { n: 37, dim: 5, theta: 1.0, seed: 1 });
        let p = tmpfile("rt.occb");
        write_occb(&ds, &p).unwrap();
        let rd = read_occb(&p).unwrap();
        assert_eq!(rd.len(), 37);
        assert_eq!(rd.dim(), 5);
        assert_eq!(rd.points.data, ds.points.data);
        assert_eq!(rd.labels, ds.labels);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn occb_roundtrip_without_labels() {
        let mut ds = dp_clusters(&GenConfig { n: 8, dim: 3, theta: 1.0, seed: 2 });
        ds.labels = None;
        let p = tmpfile("rt2.occb");
        write_occb(&ds, &p).unwrap();
        let rd = read_occb(&p).unwrap();
        assert!(rd.labels.is_none());
        assert_eq!(rd.points.data, ds.points.data);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmpfile("bad.occb");
        std::fs::write(&p, b"NOTOCCB1aaaaaaaaaaaaaaaaaaaaaaaa").unwrap();
        assert!(read_occb(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_has_header_and_rows() {
        let ds = dp_clusters(&GenConfig { n: 4, dim: 2, theta: 1.0, seed: 3 });
        let p = tmpfile("out.csv");
        write_csv(&ds, &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(lines[0].starts_with("x0,x1,label"));
        std::fs::remove_file(&p).ok();
    }
}
