//! Datasets and block partitioning.
//!
//! A [`Dataset`] is a dense row-major point matrix plus (optionally) the
//! ground-truth component each point was generated from — kept around so the
//! experiment harnesses can report `K_N` (the number of *distinct latent*
//! clusters in the first `N` points, the quantity in Theorem 3.3).
//!
//! [`partition`] implements the paper's processor-epoch blocks `B(p, t)`:
//! the first `b` points go to processor 1, the next `b` to processor 2, …,
//! cycling through processors epoch by epoch (App B.3, Figure 5). This exact
//! layout is what makes the serial-equivalence proofs (and our replay tests)
//! work.

pub mod generators;
pub mod io;
pub mod store;

use crate::linalg::Matrix;
use std::sync::{Arc, Mutex};

/// A dense dataset of `n` points in `d` dimensions.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `n × d` row-major points.
    pub points: Matrix,
    /// Ground-truth latent component per point (generator metadata), if known.
    pub labels: Option<Vec<u32>>,
    /// Canonical squared norm (`linalg::norm2`) per point, computed once
    /// at construction — points never change, so the assignment kernels
    /// read these instead of recomputing `‖x‖²` per epoch. Always
    /// `points.rows` long; bit-identical to recomputing (norm caches are
    /// pure memoization of the canonical schedule).
    pub norms: Vec<f32>,
}

impl Dataset {
    /// Build a dataset, computing the per-point norm cache.
    pub fn new(points: Matrix, labels: Option<Vec<u32>>) -> Dataset {
        let norms = crate::linalg::panel::point_norms(&points.data, points.rows, points.cols);
        Dataset { points, labels, norms }
    }

    /// Build from parts with an already-computed norm cache (streaming
    /// admission extends its norms incrementally per admitted chunk).
    /// `norms[i]` must equal `linalg::norm2(points.row(i))` bitwise.
    pub fn with_norms(points: Matrix, labels: Option<Vec<u32>>, norms: Vec<f32>) -> Dataset {
        debug_assert_eq!(norms.len(), points.rows);
        Dataset { points, labels, norms }
    }

    /// Recompute the norm cache for rows `lo..hi` (after an in-place row
    /// write, e.g. a demand-shipped block landing in a worker's store),
    /// growing the cache if the matrix grew.
    pub fn refresh_norms(&mut self, lo: usize, hi: usize) {
        self.norms.resize(self.points.rows, 0.0);
        for i in lo..hi.min(self.points.rows) {
            self.norms[i] = crate::linalg::norm2(self.points.row(i));
        }
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.points.rows
    }

    /// True if the dataset has no points.
    pub fn is_empty(&self) -> bool {
        self.points.rows == 0
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.points.cols
    }

    /// Borrow point `i`.
    pub fn point(&self, i: usize) -> &[f32] {
        self.points.row(i)
    }

    /// Number of distinct latent components among the first `n` points
    /// (`K_N` in Theorem 3.3). `None` when labels are unknown.
    pub fn distinct_components(&self, n: usize) -> Option<usize> {
        let labels = self.labels.as_ref()?;
        let mut seen = std::collections::HashSet::new();
        for &l in labels.iter().take(n) {
            seen.insert(l);
        }
        Some(seen.len())
    }
}

/// A swappable handle on the current dataset generation.
///
/// Static runs build one [`Dataset`] up front and never touch it again;
/// the streaming ingest service (`occd serve`) *grows* the dataset as
/// mini-epochs are admitted. Read sites (job planning, block shipping,
/// validation) take an `Arc` snapshot with [`DataCell::get`] — cheap, a
/// mutex-guarded `Arc::clone` — and work against that immutable
/// generation; the admission stage publishes a grown generation with
/// [`DataCell::set`] *before* announcing the mini-epoch that reads it, so
/// every epoch's span is always covered by the generation any later
/// snapshot observes. Existing snapshots are unaffected (generations are
/// immutable), which is what keeps in-flight waves bit-stable.
#[derive(Debug)]
pub struct DataCell(Mutex<Arc<Dataset>>);

impl DataCell {
    /// Wrap a dataset generation.
    pub fn new(data: Arc<Dataset>) -> DataCell {
        DataCell(Mutex::new(data))
    }

    /// Snapshot the current generation.
    pub fn get(&self) -> Arc<Dataset> {
        self.0.lock().expect("data cell poisoned").clone()
    }

    /// Publish a new generation. The new dataset must extend the old one
    /// (same prefix rows, same width) — callers only ever append.
    pub fn set(&self, data: Arc<Dataset>) {
        *self.0.lock().expect("data cell poisoned") = data;
    }
}

/// The block `B(p, t)` of data indices for processor `p` in epoch `t`
/// (both 0-based), with `P` processors and `b` points per processor-epoch.
///
/// Epoch `t` covers the contiguous range `[t·P·b, (t+1)·P·b)`, split into
/// `P` consecutive blocks of `b` — processor `p` gets
/// `[t·P·b + p·b, t·P·b + (p+1)·b)`, clamped to `n`.
pub fn block(n: usize, p_procs: usize, b: usize, p: usize, t: usize) -> std::ops::Range<usize> {
    let start = t * p_procs * b + p * b;
    let end = (start + b).min(n);
    start.min(n)..end
}

/// Number of epochs needed to cover `n` points with `P` processors × `b`.
pub fn num_epochs(n: usize, p_procs: usize, b: usize) -> usize {
    let per_epoch = p_procs * b;
    n.div_ceil(per_epoch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_tile_the_dataset_exactly_once() {
        for &(n, p_procs, b) in &[(100, 4, 10), (97, 4, 10), (16, 2, 16), (5, 8, 4)] {
            let epochs = num_epochs(n, p_procs, b);
            let mut seen = vec![0u32; n];
            for t in 0..epochs {
                for p in 0..p_procs {
                    for i in block(n, p_procs, b, p, t) {
                        seen[i] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "n={n} P={p_procs} b={b}");
        }
    }

    #[test]
    fn block_layout_matches_figure5() {
        // P=2, b=3, n=12: epoch 0 → p0 gets 0..3, p1 gets 3..6;
        // epoch 1 → p0 gets 6..9, p1 gets 9..12.
        assert_eq!(block(12, 2, 3, 0, 0), 0..3);
        assert_eq!(block(12, 2, 3, 1, 0), 3..6);
        assert_eq!(block(12, 2, 3, 0, 1), 6..9);
        assert_eq!(block(12, 2, 3, 1, 1), 9..12);
    }

    #[test]
    fn clamped_final_block() {
        assert_eq!(block(10, 2, 3, 1, 1), 9..10);
        assert_eq!(block(10, 2, 3, 0, 2), 10..10); // past the end → empty
    }

    #[test]
    fn distinct_components_counts_prefix() {
        let ds = Dataset::new(Matrix::zeros(5, 1), Some(vec![0, 0, 1, 2, 1]));
        assert_eq!(ds.distinct_components(1), Some(1));
        assert_eq!(ds.distinct_components(3), Some(2));
        assert_eq!(ds.distinct_components(5), Some(3));
    }

    #[test]
    fn norm_cache_tracks_rows() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 4.0, 0.0, 2.0]);
        let ds = Dataset::new(m, None);
        assert_eq!(ds.norms, vec![25.0, 4.0]);
        // In-place row writes refresh their norm range (worker block
        // arrival), growing the cache with the matrix.
        let mut grown = ds.clone();
        grown.points.grow_rows(3);
        grown.points.row_mut(2).copy_from_slice(&[1.0, 1.0]);
        grown.refresh_norms(2, 3);
        assert_eq!(grown.norms, vec![25.0, 4.0, 2.0]);
        grown.points.row_mut(0).copy_from_slice(&[1.0, 0.0]);
        grown.refresh_norms(0, 1);
        assert_eq!(grown.norms[0], 1.0);
        // The cached value is bitwise what the kernel would recompute.
        for i in 0..3 {
            assert_eq!(
                grown.norms[i].to_bits(),
                crate::linalg::norm2(grown.points.row(i)).to_bits()
            );
        }
    }
}
