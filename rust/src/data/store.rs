//! Out-of-core peer data plane: the offset-keyed, panel-aligned block
//! store behind `store = "sparse"`.
//!
//! A worker only ever reads the ~`2·n/P` rows its jobs cover, yet the
//! dense peer store allocates the full `n × d` zero matrix up front. The
//! [`BlockStore`] replaces that with independently-allocated 64-row
//! blocks ([`BLOCK_POINTS`] — deliberately the panel size, so a block
//! boundary is always a legal kernel panel boundary), keyed by block
//! index in a `BTreeMap`: a peer's resident footprint is O(covered
//! rows), not O(n), and a dataset that only fits sharded across the
//! cluster becomes runnable.
//!
//! # Block lifecycle
//!
//! 1. **Install.** A demand-shipped dataset frame lands at an arbitrary
//!    row offset ([`BlockStore::install`]). Every 64-row block the span
//!    touches is allocated on first touch (zero-filled, `64 × d`), the
//!    overlapping rows are copied in, and the per-row canonical
//!    [`crate::linalg::norm2`] is recomputed for exactly those rows —
//!    the block's norm slice is the same pure memoization a [`Dataset`]
//!    carries, so kernels reading it are bit-identical to recomputing.
//!    Re-ships (reconnect recovery, overlapping spans) simply rewrite
//!    rows and their norms; installs are idempotent.
//! 2. **Read.** The executor never touches blocks directly: it asks the
//!    owning [`PeerStore`] for a [`DataView`] over a job's row range,
//!    which is granted only when the session's [`Coverage`] proves every
//!    row of the range was installed — an uncovered row (and therefore a
//!    stale or zero norm) is *impossible to read*, structurally, on both
//!    the sparse and the dense variant. [`DataView::pieces`] then yields
//!    the range as contiguous `(global_range, Block)` slices — one per
//!    resident block for the sparse store, a single slice for the dense
//!    one — each carrying its norm sub-slice.
//! 3. **Drop.** Blocks live for the session; a reconnected replacement
//!    session starts from an empty store and is re-shipped its coverage.
//!
//! The same structure backs the master's streaming admission buffer
//! (`occd serve` stages un-sealed chunks in a [`BlockStore`] before a
//! seal materializes the published generation), which is exactly the
//! ROADMAP's "the ingest buffer and the block store are the same
//! structure".

use crate::config::StoreKind;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::{norm2, Matrix};
use crate::runtime::Block;
use std::collections::BTreeMap;
use std::ops::Range;

/// Rows per store block. Equal to [`crate::linalg::panel::PANEL_POINTS`]
/// by construction (const-asserted below): a block boundary is always a
/// panel boundary, so handing per-block slices to the panel kernels
/// changes memory traversal, never arithmetic or compare order.
pub const BLOCK_POINTS: usize = crate::linalg::panel::PANEL_POINTS;
const _: () = assert!(BLOCK_POINTS == 64);

// ---------------------------------------------------------------------------
// Coverage: which point ranges a peer holds
// ---------------------------------------------------------------------------

/// A set of disjoint, sorted point ranges — which parts of the dataset a
/// peer has been shipped (master side) or has installed (peer side).
#[derive(Debug, Clone, Default)]
pub struct Coverage {
    spans: Vec<Range<usize>>,
}

impl Coverage {
    /// Add a range, merging with overlapping or adjacent spans.
    pub fn add(&mut self, r: Range<usize>) {
        if r.start >= r.end {
            return;
        }
        self.spans.push(r);
        self.spans.sort_by_key(|s| s.start);
        let mut merged: Vec<Range<usize>> = Vec::with_capacity(self.spans.len());
        for s in self.spans.drain(..) {
            match merged.last_mut() {
                Some(last) if s.start <= last.end => last.end = last.end.max(s.end),
                _ => merged.push(s),
            }
        }
        self.spans = merged;
    }

    /// True if every point of `r` is covered.
    pub fn covers(&self, r: &Range<usize>) -> bool {
        r.start >= r.end || self.spans.iter().any(|s| s.start <= r.start && r.end <= s.end)
    }

    /// The sub-ranges of `r` not yet covered, in order.
    pub fn missing(&self, r: &Range<usize>) -> Vec<Range<usize>> {
        let mut out = Vec::new();
        let mut at = r.start;
        for s in &self.spans {
            if at >= r.end {
                break;
            }
            if s.end <= at {
                continue;
            }
            if s.start >= r.end {
                break;
            }
            if s.start > at {
                out.push(at..s.start.min(r.end));
            }
            at = at.max(s.end);
        }
        if at < r.end {
            out.push(at..r.end);
        }
        out
    }

    /// Forget everything (a fresh peer session holds nothing).
    pub fn clear(&mut self) {
        self.spans.clear();
    }

    /// True if nothing is covered.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// One past the highest covered row (0 when empty).
    pub fn max_end(&self) -> usize {
        self.spans.last().map(|s| s.end).unwrap_or(0)
    }

    /// Number of distinct `block_points`-aligned blocks the spans touch —
    /// exactly the blocks a sparse [`BlockStore`] holding this coverage
    /// has allocated, which is how the master models a peer's residency.
    pub fn aligned_blocks(&self, block_points: usize) -> usize {
        let mut count = 0usize;
        let mut last: Option<usize> = None;
        for s in &self.spans {
            let b0 = s.start / block_points;
            let b1 = (s.end - 1) / block_points;
            let from = match last {
                Some(l) if l + 1 > b0 => l + 1,
                _ => b0,
            };
            if from <= b1 {
                count += b1 - from + 1;
            }
            last = Some(match last {
                Some(l) => l.max(b1),
                None => b1,
            });
        }
        count
    }
}

// ---------------------------------------------------------------------------
// BlockStore: offset-keyed 64-row blocks with per-block norm slices
// ---------------------------------------------------------------------------

/// One resident block: `BLOCK_POINTS × d` row-major points (zero-filled
/// where no install has written yet) plus the canonical per-row norms
/// for the written rows.
#[derive(Debug, Clone)]
struct StoreBlock {
    points: Vec<f32>,
    norms: Vec<f32>,
}

/// Offset-keyed sparse point store: 64-row panel-aligned blocks,
/// allocated only where installs landed. See the module docs for the
/// block lifecycle.
#[derive(Debug, Clone)]
pub struct BlockStore {
    dim: usize,
    blocks: BTreeMap<usize, StoreBlock>,
}

impl BlockStore {
    /// Empty store for `dim`-wide points.
    pub fn new(dim: usize) -> BlockStore {
        BlockStore { dim, blocks: BTreeMap::new() }
    }

    /// Dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of resident blocks.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// Resident point-storage bytes: `blocks × BLOCK_POINTS × d × 4`.
    /// The dense equivalent is `n × d × 4` — the A/B the
    /// `resident_data_bytes` metric compares.
    pub fn resident_bytes(&self) -> u64 {
        (self.blocks.len() * BLOCK_POINTS * self.dim * 4) as u64
    }

    /// Install `rows` rows of row-major `data` at global row `offset`,
    /// allocating the touched blocks on first touch and refreshing the
    /// written rows' norms. Overlapping installs rewrite in place.
    pub fn install(&mut self, offset: usize, data: &[f32], rows: usize) {
        let d = self.dim;
        debug_assert_eq!(data.len(), rows * d);
        let end = offset + rows;
        let mut at = offset;
        while at < end {
            let b = at / BLOCK_POINTS;
            let b_lo = b * BLOCK_POINTS;
            let hi = end.min(b_lo + BLOCK_POINTS);
            let blk = self.blocks.entry(b).or_insert_with(|| StoreBlock {
                points: vec![0.0; BLOCK_POINTS * d],
                norms: vec![0.0; BLOCK_POINTS],
            });
            let local = at - b_lo;
            let len = hi - at;
            blk.points[local * d..(local + len) * d]
                .copy_from_slice(&data[(at - offset) * d..(hi - offset) * d]);
            for i in local..local + len {
                blk.norms[i] = norm2(&blk.points[i * d..(i + 1) * d]);
            }
            at = hi;
        }
    }

    /// Borrow global point `i`. Panics when `i`'s block is not resident —
    /// readers must hold a coverage-checked [`DataView`].
    pub fn point(&self, i: usize) -> &[f32] {
        let d = self.dim;
        let blk = self
            .blocks
            .get(&(i / BLOCK_POINTS))
            .unwrap_or_else(|| panic!("point {i} read from a non-resident block"));
        let local = i % BLOCK_POINTS;
        &blk.points[local * d..(local + 1) * d]
    }

    /// Drop blocks lying entirely below global row `row`. The streaming
    /// admission stage evicts staged blocks once a seal has materialized
    /// them into the published generation; a block straddling `row`
    /// stays resident (its upper rows may still be staged).
    pub fn evict_below(&mut self, row: usize) {
        self.blocks = self.blocks.split_off(&(row / BLOCK_POINTS));
    }

    /// The contiguous `(global_range, Block)` slices covering `range`, in
    /// ascending row order — one per resident block the range touches.
    /// Callers must have coverage-checked the range: a gap in residency
    /// silently shortens the output, which a checked range cannot have.
    pub fn pieces(&self, range: &Range<usize>) -> Vec<(Range<usize>, Block<'_>)> {
        let mut out = Vec::new();
        if range.start >= range.end {
            return out;
        }
        let d = self.dim;
        let b0 = range.start / BLOCK_POINTS;
        let b1 = (range.end - 1) / BLOCK_POINTS;
        for (b, blk) in self.blocks.range(b0..=b1) {
            let b_lo = b * BLOCK_POINTS;
            let lo = range.start.max(b_lo);
            let hi = range.end.min(b_lo + BLOCK_POINTS);
            let local = lo - b_lo;
            let n = hi - lo;
            out.push((
                lo..hi,
                Block {
                    data: &blk.points[local * d..(local + n) * d],
                    n,
                    d,
                    norms: Some(&blk.norms[local..local + n]),
                },
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// DataView: what the executor reads — dense or block-sparse, same API
// ---------------------------------------------------------------------------

/// A read view over point rows, handed to the job executor. Kernels see
/// [`Block`] slices either way; the dense variant yields its range as a
/// single slice, so the dense path is byte-for-byte the pre-store code.
#[derive(Debug, Clone, Copy)]
pub enum DataView<'a> {
    /// A dense dataset (the in-proc path, and `store = "dense"` peers).
    Dense(&'a Dataset),
    /// A sparse block store (`store = "sparse"` peers).
    Blocks(&'a BlockStore),
}

impl<'a> DataView<'a> {
    /// Dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            DataView::Dense(ds) => ds.dim(),
            DataView::Blocks(bs) => bs.dim(),
        }
    }

    /// Borrow global point `i`.
    pub fn point(&self, i: usize) -> &[f32] {
        match self {
            DataView::Dense(ds) => ds.point(i),
            DataView::Blocks(bs) => bs.point(i),
        }
    }

    /// The contiguous `(global_range, Block)` slices covering `range`, in
    /// ascending row order. Per-point kernels are order- and
    /// grouping-independent, and the sequential reducers iterate pieces
    /// in ascending row order, so any piece partition of a range is
    /// bit-identical to the one-slice dense view.
    pub fn pieces(&self, range: &Range<usize>) -> Vec<(Range<usize>, Block<'a>)> {
        match self {
            DataView::Dense(ds) => {
                if range.start >= range.end {
                    Vec::new()
                } else {
                    vec![(range.clone(), Block::of_dataset(ds, range.clone()))]
                }
            }
            DataView::Blocks(bs) => bs.pieces(range),
        }
    }
}

// ---------------------------------------------------------------------------
// PeerStore: the session store — coverage-gated reads over either variant
// ---------------------------------------------------------------------------

/// A peer session's dataset store: installs land in either a dense
/// [`Dataset`] or a sparse [`BlockStore`] (per the `store` knob), and
/// *every* read goes through [`PeerStore::view`], which refuses any range
/// the session's [`Coverage`] does not prove installed — the structural
/// fix for stale-norm reads on rows a grow zero-filled but no install
/// ever wrote.
#[derive(Debug)]
pub struct PeerStore {
    kind: StoreKind,
    covered: Coverage,
    dense: Option<Dataset>,
    sparse: Option<BlockStore>,
}

impl PeerStore {
    /// Empty store of the given kind. Nothing is allocated until the
    /// first install — validator peers never receive data and never pay.
    pub fn new(kind: StoreKind) -> PeerStore {
        PeerStore { kind, covered: Coverage::default(), dense: None, sparse: None }
    }

    /// The store variant in force.
    pub fn kind(&self) -> StoreKind {
        self.kind
    }

    /// The installed coverage.
    pub fn covered(&self) -> &Coverage {
        &self.covered
    }

    /// Install a shipped block of `rows × d` points at row `offset`.
    /// `n_hint` is the handshook dataset length — the dense variant
    /// allocates its full `max(n_hint, end) × d` matrix on first install
    /// (growing zero-filled past it when streaming ships beyond the
    /// handshook geometry); the sparse variant allocates only the touched
    /// 64-row blocks.
    pub fn install(&mut self, n_hint: usize, d: usize, offset: usize, block: &Matrix) {
        debug_assert_eq!(block.cols, d);
        let end = offset + block.rows;
        match self.kind {
            StoreKind::Dense => {
                let ds =
                    self.dense.get_or_insert_with(|| Dataset::new(Matrix::zeros(n_hint, d), None));
                if ds.points.rows < end {
                    ds.points.grow_rows(end);
                }
                ds.points.data[offset * d..end * d].copy_from_slice(&block.data);
                // Keep the point-norm cache coherent with the rows just
                // written (and grow it if the store grew past the
                // handshook geometry).
                ds.refresh_norms(offset, end);
            }
            StoreKind::Sparse => {
                let bs = self.sparse.get_or_insert_with(|| BlockStore::new(d));
                bs.install(offset, &block.data, block.rows);
            }
        }
        self.covered.add(offset..end);
    }

    /// Coverage-gated read view for a job's data need. `Ok(None)` when
    /// the job reads no points (no range, or an empty one — tail epochs);
    /// `Err` when any row of the range was never installed.
    pub fn view(&self, need: &Option<Range<usize>>) -> Result<Option<DataView<'_>>> {
        let Some(range) = need else { return Ok(None) };
        if range.start >= range.end {
            return Ok(None);
        }
        if !self.covered.covers(range) {
            return Err(Error::Coordinator(format!(
                "job range {}..{} not covered by shipped dataset blocks",
                range.start, range.end
            )));
        }
        match self.kind {
            StoreKind::Dense => {
                Ok(Some(DataView::Dense(self.dense.as_ref().expect("covered implies installed"))))
            }
            StoreKind::Sparse => {
                Ok(Some(DataView::Blocks(self.sparse.as_ref().expect("covered implies installed"))))
            }
        }
    }

    /// Resident point-storage bytes: the dense matrix's `rows × d × 4`,
    /// or the block store's `blocks × 64 × d × 4`. 0 before any install.
    pub fn resident_bytes(&self) -> u64 {
        match self.kind {
            StoreKind::Dense => self
                .dense
                .as_ref()
                .map(|ds| (ds.points.rows * ds.points.cols * 4) as u64)
                .unwrap_or(0),
            StoreKind::Sparse => self.sparse.as_ref().map(|bs| bs.resident_bytes()).unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn mat(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| (rng.next_f32() - 0.5) * 8.0).collect();
        Matrix::from_vec(rows, cols, data)
    }

    /// Gather a view's pieces back into one dense row-major buffer.
    fn materialize(view: &DataView<'_>, range: &Range<usize>) -> (Vec<f32>, Vec<f32>) {
        let d = view.dim();
        let mut points = Vec::new();
        let mut norms = Vec::new();
        let mut at = range.start;
        for (r, block) in view.pieces(range) {
            assert_eq!(r.start, at, "pieces must tile the range contiguously");
            assert_eq!(block.n, r.end - r.start);
            points.extend_from_slice(block.data);
            norms.extend_from_slice(block.norms.expect("store views carry norms"));
            at = r.end;
        }
        assert_eq!(at, range.end, "pieces must cover the whole range");
        assert_eq!(points.len(), (range.end - range.start) * d);
        (points, norms)
    }

    #[test]
    fn coverage_merges_and_answers() {
        let mut c = Coverage::default();
        c.add(10..20);
        c.add(30..40);
        assert!(c.covers(&(10..20)));
        assert!(!c.covers(&(10..21)));
        assert!(!c.covers(&(25..26)));
        assert!(c.covers(&(15..15))); // empty is always covered
        c.add(20..30); // adjacent: merges all three
        assert!(c.covers(&(10..40)));
        assert_eq!(c.missing(&(0..50)), vec![0..10, 40..50]);
        assert_eq!(c.max_end(), 40);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.missing(&(5..8)), vec![5..8]);
        assert_eq!(c.max_end(), 0);
    }

    #[test]
    fn coverage_counts_aligned_blocks_without_double_counting() {
        let mut c = Coverage::default();
        assert_eq!(c.aligned_blocks(64), 0);
        c.add(0..10);
        c.add(20..30); // same block as the first span
        assert_eq!(c.aligned_blocks(64), 1);
        c.add(60..70); // straddles blocks 0 and 1
        assert_eq!(c.aligned_blocks(64), 2);
        c.add(256..384); // blocks 4 and 5
        assert_eq!(c.aligned_blocks(64), 4);
        // Mirrors what a sparse store holding this coverage allocates.
        let mut bs = BlockStore::new(3);
        for s in [0..10, 20..30, 60..70, 256..384] {
            let m = mat(s.end - s.start, 3, s.start as u64 + 1);
            bs.install(s.start, &m.data, m.rows);
        }
        assert_eq!(bs.block_count(), c.aligned_blocks(64));
    }

    #[test]
    fn out_of_order_installs_read_back_bitwise() {
        // Spans installed out of order, at unaligned offsets, across
        // block boundaries — the view must read back the exact bytes
        // with canonical norms.
        let d = 5;
        let src = mat(400, d, 7);
        let mut ps = PeerStore::new(StoreKind::Sparse);
        for span in [200..340usize, 0..100, 100..200] {
            let rows = span.end - span.start;
            let m = Matrix::from_vec(rows, d, src.data[span.start * d..span.end * d].to_vec());
            ps.install(400, d, span.start, &m);
        }
        let range = 0..340;
        let view = ps.view(&Some(range.clone())).unwrap().unwrap();
        let (points, norms) = materialize(&view, &range);
        assert_eq!(points, src.data[..340 * d]);
        for (i, nrm) in norms.iter().enumerate() {
            assert_eq!(nrm.to_bits(), norm2(src.row(i)).to_bits(), "norm of row {i}");
        }
        // Per-point reads agree with the piece view.
        for i in [0usize, 63, 64, 199, 339] {
            assert_eq!(view.point(i), src.row(i));
        }
    }

    #[test]
    fn overlapping_reship_rewrites_rows_and_norms() {
        // A reconnect re-ships a span that partially overlaps an earlier
        // install with different bytes: the rewrite must win, rows *and*
        // norms, on both store variants.
        let d = 4;
        let first = mat(128, d, 11);
        let second = mat(96, d, 23);
        for kind in [StoreKind::Sparse, StoreKind::Dense] {
            let mut ps = PeerStore::new(kind);
            ps.install(128, d, 0, &first);
            ps.install(128, d, 32, &second); // rewrites rows 32..128
            let range = 0..128;
            let view = ps.view(&Some(range.clone())).unwrap().unwrap();
            let (points, norms) = materialize(&view, &range);
            assert_eq!(&points[..32 * d], &first.data[..32 * d]);
            assert_eq!(&points[32 * d..], &second.data[..]);
            for i in 0..128 {
                let expect = if i < 32 { norm2(first.row(i)) } else { norm2(second.row(i - 32)) };
                assert_eq!(norms[i].to_bits(), expect.to_bits(), "{:?} norm of row {i}", kind);
            }
        }
    }

    #[test]
    fn exact_coverage_gates_reads_on_both_variants() {
        // Readable ⇔ covered: a range is viewable exactly when every row
        // was installed. Uncovered gap rows (dense zero-fill between
        // installs) must be impossible to read — the norm-staleness fix
        // is structural, not numerical.
        let d = 3;
        for kind in [StoreKind::Sparse, StoreKind::Dense] {
            let mut ps = PeerStore::new(kind);
            let lo = mat(64, d, 3);
            let hi = mat(64, d, 4);
            ps.install(512, d, 0, &lo);
            ps.install(512, d, 256, &hi); // gap: 64..256 never installed
            assert!(ps.view(&Some(0..64)).unwrap().is_some());
            assert!(ps.view(&Some(256..320)).unwrap().is_some());
            assert!(ps.view(&None).unwrap().is_none());
            assert!(ps.view(&Some(10..10)).unwrap().is_none(), "empty range reads no points");
            for bad in [0..65, 63..70, 100..200, 200..300, 0..320] {
                let err = ps.view(&Some(bad.clone())).unwrap_err().to_string();
                assert!(err.contains("not covered"), "{:?} {:?}: {err}", kind, bad);
            }
        }
    }

    #[test]
    fn dense_gap_rows_unreadable_even_after_grow() {
        // The dense-path regression for the norm-coherence satellite: an
        // install past the handshook n grows the matrix, zero-filling the
        // gap and leaving gap norms unrefreshed (refresh_norms only
        // covers the installed span). Those rows must stay unreadable.
        let d = 2;
        let mut ps = PeerStore::new(StoreKind::Dense);
        ps.install(64, d, 0, &mat(64, d, 9));
        // Streaming grew the master's dataset: a block lands past n=64.
        ps.install(64, d, 192, &mat(32, d, 10));
        assert!(ps.view(&Some(0..64)).unwrap().is_some());
        assert!(ps.view(&Some(192..224)).unwrap().is_some());
        // The zero-filled grow region between 64 and 192 is not covered.
        assert!(ps.view(&Some(64..192)).unwrap_err().to_string().contains("not covered"));
        assert!(ps.view(&Some(0..224)).unwrap_err().to_string().contains("not covered"));
        assert_eq!(ps.resident_bytes(), (224 * d * 4) as u64, "dense resident is O(grown n)");
    }

    #[test]
    fn pieces_align_to_panel_boundaries() {
        // Sparse pieces break exactly at 64-row block boundaries (which
        // are panel boundaries by construction) and nowhere else.
        let d = 2;
        let src = mat(256, d, 31);
        let mut ps = PeerStore::new(StoreKind::Sparse);
        ps.install(256, d, 0, &src);
        let range = 10..250;
        let view = ps.view(&Some(range.clone())).unwrap().unwrap();
        let pieces = view.pieces(&range);
        assert_eq!(
            pieces.iter().map(|(r, _)| r.clone()).collect::<Vec<_>>(),
            vec![10..64, 64..128, 128..192, 192..250]
        );
        for (r, b) in &pieces {
            assert!(r.start == range.start || r.start % BLOCK_POINTS == 0);
            assert_eq!(b.n, r.end - r.start);
        }
        // The dense view is one unbroken slice — the pre-store shape.
        let ds = Dataset::new(src.clone(), None);
        let dense = DataView::Dense(&ds);
        assert_eq!(dense.pieces(&range).len(), 1);
        assert!(dense.pieces(&(0..0)).is_empty());
    }

    #[test]
    fn reconnect_reships_onto_a_fresh_store() {
        // A replacement session starts from an empty PeerStore and is
        // re-shipped its coverage: the fresh store must answer the same
        // ranges with the same bytes, and nothing beyond them.
        let d = 6;
        let src = mat(300, d, 17);
        let spans = [0..64usize, 128..300];
        let run = |kind: StoreKind| {
            let mut ps = PeerStore::new(kind);
            for s in &spans {
                let rows = s.end - s.start;
                let m = Matrix::from_vec(rows, d, src.data[s.start * d..s.end * d].to_vec());
                ps.install(300, d, s.start, &m);
            }
            ps
        };
        for kind in [StoreKind::Sparse, StoreKind::Dense] {
            let old = run(kind);
            let fresh = run(kind); // the re-ship, from Coverage::missing
            for s in &spans {
                let a = materialize(&old.view(&Some(s.clone())).unwrap().unwrap(), s);
                let b = materialize(&fresh.view(&Some(s.clone())).unwrap().unwrap(), s);
                assert_eq!(a, b);
            }
            assert!(fresh.view(&Some(64..128)).is_err());
        }
    }

    #[test]
    fn sparse_residency_is_o_covered_not_o_n() {
        let d = 8;
        let n = 4096;
        let mut sparse = PeerStore::new(StoreKind::Sparse);
        let mut dense = PeerStore::new(StoreKind::Dense);
        assert_eq!(sparse.resident_bytes(), 0);
        assert_eq!(dense.resident_bytes(), 0);
        let m = mat(256, d, 5);
        sparse.install(n, d, 1024, &m);
        dense.install(n, d, 1024, &m);
        assert_eq!(sparse.resident_bytes(), (256 * d * 4) as u64);
        assert_eq!(dense.resident_bytes(), (n * d * 4) as u64);
        assert!(sparse.resident_bytes() < dense.resident_bytes());
        // A partial block still costs one whole block.
        let mut ps = PeerStore::new(StoreKind::Sparse);
        ps.install(n, d, 10, &mat(4, d, 6));
        assert_eq!(ps.resident_bytes(), (BLOCK_POINTS * d * 4) as u64);
    }

    #[test]
    fn evict_below_drops_only_fully_sealed_blocks() {
        let d = 2;
        let src = mat(200, d, 41);
        let mut bs = BlockStore::new(d);
        bs.install(0, &src.data, 200); // blocks 0..=3
        assert_eq!(bs.block_count(), 4);
        bs.evict_below(100); // row 100 straddles block 1: it must survive
        assert_eq!(bs.block_count(), 3);
        assert_eq!(bs.point(100), src.row(100));
        assert_eq!(bs.point(199), src.row(199));
        bs.evict_below(128); // block-aligned bound drops block 1 exactly
        assert_eq!(bs.block_count(), 2);
        bs.evict_below(500);
        assert_eq!(bs.block_count(), 0);
        assert_eq!(bs.resident_bytes(), 0);
    }
}
