//! Synthetic workload generators — exactly the paper's §4 and App C.1 setups.
//!
//! * [`dp_clusters`] — Dirichlet-process mixture via on-the-fly
//!   stick-breaking (§4 "Clustering"): θ=1 sticks broken as new clusters are
//!   needed, cluster means `μ_k ~ N(0, I_D)`, points `x_i ~ N(μ_{z_i}, ¼I_D)`.
//! * [`bp_features`] — Beta-process latent features via the stick-breaking
//!   construction of Paisley et al. (§4 "Feature modeling"): features are
//!   pre-generated until the residual mass is `< 1e-4` w.h.p., feature means
//!   `f_k ~ N(0, I_D)`, points `x_i ~ N(Σ_k z_ik f_k, ¼I_D)`.
//! * [`separable_clusters`] — App C.1: cluster means `μ_k = (2k, 0, …, 0)`,
//!   points uniform in a radius-½ ball, so intra-cluster distances are ≤ 1
//!   and inter-cluster distances are > 1 (the Thm 3.3 regime with λ = 1).

use super::Dataset;
use crate::linalg::Matrix;
use crate::rng::distributions::{beta, uniform_in_ball, Normal};
use crate::rng::Pcg64;

/// Configuration shared by the generators.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Number of points to generate.
    pub n: usize,
    /// Dimensionality (paper: 16).
    pub dim: usize,
    /// Stick-breaking concentration θ (paper: 1.0).
    pub theta: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { n: 1024, dim: 16, theta: 1.0, seed: 0 }
    }
}

/// Dirichlet-process mixture data via on-the-fly stick-breaking.
///
/// Sticks are broken lazily: we keep the unbroken mass `rest`; a point
/// first samples whether it falls in an existing atom or the remainder, and
/// remainders recursively break new sticks — equivalent to sampling the full
/// stick-breaking weights upfront but needs only `K_N` sticks.
pub fn dp_clusters(cfg: &GenConfig) -> Dataset {
    let mut rng = Pcg64::with_stream(cfg.seed, 0xD1);
    let mut normal = Normal::new();
    let mut weights: Vec<f64> = Vec::new();
    let mut rest = 1.0f64;
    let mut means = Matrix::zeros(0, cfg.dim);
    let mut points = Matrix::zeros(0, cfg.dim);
    let mut labels = Vec::with_capacity(cfg.n);
    let mut buf = vec![0.0f32; cfg.dim];

    for _ in 0..cfg.n {
        // Sample the component (lazily extending sticks into `rest`).
        let mut u = rng.next_f64();
        let mut k = None;
        for (j, &w) in weights.iter().enumerate() {
            if u < w {
                k = Some(j);
                break;
            }
            u -= w;
        }
        let k = match k {
            Some(j) => j,
            None => loop {
                // Break a new stick: V ~ Beta(1, θ), w = V · rest.
                let v = beta(&mut rng, 1.0, cfg.theta);
                let w = v * rest;
                rest -= w;
                weights.push(w);
                // New cluster mean μ ~ N(0, I).
                normal.fill(&mut rng, 0.0, 1.0, &mut buf);
                means.push_row(&buf);
                if u < w {
                    break weights.len() - 1;
                }
                u -= w;
            },
        };
        // x ~ N(μ_k, ¼ I) i.e. std ½ per coordinate.
        let mu = means.row(k).to_vec();
        normal.fill(&mut rng, 0.0, 0.5, &mut buf);
        for (b, m) in buf.iter_mut().zip(&mu) {
            *b += m;
        }
        points.push_row(&buf);
        labels.push(k as u32);
    }
    Dataset::new(points, Some(labels))
}

/// Beta-process latent-feature data via truncated stick-breaking
/// (Paisley–Blei–Jordan). Feature inclusion probabilities are the BP
/// stick-breaking weights `π_k = Π_{j≤k} V_j`, `V_j ~ Beta(θ, 1)`;
/// truncation at `π_k < trunc_eps` leaves residual inclusion mass below
/// 1e-4 w.h.p. for θ = 1 (paper §4).
pub fn bp_features(cfg: &GenConfig) -> Dataset {
    bp_features_trunc(cfg, 1e-4)
}

/// [`bp_features`] with an explicit truncation threshold.
pub fn bp_features_trunc(cfg: &GenConfig, trunc_eps: f64) -> Dataset {
    let mut rng = Pcg64::with_stream(cfg.seed, 0xB7);
    let mut normal = Normal::new();
    // Stick-breaking feature probabilities π_k = Π V_j, V_j ~ Beta(θ, 1).
    let mut pis: Vec<f64> = Vec::new();
    let mut prod = 1.0f64;
    loop {
        let v = beta(&mut rng, cfg.theta, 1.0);
        prod *= v;
        if prod < trunc_eps || pis.len() >= 4096 {
            break;
        }
        pis.push(prod);
    }
    if pis.is_empty() {
        pis.push(trunc_eps);
    }
    let k = pis.len();
    // Feature means f_k ~ N(0, I).
    let mut feats = Matrix::zeros(0, cfg.dim);
    let mut buf = vec![0.0f32; cfg.dim];
    for _ in 0..k {
        normal.fill(&mut rng, 0.0, 1.0, &mut buf);
        feats.push_row(&buf);
    }

    let mut points = Matrix::zeros(0, cfg.dim);
    let mut labels = Vec::with_capacity(cfg.n);
    let mut mean = vec![0.0f32; cfg.dim];
    for _ in 0..cfg.n {
        mean.fill(0.0);
        // Binary feature indicators z_ik ~ Bernoulli(π_k); label = bitmask of
        // the first 32 features (enough to distinguish latent patterns: the
        // harnesses only use it to count distinct combinations).
        let mut mask = 0u32;
        let mut any = false;
        for (j, &pi) in pis.iter().enumerate() {
            if rng.bernoulli(pi) {
                crate::linalg::axpy(1.0, feats.row(j), &mut mean);
                if j < 32 {
                    mask |= 1 << j;
                }
                any = true;
            }
        }
        let _ = any;
        normal.fill(&mut rng, 0.0, 0.5, &mut buf);
        for (b, m) in buf.iter_mut().zip(&mean) {
            *b += m;
        }
        points.push_row(&buf);
        labels.push(mask);
    }
    Dataset::new(points, Some(labels))
}

/// App C.1 separable clusters: proportions from DP stick-breaking (θ),
/// means `μ_k = (2k, 0, …, 0)`, points uniform in the radius-½ ball around
/// their mean. All intra-cluster distances ≤ 1 < inter-cluster distances.
pub fn separable_clusters(cfg: &GenConfig) -> Dataset {
    let mut rng = Pcg64::with_stream(cfg.seed, 0x5E);
    let mut weights: Vec<f64> = Vec::new();
    let mut rest = 1.0f64;
    let mut points = Matrix::zeros(0, cfg.dim);
    let mut labels = Vec::with_capacity(cfg.n);
    let mut center = vec![0.0f32; cfg.dim];
    let mut buf = vec![0.0f32; cfg.dim];

    for _ in 0..cfg.n {
        let mut u = rng.next_f64();
        let mut k = None;
        for (j, &w) in weights.iter().enumerate() {
            if u < w {
                k = Some(j);
                break;
            }
            u -= w;
        }
        let k = match k {
            Some(j) => j,
            None => loop {
                let v = beta(&mut rng, 1.0, cfg.theta);
                let w = v * rest;
                rest -= w;
                weights.push(w);
                if u < w {
                    break weights.len() - 1;
                }
                u -= w;
            },
        };
        center.fill(0.0);
        center[0] = 2.0 * k as f32;
        uniform_in_ball(&mut rng, &center, 0.5, &mut buf);
        points.push_row(&buf);
        labels.push(k as u32);
    }
    Dataset::new(points, Some(labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sqdist;

    #[test]
    fn dp_clusters_shape_and_labels() {
        let cfg = GenConfig { n: 500, dim: 16, theta: 1.0, seed: 42 };
        let ds = dp_clusters(&cfg);
        assert_eq!(ds.len(), 500);
        assert_eq!(ds.dim(), 16);
        let k = ds.distinct_components(500).unwrap();
        // θ=1 ⇒ E[K_N] ≈ ln(N) ≈ 6.2; allow wide slack.
        assert!(k >= 2 && k <= 30, "k={k}");
        // Deterministic per seed.
        let ds2 = dp_clusters(&cfg);
        assert_eq!(ds.points.data, ds2.points.data);
        // Different seeds differ.
        let ds3 = dp_clusters(&GenConfig { seed: 43, ..cfg });
        assert_ne!(ds.points.data, ds3.points.data);
    }

    #[test]
    fn dp_points_near_their_cluster_mates() {
        // Points sharing a label should typically be closer than σ scales:
        // pairwise within-cluster squared distance has mean 2·D·¼ = 8 for
        // D=16; across clusters it adds ‖μ_a−μ_b‖² (mean 2·D = 32).
        let cfg = GenConfig { n: 400, dim: 16, theta: 1.0, seed: 7 };
        let ds = dp_clusters(&cfg);
        let labels = ds.labels.as_ref().unwrap();
        let mut within = (0.0f64, 0usize);
        let mut across = (0.0f64, 0usize);
        for i in 0..200 {
            for j in (i + 1)..200 {
                let d = sqdist(ds.point(i), ds.point(j)) as f64;
                if labels[i] == labels[j] {
                    within.0 += d;
                    within.1 += 1;
                } else {
                    across.0 += d;
                    across.1 += 1;
                }
            }
        }
        if within.1 > 10 && across.1 > 10 {
            assert!(within.0 / within.1 as f64 + 4.0 < across.0 / across.1 as f64);
        }
    }

    #[test]
    fn separable_clusters_truly_separable() {
        let cfg = GenConfig { n: 300, dim: 8, theta: 1.0, seed: 3 };
        let ds = separable_clusters(&cfg);
        let labels = ds.labels.as_ref().unwrap();
        for i in 0..100 {
            for j in (i + 1)..100 {
                let d2 = sqdist(ds.point(i), ds.point(j));
                if labels[i] == labels[j] {
                    assert!(d2 <= 1.0 + 1e-5, "within-cluster d²={d2}");
                } else {
                    assert!(d2 > 1.0, "across-cluster d²={d2}");
                }
            }
        }
    }

    #[test]
    fn bp_features_shapes_and_determinism() {
        let cfg = GenConfig { n: 200, dim: 16, theta: 1.0, seed: 11 };
        let ds = bp_features(&cfg);
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.dim(), 16);
        let ds2 = bp_features(&cfg);
        assert_eq!(ds.points.data, ds2.points.data);
        // Multiple distinct feature combinations should occur.
        let k = ds.distinct_components(200).unwrap();
        assert!(k >= 2, "k={k}");
    }

    #[test]
    fn bp_truncation_threshold_respected() {
        // With a loose threshold there are fewer features than with a tight
        // one — indirectly checks the truncation logic.
        let cfg = GenConfig { n: 50, dim: 4, theta: 1.0, seed: 9 };
        let loose = bp_features_trunc(&cfg, 1e-1);
        let tight = bp_features_trunc(&cfg, 1e-6);
        let kl = loose.distinct_components(50).unwrap();
        let kt = tight.distinct_components(50).unwrap();
        assert!(kl <= kt + 5, "loose {kl} vs tight {kt}");
    }
}
