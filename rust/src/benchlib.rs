//! Benchmark harness (no `criterion` offline).
//!
//! `cargo bench` runs each `[[bench]]` target with `harness = false`; the
//! targets use this module for warmup + repeated timing, robust statistics,
//! aligned table rendering and CSV output (so figures can be re-plotted).

use std::io::Write;
use std::time::{Duration, Instant};

/// Summary statistics of repeated timings.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Mean wall-clock per iteration.
    pub mean: Duration,
    /// Minimum.
    pub min: Duration,
    /// Maximum.
    pub max: Duration,
    /// Sample standard deviation.
    pub std: Duration,
    /// Iterations measured.
    pub iters: usize,
}

impl Stats {
    /// From raw samples.
    pub fn from_samples(samples: &[Duration]) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|s| (s.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        Stats {
            mean,
            min: *samples.iter().min().unwrap(),
            max: *samples.iter().max().unwrap(),
            std: Duration::from_secs_f64(var.sqrt()),
            iters: n,
        }
    }
}

/// Time `f` for `iters` measured iterations after `warmup` unmeasured ones.
pub fn time_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Stats::from_samples(&samples)
}

/// Human-friendly duration formatting.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// An aligned console table + CSV sink for bench results.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }
    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }
    /// Render aligned to stdout.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let render = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", render(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", render(row));
        }
    }
    /// Write as CSV.
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        f.flush()
    }
}

/// Parse trailing bench args of the form `--key=value`, returning lookups.
pub struct BenchArgs {
    args: Vec<String>,
}

impl BenchArgs {
    /// From `std::env::args` (skips the `--bench` flag cargo passes).
    pub fn from_env() -> BenchArgs {
        BenchArgs { args: std::env::args().skip(1).filter(|a| a != "--bench").collect() }
    }
    /// Value of `--key=value`.
    pub fn get(&self, key: &str) -> Option<&str> {
        let prefix = format!("--{key}=");
        self.args.iter().find_map(|a| a.strip_prefix(&prefix))
    }
    /// Parsed value with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    /// Boolean switch `--key`.
    pub fn has(&self, key: &str) -> bool {
        let flag = format!("--{key}");
        self.args.iter().any(|a| a == &flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_samples() {
        let s = Stats::from_samples(&[
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ]);
        assert_eq!(s.mean, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
        assert_eq!(s.iters, 3);
        assert!(s.std > Duration::from_millis(5));
    }

    #[test]
    fn time_fn_counts_iterations() {
        let mut count = 0;
        let s = time_fn(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn fmt_covers_ranges() {
        assert!(fmt_duration(Duration::from_secs(2)).contains("s"));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_micros(7)).contains("µs"));
    }

    #[test]
    fn table_renders_and_saves_csv() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
        let mut p = std::env::temp_dir();
        p.push(format!("occml-bench-{}.csv", std::process::id()));
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,bb\n1,2\n");
        std::fs::remove_file(&p).ok();
    }
}
