//! A miniature property-based testing framework (no `proptest` offline).
//!
//! Usage (`no_run` in rustdoc: doctest binaries miss the xla rpath):
//!
//! ```no_run
//! use occml::testing::{Prop, Arbitrary};
//! Prop::new("sum is commutative")
//!     .cases(64)
//!     .check(|g| {
//!         let a = g.usize_in(0, 100);
//!         let b = g.usize_in(0, 100);
//!         if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//!     });
//! ```
//!
//! On failure it reports the failing case's seed so the exact inputs can be
//! replayed with `Prop::replay(seed, f)`. A size-ramping schedule makes early
//! cases small (cheap shrink substitute: the smallest failing size is
//! reported first).

use crate::rng::Pcg64;

/// Per-case value generator handed to properties.
pub struct Gen {
    rng: Pcg64,
    /// Current size hint in [0, 1]; early cases are small.
    pub size: f64,
}

impl Gen {
    /// Underlying RNG (for custom generators).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
    /// Uniform usize in [lo, hi] (inclusive), scaled by the size ramp so
    /// early cases stay near `lo`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.next_below(span as u64 + 1) as usize
    }
    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }
    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }
    /// Coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    /// A vector of values from `f`.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }
}

/// Trait for types with a canonical generator.
pub trait Arbitrary: Sized {
    /// Generate one value.
    fn arbitrary(g: &mut Gen) -> Self;
}

impl Arbitrary for f32 {
    fn arbitrary(g: &mut Gen) -> Self {
        g.f32_in(-100.0, 100.0)
    }
}

impl Arbitrary for usize {
    fn arbitrary(g: &mut Gen) -> Self {
        g.usize_in(0, 1 << 16)
    }
}

/// A named property check.
pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
}

impl Prop {
    /// New property with default 100 cases.
    pub fn new(name: &'static str) -> Self {
        // Honor OCCML_PROP_SEED for reproducing CI failures.
        let seed = std::env::var("OCCML_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xA11CE);
        Prop { name, cases: 100, seed }
    }
    /// Set the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    /// Set the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Run the property; panics with seed + message on the first failure.
    pub fn check(self, mut f: impl FnMut(&mut Gen) -> Result<(), String>) {
        for case in 0..self.cases {
            let case_seed = self
                .seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(case as u64);
            // Ramp sizes: first quarter tiny, growing to full size.
            let size = ((case + 1) as f64 / (self.cases as f64 * 0.75)).min(1.0);
            let mut g = Gen { rng: Pcg64::with_stream(case_seed, 0x7e57), size };
            if let Err(msg) = f(&mut g) {
                panic!(
                    "property `{}` failed on case {case} (replay: Prop::replay({case_seed:#x}, f)):\n  {msg}",
                    self.name
                );
            }
        }
    }

    /// Re-run a single failing case by seed (full size).
    pub fn replay(case_seed: u64, mut f: impl FnMut(&mut Gen) -> Result<(), String>) {
        let mut g = Gen { rng: Pcg64::with_stream(case_seed, 0x7e57), size: 1.0 };
        if let Err(msg) = f(&mut g) {
            panic!("replayed case {case_seed:#x} failed:\n  {msg}");
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol && !(x.is_nan() && y.is_nan()) {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("add commutes").cases(50).check(|g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_seed() {
        Prop::new("always fails").cases(5).check(|_| Err("boom".into()));
    }

    #[test]
    fn size_ramp_starts_small() {
        let mut max_early = 0usize;
        let mut saw_large = false;
        let collected = std::cell::RefCell::new(Vec::new());
        Prop::new("sizes").cases(100).check(|g| {
            collected.borrow_mut().push(g.usize_in(0, 1000));
            Ok(())
        });
        let sizes = collected.into_inner();
        for &s in &sizes[..10] {
            max_early = max_early.max(s);
        }
        for &s in &sizes[50..] {
            if s > 500 {
                saw_large = true;
            }
        }
        assert!(max_early <= 200, "early sizes too big: {max_early}");
        assert!(saw_large, "never generated large cases");
    }

    #[test]
    fn allclose_checks() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-5, 1e-5).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-5).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first = Vec::new();
        Prop::new("det").cases(10).seed(99).check(|g| {
            first.push(g.usize_in(0, 1 << 20));
            Ok(())
        });
        let mut second = Vec::new();
        Prop::new("det").cases(10).seed(99).check(|g| {
            second.push(g.usize_in(0, 1 << 20));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
