//! Cholesky factorization and SPD solves.
//!
//! Used by BP-means' feature re-estimate `F ← (ZᵀZ + εI)⁻¹ ZᵀX` (Alg 6/7's
//! second phase). `ZᵀZ` is symmetric positive semi-definite; we add a small
//! ridge `ε` to guarantee positive definiteness when features are unused.

use super::Matrix;
use crate::error::{Error, Result};

/// In-place lower-Cholesky of a symmetric positive-definite `n×n` matrix
/// given in row-major `a`. Returns the lower factor `L` (upper left as-is is
/// overwritten; upper triangle zeroed).
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    if a.rows != a.cols {
        return Err(Error::shape(format!("cholesky needs square, got {}x{}", a.rows, a.cols)));
    }
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j) as f64;
            for k in 0..j {
                sum -= l.get(i, k) as f64 * l.get(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::Numerical(format!(
                        "cholesky: non-positive pivot {sum} at {i}"
                    )));
                }
                l.set(i, j, sum.sqrt() as f32);
            } else {
                l.set(i, j, (sum / l.get(j, j) as f64) as f32);
            }
        }
    }
    Ok(l)
}

/// Solve `A · X = B` for SPD `A` via Cholesky, where `B` is `n×m` row-major.
/// Returns `X` (`n×m`).
pub fn solve_spd(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    if a.rows != b.rows {
        return Err(Error::shape(format!("solve: A is {}x{}, B has {} rows", a.rows, a.cols, b.rows)));
    }
    let l = cholesky(a)?;
    let n = a.rows;
    let m = b.cols;
    let mut x = b.clone();
    // Forward: L y = b (column-wise over all rhs simultaneously).
    for i in 0..n {
        for c in 0..m {
            let mut v = x.get(i, c) as f64;
            for k in 0..i {
                v -= l.get(i, k) as f64 * x.get(k, c) as f64;
            }
            x.set(i, c, (v / l.get(i, i) as f64) as f32);
        }
    }
    // Backward: Lᵀ x = y.
    for i in (0..n).rev() {
        for c in 0..m {
            let mut v = x.get(i, c) as f64;
            for k in i + 1..n {
                v -= l.get(k, i) as f64 * x.get(k, c) as f64;
            }
            x.set(i, c, (v / l.get(i, i) as f64) as f32);
        }
    }
    Ok(x)
}

/// Solve the ridge-regularized normal equations `(G + εI) X = B`.
/// This is the entry point BP-means uses; `ε` keeps unused features benign.
pub fn solve_ridge(g: &Matrix, b: &Matrix, eps: f32) -> Result<Matrix> {
    let mut a = g.clone();
    for i in 0..a.rows.min(a.cols) {
        let v = a.get(i, i) + eps;
        a.set(i, i, v);
    }
    solve_spd(&a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_identity() {
        let mut a = Matrix::zeros(3, 3);
        for i in 0..3 {
            a.set(i, i, 1.0);
        }
        let l = cholesky(&a).unwrap();
        assert_eq!(l, a);
    }

    #[test]
    fn cholesky_known_factor() {
        // A = [[4,2],[2,3]] → L = [[2,0],[1,sqrt(2)]]
        let a = Matrix::from_vec(2, 2, vec![4.0, 2.0, 2.0, 3.0]);
        let l = cholesky(&a).unwrap();
        assert!((l.get(0, 0) - 2.0).abs() < 1e-6);
        assert!((l.get(1, 0) - 1.0).abs() < 1e-6);
        assert!((l.get(1, 1) - 2f32.sqrt()).abs() < 1e-6);
        assert_eq!(l.get(0, 1), 0.0);
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(cholesky(&a).is_err());
        let r = Matrix::from_vec(2, 3, vec![0.0; 6]);
        assert!(cholesky(&r).is_err());
    }

    #[test]
    fn solve_recovers_solution() {
        // A x = b with known x.
        let a = Matrix::from_vec(3, 3, vec![4.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 2.0]);
        let x_true = Matrix::from_vec(3, 2, vec![1.0, -1.0, 2.0, 0.5, -1.0, 3.0]);
        // b = A · x_true (A symmetric, row-major mult).
        let mut b = Matrix::zeros(3, 2);
        for i in 0..3 {
            for c in 0..2 {
                let mut v = 0.0;
                for k in 0..3 {
                    v += a.get(i, k) * x_true.get(k, c);
                }
                b.set(i, c, v);
            }
        }
        let x = solve_spd(&a, &b).unwrap();
        for i in 0..3 {
            for c in 0..2 {
                assert!((x.get(i, c) - x_true.get(i, c)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn ridge_handles_singular() {
        // G singular (zero row/col — an unused feature).
        let g = Matrix::from_vec(2, 2, vec![2.0, 0.0, 0.0, 0.0]);
        let b = Matrix::from_vec(2, 1, vec![2.0, 0.0]);
        let x = solve_ridge(&g, &b, 1e-6).unwrap();
        assert!((x.get(0, 0) - 1.0).abs() < 1e-3);
        assert!(x.get(1, 0).abs() < 1e-3);
    }
}
