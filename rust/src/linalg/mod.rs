//! Dense linear algebra substrate.
//!
//! Everything the algorithms need and nothing more: a row-major `f32`
//! [`Matrix`], the canonical squared-distance kernels (scalar here, the
//! cache-tiled panel variant in [`panel`]), and a Cholesky solver for the
//! BP-means feature re-estimate `F ← (ZᵀZ + εI)⁻¹ ZᵀX`.
//!
//! # Canonical reduction schedule
//!
//! Every distance the system compares — worker assignment kernels, the
//! serial baselines, validator pair caches, objectives — must be **bit
//! identical**, because OCC serializability (Pan et al., Thm 3.1) folds
//! worker-computed distances against master-recomputed ones and OFL's
//! send probability `min(d²/λ², 1)` feeds pre-drawn uniforms. One
//! reduction schedule is therefore defined here, once, and every path
//! routes through it:
//!
//! * [`dot`]`(a, b)`: eight strided f32 accumulators; element `j` is
//!   multiplied and added into lane `j mod 8` in increasing-`j` order;
//!   lanes combine as `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`. One lane
//!   block is one 8×f32 vector register, so this auto-vectorizes without
//!   the compiler needing (forbidden) reassociation.
//! * [`norm2`]`(a)` = `dot(a, a)`.
//! * [`sqdist_norms`]`(na, a, b, nb)` = `clamp⁰((na − 2·dot(a,b)) + nb)`
//!   where `clamp⁰(v)` is `v` if `v > 0.0` else `0.0` — the decomposed
//!   `‖a−b‖² = ‖a‖² − 2a·b + ‖b‖²` with exactly that association, clamped
//!   **per pair** (not per tile, not at write-back) so cached-norm kernels,
//!   the scalar reference, and any incremental argmin fold all compare the
//!   same clamped values.
//! * [`sqdist`]`(a, b)` = `sqdist_norms(norm2(a), a, b, norm2(b))` — the
//!   decomposed form is canonical even without a cache; subtract-then-
//!   square is **not** used anywhere distances are compared.
//! * [`nearest`]: strict `<` first-minimum over centers in increasing row
//!   order; no centers → `(usize::MAX, f32::INFINITY)`.
//!
//! For identical vectors the decomposed form is still exactly `0.0`:
//! `na = nb = dot = s`, `s − 2s = −s` exactly (power-of-two multiply),
//! `−s + s = +0.0`. Norm caches are pure memoization of [`norm2`], so a
//! kernel recomputing a missing norm is bit-identical to one reading it
//! from a cache.

pub mod blocked;
pub mod cholesky;
pub mod panel;

/// Row-major dense `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Empty matrix with storage pre-reserved for `rows` rows — use when
    /// the final row count (or a good bound) is known before a
    /// `push_row` loop, so growth never reallocates.
    pub fn with_row_capacity(rows: usize, cols: usize) -> Self {
        Matrix { rows: 0, cols, data: Vec::with_capacity(rows * cols) }
    }

    /// Build from existing row-major storage.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "storage length mismatch");
        Matrix { rows, cols, data }
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Append a row (grows the matrix).
    ///
    /// Growth doubles capacity explicitly, so `n` appends cost `O(n)`
    /// amortized with at most `log₂ n` reallocations — a `push_row` loop
    /// never degrades to quadratic copying even if the underlying `Vec`
    /// growth policy changes.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols);
        let need = self.data.len() + self.cols;
        if need > self.data.capacity() {
            let target = need.max(self.data.capacity().saturating_mul(2));
            self.data.reserve_exact(target - self.data.len());
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Grow to `new_rows` rows, zero-filling the new ones (no-op when
    /// already that tall). The single sanctioned way to extend a matrix
    /// in place — callers never touch `rows`/`data` bookkeeping — with
    /// the same explicit capacity doubling as [`Matrix::push_row`], so
    /// repeated small grows stay `O(n)` amortized.
    pub fn grow_rows(&mut self, new_rows: usize) {
        if new_rows <= self.rows {
            return;
        }
        let need = new_rows * self.cols;
        if need > self.data.capacity() {
            let target = need.max(self.data.capacity().saturating_mul(2));
            self.data.reserve_exact(target - self.data.len());
        }
        self.data.resize(need, 0.0);
        self.rows = new_rows;
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// `self · otherᵀ` — rows of both operands are treated as vectors.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "inner dims differ");
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a = self.row(i);
            let orow = out.row_mut(i);
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot(a, other.row(j));
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

/// Dot product of two equal-length slices under the canonical schedule:
/// eight strided f32 lanes (element `j` into lane `j mod 8`, increasing
/// `j`), combined `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut l = [0.0f32; 8];
    let mut i = 0;
    while i + 8 <= n {
        l[0] += a[i] * b[i];
        l[1] += a[i + 1] * b[i + 1];
        l[2] += a[i + 2] * b[i + 2];
        l[3] += a[i + 3] * b[i + 3];
        l[4] += a[i + 4] * b[i + 4];
        l[5] += a[i + 5] * b[i + 5];
        l[6] += a[i + 6] * b[i + 6];
        l[7] += a[i + 7] * b[i + 7];
        i += 8;
    }
    let mut j = 0;
    while i < n {
        l[j] += a[i] * b[i];
        i += 1;
        j += 1;
    }
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Squared L2 norm under the canonical schedule: `dot(a, a)`.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Canonical squared distance given both precomputed norms:
/// `clamp⁰((na − 2·dot(a,b)) + nb)`. The clamp is per pair — every
/// comparison anywhere in the system sees this clamped value.
#[inline]
pub fn sqdist_norms(na: f32, a: &[f32], b: &[f32], nb: f32) -> f32 {
    let v = (na - 2.0 * dot(a, b)) + nb;
    if v > 0.0 {
        v
    } else {
        0.0
    }
}

/// Canonical squared Euclidean distance: the decomposed clamped form with
/// norms computed on the spot. Bit-identical to any cached-norm path.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    sqdist_norms(norm2(a), a, b, norm2(b))
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Nearest row of `centers` to `x`: returns `(index, squared distance)`.
/// Strict `<` first-minimum in increasing row order (the canonical
/// tie-break); `centers.rows == 0` returns `(usize::MAX, f32::INFINITY)`.
#[inline]
pub fn nearest(x: &[f32], centers: &Matrix) -> (usize, f32) {
    let mut best = usize::MAX;
    let mut best_d = f32::INFINITY;
    for k in 0..centers.rows {
        let d = sqdist(x, centers.row(k));
        if d < best_d {
            best_d = d;
            best = k;
        }
    }
    (best, best_d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_sqdist_match_naive() {
        let a: Vec<f32> = (0..19).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..19).map(|i| (i as f32).sin()).collect();
        let nd: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - nd).abs() < 1e-3);
        let ns: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((sqdist(&a, &b) - ns).abs() < 1e-3);
    }

    #[test]
    fn sqdist_of_identical_vectors_is_exactly_zero() {
        // na = nb = dot = s; s − 2s = −s exactly; −s + s = +0.0.
        for v in [
            vec![0.1f32, -2.5, 3e7, 1e-40],
            vec![16777216.0f32],
            vec![0.0f32, -0.0],
        ] {
            assert_eq!(sqdist(&v, &v), 0.0);
            assert_eq!(sqdist(&v, &v).to_bits(), 0.0f32.to_bits());
        }
    }

    #[test]
    fn sqdist_clamps_negative_cancellation_to_zero() {
        // Nearly-identical large-magnitude vectors: the true distance is
        // ~ULP², far below the rounding noise of the three big reduction
        // terms, so the unclamped decomposed value lands on either side
        // of zero depending on rounding. The sweep must hit genuinely
        // negative raw values (else this regression test has gone stale),
        // and the clamp must floor every one of them at exactly 0.0.
        let mut rng = crate::rng::Pcg64::new(7);
        let mut saw_negative = false;
        for _ in 0..256 {
            let a: Vec<f32> = (0..8).map(|_| (rng.next_f32() - 0.5) * 2e4).collect();
            let mut b = a.clone();
            // Nudge one element by one ULP.
            b[7] = f32::from_bits(b[7].to_bits() + 1);
            let raw = (norm2(&a) - 2.0 * dot(&a, &b)) + norm2(&b);
            saw_negative |= raw < 0.0;
            let d = sqdist(&a, &b);
            assert!(d >= 0.0);
            let expect = if raw > 0.0 { raw } else { 0.0 };
            assert_eq!(d.to_bits(), expect.to_bits());
        }
        assert!(saw_negative, "sweep never produced a negative raw distance");
    }

    #[test]
    fn sqdist_handles_signed_zero_and_subnormals() {
        assert_eq!(sqdist(&[0.0f32], &[-0.0f32]), 0.0);
        let sub = f32::MIN_POSITIVE / 2.0; // subnormal
        let d = sqdist(&[sub], &[0.0f32]);
        assert!(d >= 0.0 && d.is_finite());
        // Cached-norm path is bit-identical to the on-the-spot path.
        let a = [sub, -0.0, 1.5e-39];
        let b = [0.0f32, sub, -1.5e-39];
        let cached = sqdist_norms(norm2(&a), &a, &b, norm2(&b));
        assert_eq!(cached.to_bits(), sqdist(&a, &b).to_bits());
    }

    #[test]
    fn matrix_rows_and_push() {
        let mut m = Matrix::zeros(0, 3);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.rows, 2);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2), 3.0);
    }

    #[test]
    fn push_row_grows_capacity_geometrically() {
        let mut m = Matrix::zeros(0, 8);
        let mut caps = std::collections::BTreeSet::new();
        for i in 0..1024 {
            m.push_row(&[i as f32; 8]);
            caps.insert(m.data.capacity());
        }
        // Doubling growth: ~log₂(1024·8) distinct capacities, not O(n).
        assert!(caps.len() <= 14, "push_row reallocated {} times", caps.len());
        // Pre-sized matrices never reallocate.
        let mut pre = Matrix::with_row_capacity(1024, 8);
        let cap0 = pre.data.capacity();
        for i in 0..1024 {
            pre.push_row(&[i as f32; 8]);
        }
        assert_eq!(pre.data.capacity(), cap0);
        assert_eq!(pre.rows, 1024);
    }

    #[test]
    fn grow_rows_zero_fills_and_reserves_geometrically() {
        let mut m = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        m.grow_rows(4);
        assert_eq!(m.rows, 4);
        assert_eq!(m.data.len(), 12);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(3), &[0.0, 0.0, 0.0]);
        // Shrinking and same-size calls are no-ops.
        m.grow_rows(2);
        m.grow_rows(4);
        assert_eq!(m.rows, 4);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        // Repeated one-row grows reallocate O(log n) times, like push_row.
        let mut g = Matrix::zeros(0, 8);
        let mut caps = std::collections::BTreeSet::new();
        for r in 1..=1024 {
            g.grow_rows(r);
            caps.insert(g.data.capacity());
        }
        assert!(caps.len() <= 14, "grow_rows reallocated {} times", caps.len());
    }

    #[test]
    fn matmul_nt_small() {
        // a = [[1,2],[3,4]]; b = [[1,0],[0,1],[1,1]] (rows as vectors)
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_nt(&b);
        assert_eq!(c.rows, 2);
        assert_eq!(c.cols, 3);
        assert_eq!(c.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.row(1), &[3.0, 4.0, 7.0]);
    }

    #[test]
    fn nearest_picks_minimum_and_breaks_ties_low() {
        let mut c = Matrix::zeros(0, 2);
        c.push_row(&[0.0, 0.0]);
        c.push_row(&[10.0, 0.0]);
        c.push_row(&[0.0, 3.0]);
        let (k, d) = nearest(&[0.5, 2.9], &c);
        assert_eq!(k, 2);
        assert!((d - (0.25 + 0.01)).abs() < 1e-4);
        let empty = Matrix::zeros(0, 2);
        let (k, d) = nearest(&[0.0, 0.0], &empty);
        assert_eq!(k, usize::MAX);
        assert!(d.is_infinite());
        // Duplicate rows: strict < keeps the first minimum.
        let dup = Matrix::from_vec(2, 2, vec![1.0, 0.0, 1.0, 0.0]);
        assert_eq!(nearest(&[0.0, 0.0], &dup).0, 0);
    }

    #[test]
    fn axpy_works() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 14.0, 16.0]);
    }
}
