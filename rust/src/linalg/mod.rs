//! Dense linear algebra substrate.
//!
//! Everything the algorithms need and nothing more: a row-major `f32`
//! [`Matrix`], squared-distance kernels (scalar and blocked — the native
//! backend's hot path), and a Cholesky solver for the BP-means feature
//! re-estimate `F ← (ZᵀZ + εI)⁻¹ ZᵀX`.

pub mod blocked;
pub mod cholesky;

/// Row-major dense `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from existing row-major storage.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "storage length mismatch");
        Matrix { rows, cols, data }
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Append a row (grows the matrix).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// `self · otherᵀ` — rows of both operands are treated as vectors.
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "inner dims differ");
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a = self.row(i);
            let orow = out.row_mut(i);
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot(a, other.row(j));
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

/// Dot product of two equal-length slices (f64 accumulator).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    // 4-way unrolled: the compiler auto-vectorizes this reliably.
    let mut i = 0;
    let n = a.len();
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    while i + 4 <= n {
        acc0 += a[i] * b[i];
        acc1 += a[i + 1] * b[i + 1];
        acc2 += a[i + 2] * b[i + 2];
        acc3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    while i < n {
        acc += a[i] * b[i];
        i += 1;
    }
    acc + acc0 + acc1 + acc2 + acc3
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut i = 0;
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    while i + 4 <= n {
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        acc0 += d0 * d0;
        acc1 += d1 * d1;
        acc2 += d2 * d2;
        acc3 += d3 * d3;
        i += 4;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    while i < n {
        let d = a[i] - b[i];
        acc += d * d;
        i += 1;
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Squared L2 norm.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Nearest row of `centers` to `x`: returns `(index, squared distance)`.
/// `centers.rows == 0` returns `(usize::MAX, f32::INFINITY)`.
#[inline]
pub fn nearest(x: &[f32], centers: &Matrix) -> (usize, f32) {
    let mut best = usize::MAX;
    let mut best_d = f32::INFINITY;
    for k in 0..centers.rows {
        let d = sqdist(x, centers.row(k));
        if d < best_d {
            best_d = d;
            best = k;
        }
    }
    (best, best_d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_sqdist_match_naive() {
        let a: Vec<f32> = (0..19).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..19).map(|i| (i as f32).sin()).collect();
        let nd: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - nd).abs() < 1e-3);
        let ns: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((sqdist(&a, &b) - ns).abs() < 1e-3);
    }

    #[test]
    fn matrix_rows_and_push() {
        let mut m = Matrix::zeros(0, 3);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.rows, 2);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2), 3.0);
    }

    #[test]
    fn matmul_nt_small() {
        // a = [[1,2],[3,4]]; b = [[1,0],[0,1],[1,1]] (rows as vectors)
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_nt(&b);
        assert_eq!(c.rows, 2);
        assert_eq!(c.cols, 3);
        assert_eq!(c.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.row(1), &[3.0, 4.0, 7.0]);
    }

    #[test]
    fn nearest_picks_minimum() {
        let mut c = Matrix::zeros(0, 2);
        c.push_row(&[0.0, 0.0]);
        c.push_row(&[10.0, 0.0]);
        c.push_row(&[0.0, 3.0]);
        let (k, d) = nearest(&[0.5, 2.9], &c);
        assert_eq!(k, 2);
        assert!((d - (0.25 + 0.01)).abs() < 1e-4);
        let empty = Matrix::zeros(0, 2);
        let (k, d) = nearest(&[0.0, 0.0], &empty);
        assert_eq!(k, usize::MAX);
        assert!(d.is_infinite());
    }

    #[test]
    fn axpy_works() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 14.0, 16.0]);
    }
}
