//! Blocked distance kernels — the native backend's hot path.
//!
//! Computing `argmin_k ‖x_i − μ_k‖²` for a block of points against all
//! centers is the dominant compute of every algorithm in the paper (workers
//! spend N/P · K · D flops per pass on it). The blocked kernel uses the
//! classical decomposition
//!
//! ```text
//! ‖x − μ‖² = ‖x‖² − 2·x·μ + ‖μ‖²
//! ```
//!
//! so the inner loop is a small GEMM tile (points×centers), which the
//! compiler vectorizes, and stays in L1/L2 cache — the same structure the
//! L1 Pallas kernel uses to hit the MXU on TPU.

use super::Matrix;

/// Borrowed row-major view used by the raw kernel entry point.
struct RawView<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
}

impl<'a> RawView<'a> {
    #[inline]
    fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

/// Tile sizes chosen so that a (TP×D + TK×D + TP×TK) f32 working set fits
/// comfortably in a 32 KiB L1d for D ≤ 64.
const TILE_POINTS: usize = 64;
const TILE_CENTERS: usize = 32;

/// For each row of `points`, the index and squared distance of the nearest
/// row of `centers`. Writes into `out_idx` / `out_d2` (must be `points.rows`
/// long). `centers.rows == 0` yields `u32::MAX` / `+inf`.
pub fn nearest_blocked(
    points: &Matrix,
    centers: &Matrix,
    out_idx: &mut [u32],
    out_d2: &mut [f32],
) {
    nearest_blocked_raw(&points.data, points.rows, points.cols, centers, out_idx, out_d2)
}

/// [`nearest_blocked`] over a raw row-major slice — lets callers pass a
/// sub-range of a larger matrix without copying (the native backend's hot
/// path does exactly that every epoch).
pub fn nearest_blocked_raw(
    pdata: &[f32],
    prows: usize,
    pcols: usize,
    centers: &Matrix,
    out_idx: &mut [u32],
    out_d2: &mut [f32],
) {
    let points = RawView { data: pdata, rows: prows, cols: pcols };
    assert_eq!(points.cols, centers.cols, "dimension mismatch");
    assert_eq!(pdata.len(), prows * pcols, "raw view length mismatch");
    assert_eq!(out_idx.len(), points.rows);
    assert_eq!(out_d2.len(), points.rows);
    out_idx.fill(u32::MAX);
    out_d2.fill(f32::INFINITY);
    if centers.rows == 0 || points.rows == 0 {
        return;
    }
    let d = points.cols;

    // Precompute center norms once per call.
    let mut cnorm = vec![0.0f32; centers.rows];
    for (k, cn) in cnorm.iter_mut().enumerate() {
        *cn = super::norm2(centers.row(k));
    }

    // Center tile packed d-major (`ct[dd*TILE_CENTERS + j] = μ_{k0+j}[dd]`)
    // so the rank-1-update microkernel below reads contiguously and the
    // compiler vectorizes the j-loop with FMA — ~6× over a dot-per-pair
    // formulation (EXPERIMENTS.md §Perf).
    let mut ct = vec![0.0f32; TILE_CENTERS * pcols];
    let mut acc = [0.0f32; TILE_CENTERS];

    let mut k0 = 0;
    while k0 < centers.rows {
        let kn = TILE_CENTERS.min(centers.rows - k0);
        // Pack the center tile once per k0 (amortized over all points).
        for dd in 0..d {
            let dst = &mut ct[dd * TILE_CENTERS..dd * TILE_CENTERS + kn];
            for (jj, t) in dst.iter_mut().enumerate() {
                *t = centers.get(k0 + jj, dd);
            }
        }
        let mut p0 = 0;
        while p0 < points.rows {
            let pn = TILE_POINTS.min(points.rows - p0);
            for i in 0..pn {
                let x = points.row(p0 + i);
                // acc[j] = x · μ_{k0+j} via d rank-1 updates; the inner loop
                // is a contiguous fused multiply-add over TILE_CENTERS lanes.
                let a = &mut acc[..TILE_CENTERS];
                a.fill(0.0);
                for (dd, &xv) in x.iter().enumerate() {
                    let crow = &ct[dd * TILE_CENTERS..(dd + 1) * TILE_CENTERS];
                    for j in 0..TILE_CENTERS {
                        a[j] += xv * crow[j];
                    }
                }
                // Combine: d² = ‖x‖² − 2·dot + ‖μ‖², fused argmin.
                let base = super::norm2(x);
                let mut best = out_d2[p0 + i];
                let mut best_k = out_idx[p0 + i];
                for (jj, &t) in a.iter().take(kn).enumerate() {
                    let d2 = base - 2.0 * t + cnorm[k0 + jj];
                    if d2 < best {
                        best = d2;
                        best_k = (k0 + jj) as u32;
                    }
                }
                // Clamp tiny negatives from cancellation.
                out_d2[p0 + i] = if best < 0.0 { 0.0 } else { best };
                out_idx[p0 + i] = best_k;
            }
            p0 += pn;
        }
        k0 += kn;
    }
}

/// Sufficient statistics for the mean-recompute phase: per-center sums and
/// counts, accumulated from `points` with assignment `idx`. `sums` must be
/// `k × d` zeroed (or partial — this *accumulates*), `counts` length `k`.
/// Assignments `>= k as u32` (e.g. `u32::MAX` for unassigned) are skipped.
pub fn suffstats_accumulate(
    points: &Matrix,
    idx: &[u32],
    sums: &mut Matrix,
    counts: &mut [u64],
) {
    assert_eq!(idx.len(), points.rows);
    assert_eq!(sums.cols, points.cols);
    assert_eq!(counts.len(), sums.rows);
    let k = sums.rows as u32;
    for (i, &a) in idx.iter().enumerate() {
        if a >= k {
            continue;
        }
        counts[a as usize] += 1;
        super::axpy(1.0, points.row(i), sums.row_mut(a as usize));
    }
}

/// Finalize means from accumulated sufficient statistics, writing into
/// `centers`. Centers with zero count are left untouched.
pub fn finalize_means(sums: &Matrix, counts: &[u64], centers: &mut Matrix) {
    assert_eq!(sums.rows, centers.rows);
    assert_eq!(sums.cols, centers.cols);
    for kk in 0..sums.rows {
        let c = counts[kk];
        if c == 0 {
            continue;
        }
        let inv = 1.0 / c as f32;
        let src = sums.row(kk);
        for (dst, s) in centers.row_mut(kk).iter_mut().zip(src) {
            *dst = s * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{nearest, Matrix};
    use crate::rng::Pcg64;

    fn random_matrix(rng: &mut Pcg64, rows: usize, cols: usize) -> Matrix {
        let data = (0..rows * cols).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        Matrix::from_vec(rows, cols, data)
    }

    #[test]
    fn blocked_matches_scalar_nearest() {
        let mut rng = Pcg64::new(17);
        for &(n, k, d) in &[(1, 1, 1), (7, 3, 5), (130, 70, 16), (257, 33, 16), (64, 32, 24)] {
            let pts = random_matrix(&mut rng, n, d);
            let ctr = random_matrix(&mut rng, k, d);
            let mut idx = vec![0u32; n];
            let mut d2 = vec![0.0f32; n];
            nearest_blocked(&pts, &ctr, &mut idx, &mut d2);
            for i in 0..n {
                let (bi, bd) = nearest(pts.row(i), &ctr);
                // Tie-breaking can differ; distances must match.
                assert!(
                    (d2[i] - bd).abs() < 1e-3 * (1.0 + bd.abs()),
                    "n={n} k={k} i={i}: blocked {} vs scalar {}",
                    d2[i],
                    bd
                );
                let d_via_idx = crate::linalg::sqdist(pts.row(i), ctr.row(idx[i] as usize));
                assert!((d_via_idx - bd).abs() < 1e-3 * (1.0 + bd.abs()));
                let _ = bi;
            }
        }
    }

    #[test]
    fn empty_centers_yield_infinity() {
        let pts = Matrix::from_vec(3, 2, vec![0.0; 6]);
        let ctr = Matrix::zeros(0, 2);
        let mut idx = vec![0u32; 3];
        let mut d2 = vec![0.0f32; 3];
        nearest_blocked(&pts, &ctr, &mut idx, &mut d2);
        assert!(idx.iter().all(|&i| i == u32::MAX));
        assert!(d2.iter().all(|&d| d.is_infinite()));
    }

    #[test]
    fn suffstats_and_means() {
        let pts = Matrix::from_vec(4, 2, vec![1.0, 0.0, 3.0, 0.0, 0.0, 2.0, 0.0, 4.0]);
        let idx = vec![0u32, 0, 1, 1];
        let mut sums = Matrix::zeros(2, 2);
        let mut counts = vec![0u64; 2];
        suffstats_accumulate(&pts, &idx, &mut sums, &mut counts);
        assert_eq!(counts, vec![2, 2]);
        assert_eq!(sums.row(0), &[4.0, 0.0]);
        assert_eq!(sums.row(1), &[0.0, 6.0]);
        let mut centers = Matrix::zeros(2, 2);
        finalize_means(&sums, &counts, &mut centers);
        assert_eq!(centers.row(0), &[2.0, 0.0]);
        assert_eq!(centers.row(1), &[0.0, 3.0]);
    }

    #[test]
    fn suffstats_skips_unassigned() {
        let pts = Matrix::from_vec(2, 1, vec![5.0, 7.0]);
        let idx = vec![u32::MAX, 0];
        let mut sums = Matrix::zeros(1, 1);
        let mut counts = vec![0u64; 1];
        suffstats_accumulate(&pts, &idx, &mut sums, &mut counts);
        assert_eq!(counts[0], 1);
        assert_eq!(sums.get(0, 0), 7.0);
    }

    #[test]
    fn zero_count_center_untouched() {
        let sums = Matrix::zeros(1, 2);
        let counts = vec![0u64];
        let mut centers = Matrix::from_vec(1, 2, vec![9.0, 9.0]);
        finalize_means(&sums, &counts, &mut centers);
        assert_eq!(centers.row(0), &[9.0, 9.0]);
    }
}
