//! Suffstats reduction helpers for the mean-recompute phase.
//!
//! The assignment distance kernels formerly here moved to [`super::panel`]
//! when the canonical reduction schedule was defined (this file's old
//! tile-level clamp and rank-1-update reduction order were *not*
//! bit-identical to the scalar path — the panel kernels are, by
//! construction). What remains is the suffstats accumulate/finalize pair
//! shared by the serial algorithms and the native backend.

use super::Matrix;

/// Sufficient statistics for the mean-recompute phase: per-center sums and
/// counts, accumulated from `points` with assignment `idx`. `sums` must be
/// `k × d` zeroed (or partial — this *accumulates*), `counts` length `k`.
/// Assignments `>= k as u32` (e.g. `u32::MAX` for unassigned) are skipped.
pub fn suffstats_accumulate(
    points: &Matrix,
    idx: &[u32],
    sums: &mut Matrix,
    counts: &mut [u64],
) {
    assert_eq!(idx.len(), points.rows);
    assert_eq!(sums.cols, points.cols);
    assert_eq!(counts.len(), sums.rows);
    let k = sums.rows as u32;
    for (i, &a) in idx.iter().enumerate() {
        if a >= k {
            continue;
        }
        counts[a as usize] += 1;
        super::axpy(1.0, points.row(i), sums.row_mut(a as usize));
    }
}

/// Finalize means from accumulated sufficient statistics, writing into
/// `centers`. Centers with zero count are left untouched.
pub fn finalize_means(sums: &Matrix, counts: &[u64], centers: &mut Matrix) {
    assert_eq!(sums.rows, centers.rows);
    assert_eq!(sums.cols, centers.cols);
    for kk in 0..sums.rows {
        let c = counts[kk];
        if c == 0 {
            continue;
        }
        let inv = 1.0 / c as f32;
        let src = sums.row(kk);
        for (dst, s) in centers.row_mut(kk).iter_mut().zip(src) {
            *dst = s * inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn suffstats_and_means() {
        let pts = Matrix::from_vec(4, 2, vec![1.0, 0.0, 3.0, 0.0, 0.0, 2.0, 0.0, 4.0]);
        let idx = vec![0u32, 0, 1, 1];
        let mut sums = Matrix::zeros(2, 2);
        let mut counts = vec![0u64; 2];
        suffstats_accumulate(&pts, &idx, &mut sums, &mut counts);
        assert_eq!(counts, vec![2, 2]);
        assert_eq!(sums.row(0), &[4.0, 0.0]);
        assert_eq!(sums.row(1), &[0.0, 6.0]);
        let mut centers = Matrix::zeros(2, 2);
        finalize_means(&sums, &counts, &mut centers);
        assert_eq!(centers.row(0), &[2.0, 0.0]);
        assert_eq!(centers.row(1), &[0.0, 3.0]);
    }

    #[test]
    fn suffstats_skips_unassigned() {
        let pts = Matrix::from_vec(2, 1, vec![5.0, 7.0]);
        let idx = vec![u32::MAX, 0];
        let mut sums = Matrix::zeros(1, 1);
        let mut counts = vec![0u64; 1];
        suffstats_accumulate(&pts, &idx, &mut sums, &mut counts);
        assert_eq!(counts[0], 1);
        assert_eq!(sums.get(0, 0), 7.0);
    }

    #[test]
    fn zero_count_center_untouched() {
        let sums = Matrix::zeros(1, 2);
        let counts = vec![0u64];
        let mut centers = Matrix::from_vec(1, 2, vec![9.0, 9.0]);
        finalize_means(&sums, &counts, &mut centers);
        assert_eq!(centers.row(0), &[9.0, 9.0]);
    }
}
