//! Cache-tiled assignment kernels over the canonical reduction schedule.
//!
//! [`nearest_panel`] tiles a panel of [`PANEL_POINTS`] points against
//! center tiles of [`TILE_CENTERS`] rows, so each center tile (≤ 32 rows
//! of f32) is pulled into L1 once per *panel* instead of once per
//! *point* — for a `k×d` snapshot larger than L1/L2 this cuts center
//! traffic by `PANEL_POINTS×`. Per (point, center) pair it evaluates
//! exactly [`super::sqdist_norms`] — the decomposed clamped form over the
//! canonical 8-lane [`super::dot`] — and folds the first minimum with
//! strict `<` in increasing center order, visiting tiles in increasing
//! row order. Tiling therefore changes only the *memory traversal*, never
//! the arithmetic or the compare order: [`nearest_panel`] is bit-identical
//! to [`nearest_scalar`] (the same-schedule reference kept as the
//! `kernel = "scalar"` A/B baseline) and to a per-point
//! [`super::nearest`] loop, by construction.
//!
//! Norms are pure memoization: a caller holding per-point norms (computed
//! once at dataset-block arrival) or per-center norms (a [`NormCache`]
//! extended incrementally on snapshot deltas) passes them in; a caller
//! without them passes `None` and the kernel recomputes with the same
//! [`super::norm2`] — bit-identical either way.

use super::{norm2, sqdist_norms, Matrix};
use std::borrow::Cow;

/// Points per panel: 64 rows keep a `d ≤ 64` panel (≤ 16 KiB) L1-resident
/// alongside one center tile. Job splits align to this so only range-end
/// panels are partial.
pub const PANEL_POINTS: usize = 64;

/// Centers per tile: 32 rows × `d ≤ 64` × 4 B ≤ 8 KiB — comfortably
/// L1-resident while the point panel streams through it.
pub const TILE_CENTERS: usize = 32;

/// Canonical norms for each row of a row-major slice.
pub fn point_norms(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    (0..rows).map(|i| norm2(&data[i * cols..(i + 1) * cols])).collect()
}

/// Canonical norms for each row of `m`.
pub fn center_norms(m: &Matrix) -> Vec<f32> {
    point_norms(&m.data, m.rows, m.cols)
}

fn resolve<'a>(cached: Option<&'a [f32]>, data: &[f32], rows: usize, cols: usize) -> Cow<'a, [f32]> {
    match cached {
        Some(v) => {
            debug_assert!(v.len() >= rows);
            Cow::Borrowed(v)
        }
        None => Cow::Owned(point_norms(data, rows, cols)),
    }
}

/// Tiled nearest-center assignment over a raw row-major point slice.
///
/// `pnorms`/`cnorms` are optional memoized [`norm2`] rows (recomputed
/// bit-identically when absent). Empty centers yield
/// `(u32::MAX, f32::INFINITY)` per point.
#[allow(clippy::too_many_arguments)]
pub fn nearest_panel_raw(
    pdata: &[f32],
    prows: usize,
    pcols: usize,
    pnorms: Option<&[f32]>,
    centers: &Matrix,
    cnorms: Option<&[f32]>,
    out_idx: &mut [u32],
    out_d2: &mut [f32],
) {
    debug_assert_eq!(out_idx.len(), prows);
    debug_assert_eq!(out_d2.len(), prows);
    out_idx.fill(u32::MAX);
    out_d2.fill(f32::INFINITY);
    if prows == 0 || centers.rows == 0 {
        return;
    }
    debug_assert_eq!(centers.cols, pcols);
    let d = pcols;
    let pn = resolve(pnorms, pdata, prows, d);
    let cn = resolve(cnorms, &centers.data, centers.rows, d);
    let mut p0 = 0;
    while p0 < prows {
        let p1 = (p0 + PANEL_POINTS).min(prows);
        // Center tiles in increasing row order: for every point the
        // global visit order over j is 0..k, so the strict-< fold picks
        // the same first minimum as a flat scalar loop.
        let mut k0 = 0;
        while k0 < centers.rows {
            let k1 = (k0 + TILE_CENTERS).min(centers.rows);
            for i in p0..p1 {
                let x = &pdata[i * d..(i + 1) * d];
                let xn = pn[i];
                let mut bi = out_idx[i];
                let mut bd = out_d2[i];
                for j in k0..k1 {
                    let dist = sqdist_norms(xn, x, centers.row(j), cn[j]);
                    if dist < bd {
                        bd = dist;
                        bi = j as u32;
                    }
                }
                out_idx[i] = bi;
                out_d2[i] = bd;
            }
            k0 = k1;
        }
        p0 = p1;
    }
}

/// [`nearest_panel_raw`] over a [`Matrix`] of points.
pub fn nearest_panel(
    points: &Matrix,
    pnorms: Option<&[f32]>,
    centers: &Matrix,
    cnorms: Option<&[f32]>,
    out_idx: &mut [u32],
    out_d2: &mut [f32],
) {
    nearest_panel_raw(&points.data, points.rows, points.cols, pnorms, centers, cnorms, out_idx, out_d2)
}

/// The same-schedule scalar reference: one flat point-major loop, the
/// identical per-pair [`sqdist_norms`] and strict-< fold. Bit-identical
/// to [`nearest_panel_raw`]; kept as the `kernel = "scalar"` A/B
/// baseline (it re-streams all `k×d` center bytes per point).
#[allow(clippy::too_many_arguments)]
pub fn nearest_scalar_raw(
    pdata: &[f32],
    prows: usize,
    pcols: usize,
    pnorms: Option<&[f32]>,
    centers: &Matrix,
    cnorms: Option<&[f32]>,
    out_idx: &mut [u32],
    out_d2: &mut [f32],
) {
    debug_assert_eq!(out_idx.len(), prows);
    debug_assert_eq!(out_d2.len(), prows);
    out_idx.fill(u32::MAX);
    out_d2.fill(f32::INFINITY);
    if prows == 0 || centers.rows == 0 {
        return;
    }
    debug_assert_eq!(centers.cols, pcols);
    let d = pcols;
    let pn = resolve(pnorms, pdata, prows, d);
    let cn = resolve(cnorms, &centers.data, centers.rows, d);
    for i in 0..prows {
        let x = &pdata[i * d..(i + 1) * d];
        let xn = pn[i];
        let mut bi = u32::MAX;
        let mut bd = f32::INFINITY;
        for j in 0..centers.rows {
            let dist = sqdist_norms(xn, x, centers.row(j), cn[j]);
            if dist < bd {
                bd = dist;
                bi = j as u32;
            }
        }
        out_idx[i] = bi;
        out_d2[i] = bd;
    }
}

/// Nearest assignment plus a threshold verdict per point:
/// `out_over[i] = d²ᵢ > lambda2` (strictly — a point exactly on the
/// boundary is *not* over, matching the serial DP-means open rule).
#[allow(clippy::too_many_arguments)]
pub fn threshold_panel(
    points: &Matrix,
    pnorms: Option<&[f32]>,
    centers: &Matrix,
    cnorms: Option<&[f32]>,
    lambda2: f32,
    out_idx: &mut [u32],
    out_d2: &mut [f32],
    out_over: &mut [bool],
) {
    nearest_panel(points, pnorms, centers, cnorms, out_idx, out_d2);
    for (o, &dd) in out_over.iter_mut().zip(out_d2.iter()) {
        *o = dd > lambda2;
    }
}

/// Generation-extending cache of per-center [`norm2`] rows.
///
/// The TCP worker session keeps one of these beside its snapshot cache:
/// a full snapshot (re-base, reconnect re-ship) rebuilds it; a snapshot
/// delta — whose apply keeps prefix rows bit-identical and appends a
/// tail — extends it with norms for the new rows only. Either path
/// stores exactly `norm2(row)`, so kernels fed from the cache are
/// bit-identical to kernels that recompute.
#[derive(Debug, Default)]
pub struct NormCache {
    norms: Vec<f32>,
}

impl NormCache {
    /// Empty cache.
    pub fn new() -> Self {
        NormCache { norms: Vec::new() }
    }

    /// Recompute all norms for `m` (full snapshot / re-base).
    pub fn rebuild(&mut self, m: &Matrix) {
        self.norms.clear();
        self.norms.reserve(m.rows);
        for i in 0..m.rows {
            self.norms.push(norm2(m.row(i)));
        }
    }

    /// `m` extends the previously cached matrix: compute norms only for
    /// the appended tail. A shrink (shouldn't happen on the delta path,
    /// but re-bases may) falls back to a full rebuild.
    pub fn extend_to(&mut self, m: &Matrix) {
        if m.rows < self.norms.len() {
            self.rebuild(m);
            return;
        }
        for i in self.norms.len()..m.rows {
            self.norms.push(norm2(m.row(i)));
        }
    }

    /// Cached norms, one per cached row.
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    /// Number of rows cached.
    pub fn len(&self) -> usize {
        self.norms.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.norms.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_matrix(rng: &mut Pcg64, rows: usize, cols: usize, scale: f32) -> Matrix {
        Matrix::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| (rng.next_f32() - 0.5) * scale).collect(),
        )
    }

    /// Random points/centers with adversarial rows spliced in: all +0.0,
    /// all -0.0, subnormals, an exact copy of a center row (exact-zero
    /// distance), and a one-ULP nudge of a center row (large-magnitude
    /// cancellation near zero).
    fn adversarial_pair(rng: &mut Pcg64, n: usize, k: usize, d: usize) -> (Matrix, Matrix) {
        let mut pts = random_matrix(rng, n, d, 2e4);
        let mut ctr = random_matrix(rng, k, d, 2e4);
        if k >= 2 {
            // Duplicate center rows: ties must break to the lower index
            // identically in both kernels.
            let first = ctr.row(0).to_vec();
            ctr.row_mut(1).copy_from_slice(&first);
        }
        let splices = n.min(5);
        for i in 0..splices {
            match i {
                0 => pts.row_mut(0).fill(0.0),
                1 => pts.row_mut(1).fill(-0.0),
                2 => pts.row_mut(2).fill(f32::MIN_POSITIVE / 2.0),
                3 => {
                    let c = ctr.row(i % k).to_vec();
                    pts.row_mut(3).copy_from_slice(&c);
                }
                _ => {
                    let mut c = ctr.row(i % k).to_vec();
                    c[d - 1] = f32::from_bits(c[d - 1].to_bits() + 1);
                    pts.row_mut(4).copy_from_slice(&c);
                }
            }
        }
        (pts, ctr)
    }

    fn run_both(pts: &Matrix, ctr: &Matrix) -> (Vec<u32>, Vec<f32>, Vec<u32>, Vec<f32>) {
        let n = pts.rows;
        let (mut pi, mut pd) = (vec![0u32; n], vec![0.0f32; n]);
        let (mut si, mut sd) = (vec![0u32; n], vec![0.0f32; n]);
        nearest_panel(pts, None, ctr, None, &mut pi, &mut pd);
        nearest_scalar_raw(&pts.data, n, pts.cols, None, ctr, None, &mut si, &mut sd);
        (pi, pd, si, sd)
    }

    #[test]
    fn panel_scalar_and_serial_are_bit_identical() {
        let mut rng = Pcg64::new(11);
        for &(n, k, d) in
            &[(1usize, 1usize, 1usize), (7, 3, 5), (64, 32, 24), (130, 70, 16), (257, 33, 19), (96, 129, 8)]
        {
            let (pts, ctr) = adversarial_pair(&mut rng, n, k, d);
            let (pi, pd, si, sd) = run_both(&pts, &ctr);
            for i in 0..n {
                assert_eq!(pi[i], si[i], "idx diverged at point {i} (n={n} k={k} d={d})");
                assert_eq!(
                    pd[i].to_bits(),
                    sd[i].to_bits(),
                    "d2 diverged at point {i} (n={n} k={k} d={d})"
                );
                // Both equal the per-point serial canonical fold.
                let (bk, bd) = crate::linalg::nearest(pts.row(i), &ctr);
                assert_eq!(pi[i] as usize, bk);
                assert_eq!(pd[i].to_bits(), bd.to_bits());
                assert!(pd[i] >= 0.0, "clamped distance went negative at point {i}");
            }
        }
    }

    #[test]
    fn cached_norms_match_recomputed_bitwise() {
        let mut rng = Pcg64::new(23);
        let (pts, ctr) = adversarial_pair(&mut rng, 100, 37, 12);
        let pn = point_norms(&pts.data, pts.rows, pts.cols);
        let cn = center_norms(&ctr);
        let n = pts.rows;
        let (mut ci, mut cd) = (vec![0u32; n], vec![0.0f32; n]);
        let (mut ui, mut ud) = (vec![0u32; n], vec![0.0f32; n]);
        nearest_panel(&pts, Some(&pn), &ctr, Some(&cn), &mut ci, &mut cd);
        nearest_panel(&pts, None, &ctr, None, &mut ui, &mut ud);
        assert_eq!(ci, ui);
        for i in 0..n {
            assert_eq!(cd[i].to_bits(), ud[i].to_bits());
        }
    }

    #[test]
    fn norm_cache_extend_matches_rebuild() {
        let mut rng = Pcg64::new(31);
        let mut m = random_matrix(&mut rng, 9, 6, 100.0);
        let mut cache = NormCache::new();
        cache.rebuild(&m);
        assert_eq!(cache.len(), 9);
        // Delta path: append rows, extend incrementally.
        for _ in 0..7 {
            let row: Vec<f32> = (0..6).map(|_| rng.next_f32() * 50.0).collect();
            m.push_row(&row);
        }
        cache.extend_to(&m);
        let mut fresh = NormCache::new();
        fresh.rebuild(&m);
        assert_eq!(cache.len(), fresh.len());
        for (a, b) in cache.norms().iter().zip(fresh.norms()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Re-base to a smaller snapshot falls back to a full rebuild.
        let small = random_matrix(&mut rng, 3, 6, 100.0);
        cache.extend_to(&small);
        assert_eq!(cache.len(), 3);
        for i in 0..3 {
            assert_eq!(cache.norms()[i].to_bits(), norm2(small.row(i)).to_bits());
        }
    }

    #[test]
    fn empty_inputs_yield_sentinels() {
        let pts = Matrix::zeros(4, 3);
        let empty = Matrix::zeros(0, 3);
        let (mut idx, mut d2) = (vec![0u32; 4], vec![0.0f32; 4]);
        nearest_panel(&pts, None, &empty, None, &mut idx, &mut d2);
        assert!(idx.iter().all(|&i| i == u32::MAX));
        assert!(d2.iter().all(|&d| d.is_infinite()));
        let (mut none_i, mut none_d) = (vec![0u32; 0], vec![0.0f32; 0]);
        nearest_panel(&empty, None, &pts, None, &mut none_i, &mut none_d);
    }

    #[test]
    fn threshold_is_strictly_greater() {
        let pts = Matrix::from_vec(3, 1, vec![0.0, 2.0, 3.0]);
        let ctr = Matrix::from_vec(1, 1, vec![0.0]);
        let (mut idx, mut d2) = (vec![0u32; 3], vec![0.0f32; 3]);
        let mut over = vec![false; 3];
        threshold_panel(&pts, None, &ctr, None, 4.0, &mut idx, &mut d2, &mut over);
        assert_eq!(idx, vec![0, 0, 0]);
        // d² = 0, 4, 9 against λ² = 4: the boundary point is not over.
        assert_eq!(over, vec![false, false, true]);
    }

    #[test]
    fn panel_constants_stay_pow2_aligned() {
        assert!(PANEL_POINTS.is_power_of_two());
        assert!(TILE_CENTERS.is_power_of_two());
    }
}
