//! Peer job execution and the in-process worker pool.
//!
//! This module owns the cluster's *unit of work*: the [`Job`] /
//! [`JobOutput`] / [`JobReply`] message types (shared by every transport —
//! see [`super::transport`]) and [`WorkerPool`], the in-process peer group:
//! P persistent threads each owning a handle to the shared dataset and the
//! compute backend. Every epoch (or mean-recompute phase) the master
//! scatters one [`Job`] per peer and eventually gathers one [`JobReply`]
//! per peer; several waves may be in flight at once (the wave engine's
//! speculation), each retired by its [`WaveId`]. Channels are
//! `std::sync::mpsc`; the per-epoch coordination cost is two sends per
//! worker, negligible next to the numeric work. The TCP transport reuses
//! the same job executor ([`run_job`]) behind sockets instead of
//! channels.
//!
//! Workers never touch global state: they read an immutable snapshot
//! (`Arc<Matrix>`) of the epoch's centers/features — the paper's
//! "replicated view of the global state" — and return pure data. All
//! mutation happens in the master (driver + validators), which is what
//! makes the execution serializable.
//!
//! A panicking job (bad geometry, poisoned input) is caught at the worker
//! and surfaces as an `Err` reply rather than a dead thread: the wave's
//! gather reports the error and the pool remains joinable, so dropping a
//! pool mid-wave can never hang the master.

use crate::data::store::DataView;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::panel::PANEL_POINTS;
use crate::linalg::Matrix;
use crate::runtime::ComputeBackend;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Identifier of one scattered wave, unique per plane for the plane's
/// lifetime (monotone, never reused). Returned by a scatter so the caller
/// can retire waves by id — in any order — while several are in flight.
pub type WaveId = u64;

/// One unit of scattered work.
pub enum Job {
    /// Nearest-center assignment for a block against a state snapshot.
    Nearest {
        /// Global point range.
        range: Range<usize>,
        /// Snapshot of `C^{t-1}`.
        centers: Arc<Matrix>,
    },
    /// Partial sufficient statistics (sums/counts) for the mean recompute.
    /// Computed per fixed-size chunk (see [`REDUCE_CHUNK`]) so the master
    /// can reduce in a P-independent deterministic order.
    SuffStats {
        /// Global point range.
        range: Range<usize>,
        /// Snapshot of all assignments.
        assignments: Arc<Vec<u32>>,
        /// Number of centers.
        k: usize,
    },
    /// BP-means coordinate descent for a block against a feature snapshot.
    BpDescend {
        /// Global point range.
        range: Range<usize>,
        /// Snapshot of `F^{t-1}`.
        features: Arc<Matrix>,
        /// Coordinate-descent sweeps.
        sweeps: usize,
    },
    /// Partial `ZᵀZ` / `ZᵀX` for the BP feature re-estimate.
    BpStats {
        /// Global point range.
        range: Range<usize>,
        /// Snapshot of all binary assignments (row-padded to `k`).
        z: Arc<Vec<Vec<bool>>>,
        /// Number of features.
        k: usize,
    },
    /// Validation-plane job: pairwise conflict distances for a group of
    /// validator shards. Each shard is a strictly-increasing list of
    /// *global* proposal positions (the epoch's proposals in point-index
    /// order); the peer returns every within-shard pair distance keyed by
    /// those global positions (see [`super::validator`]).
    ///
    /// `vectors` need not be the full proposal matrix: with row-subset
    /// shipping the peer receives only the rows its shards read, and
    /// `positions` maps each local row to its global proposal position
    /// (strictly increasing). An empty `positions` means the identity map —
    /// row `r` *is* global position `r` — which is the full-matrix form.
    PairCache {
        /// Proposal vectors, one row per shipped proposal.
        vectors: Arc<Matrix>,
        /// Global proposal position of each `vectors` row (strictly
        /// increasing; empty = identity).
        positions: Vec<u32>,
        /// The shard lists (conflict-key buckets) this peer owns, in
        /// global positions.
        shards: Vec<Vec<u32>>,
    },
    /// Terminate the worker thread.
    Shutdown,
}

impl Job {
    /// The global dataset point range this job reads, if any. This is what
    /// the TCP transport ships to a remote peer before the job: compute
    /// jobs read their scattered block, while `PairCache` carries its
    /// proposal vectors inline and `Shutdown` is pure control — validator
    /// peers therefore never need a byte of the dataset.
    pub fn data_range(&self) -> Option<Range<usize>> {
        match self {
            Job::Nearest { range, .. }
            | Job::SuffStats { range, .. }
            | Job::BpDescend { range, .. }
            | Job::BpStats { range, .. } => Some(range.clone()),
            Job::PairCache { .. } | Job::Shutdown => None,
        }
    }
}

/// Fixed reduction chunk: float sums are accumulated per chunk of this many
/// points and combined at the master in global chunk order, making the
/// result *bit-identical for every worker count* (f32 addition is not
/// associative; P-dependent partial boundaries would leak into the state).
pub const REDUCE_CHUNK: usize = 4096;

// Reduction chunks must themselves be panel-aligned so the chunked split is
// automatically a panel-aligned split too.
const _: () = assert!(REDUCE_CHUNK % PANEL_POINTS == 0);

/// Result payload of one job.
pub enum JobOutput {
    /// Nearest-center results for the job's range.
    Nearest {
        /// Per-point nearest center index (into the snapshot).
        idx: Vec<u32>,
        /// Per-point squared distance.
        d2: Vec<f32>,
    },
    /// Partial sums/counts, one entry per [`REDUCE_CHUNK`]-aligned chunk
    /// (chunk id = start index / REDUCE_CHUNK).
    SuffStats {
        /// `(chunk id, per-center sums, per-center counts)` per chunk.
        chunks: Vec<(usize, Matrix, Vec<u64>)>,
    },
    /// BP descent results for the job's range.
    BpDescend {
        /// Row-major `n × k` binary assignments.
        z: Vec<bool>,
        /// Feature count the z rows are against.
        k: usize,
        /// Row-major `n × d` residuals.
        residuals: Vec<f32>,
        /// Per-point squared residual norms.
        r2: Vec<f32>,
    },
    /// Partial normal-equation blocks, one entry per chunk (like SuffStats).
    BpStats {
        /// `(chunk id, ZᵀZ partial (k×k), ZᵀX partial (k×d))` per chunk.
        chunks: Vec<(usize, Matrix, Matrix)>,
    },
    /// Same-shard pair distances, `(a, b, d²)` with `a < b` global proposal
    /// positions, lexicographically sorted by `(a, b)`.
    PairCache {
        /// The peer's conflict cache contribution.
        pairs: Vec<(u32, u32, f32)>,
    },
}

/// A worker's reply: its id, the output (or error), and its busy time.
pub struct JobReply {
    /// Worker id. [`WAKER_SENTINEL`] marks a pure wakeup message from a
    /// plane waker — no payload, routed to nothing.
    pub worker: usize,
    /// Output or failure.
    pub output: Result<JobOutput>,
    /// Time the worker spent on the job.
    pub busy: Duration,
}

/// Pseudo worker-id of a waker's sentinel reply: its only job is to
/// interrupt a blocking [`WorkerPool::wait_reply`]; [`WorkerPool`]'s
/// reply routing drops it on sight.
pub const WAKER_SENTINEL: usize = usize::MAX;

/// One outstanding wave's reply slots.
struct PoolWave {
    id: WaveId,
    outputs: Vec<Option<JobOutput>>,
    /// Replies still owed before the wave is fully drained.
    remaining: usize,
    max_busy: Duration,
    err: Option<Error>,
}

/// Persistent worker pool.
///
/// The classic use is bulk-synchronous ([`WorkerPool::scatter_gather`]);
/// schedulers that overlap master-side work with worker compute use the
/// split [`WorkerPool::scatter`] / [`WorkerPool::gather_wave`] pair
/// instead, and may keep *several* waves in flight: each worker executes
/// its queued jobs in scatter order, so the k-th reply from worker `w`
/// retires the k-th wave scattered — no wave tags cross the channel.
/// Replies buffer into their wave's slots as they arrive, which is what
/// lets [`WorkerPool::gather_wave`] retire waves in any order and
/// [`WorkerPool::try_ready`] poll them without blocking. The speculation
/// bound lives in the scheduler (the wave engine's depth knob), not here.
pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
    replies: Receiver<JobReply>,
    /// A retained clone of the workers' reply sender, handed out to
    /// plane wakers so another thread can interrupt a blocking
    /// [`WorkerPool::wait_reply`] with a [`WAKER_SENTINEL`] message.
    /// (Holding it means the reply channel never reports disconnect —
    /// fine, because workers catch job panics and always reply, so the
    /// channel's only legitimate close is pool drop.)
    reply_tx: Sender<JobReply>,
    handles: Vec<JoinHandle<()>>,
    /// Number of workers.
    pub procs: usize,
    /// Next wave id (monotone; never reused).
    next_wave: Cell<WaveId>,
    /// Outstanding waves in scatter order (front = oldest).
    pending: RefCell<VecDeque<PoolWave>>,
    /// Per-worker id of the wave its next reply belongs to.
    replied: RefCell<Vec<WaveId>>,
    /// Set when a scatter failed partway: some workers own a job whose
    /// reply can no longer be paired with a wave, so further scatters
    /// would risk misattributing those stale replies.
    poisoned: Cell<bool>,
}

impl WorkerPool {
    /// Spawn `procs` workers over a shared dataset and backend.
    pub fn spawn(data: Arc<Dataset>, backend: Arc<dyn ComputeBackend>, procs: usize) -> WorkerPool {
        assert!(procs >= 1);
        let (reply_tx, replies) = channel::<JobReply>();
        let mut senders = Vec::with_capacity(procs);
        let mut handles = Vec::with_capacity(procs);
        for w in 0..procs {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            let data = data.clone();
            let backend = backend.clone();
            let reply_tx = reply_tx.clone();
            handles.push(std::thread::spawn(move || worker_loop(w, data, backend, rx, reply_tx)));
        }
        WorkerPool {
            senders,
            replies,
            reply_tx,
            handles,
            procs,
            next_wave: Cell::new(0),
            pending: RefCell::new(VecDeque::new()),
            replied: RefCell::new(vec![0; procs]),
            poisoned: Cell::new(false),
        }
    }

    /// Scatter one job per worker (jobs.len() must equal procs) without
    /// waiting for results, returning the wave's id. Several waves may be
    /// outstanding at once; each is retired by [`WorkerPool::gather_wave`].
    ///
    /// A scatter that fails partway (a worker's channel closed) *poisons*
    /// the pool: workers that already received their job will reply, but
    /// the wave is not registered, so those replies pair with no wave and
    /// are dropped, and later scatters error out instead of risking
    /// misattribution. (A worker *job* failure is different — the wave
    /// completes, its gather reports the error, and the pool stays
    /// usable.)
    pub fn scatter(&self, jobs: Vec<Job>) -> Result<WaveId> {
        assert_eq!(jobs.len(), self.procs);
        if self.poisoned.get() {
            return Err(Error::Coordinator(
                "worker pool poisoned by an earlier failed scatter".into(),
            ));
        }
        let id = self.next_wave.get();
        self.next_wave.set(id + 1);
        for (tx, job) in self.senders.iter().zip(jobs) {
            if tx.send(job).is_err() {
                self.poisoned.set(true);
                return Err(Error::Coordinator("worker channel closed".into()));
            }
        }
        self.pending.borrow_mut().push_back(PoolWave {
            id,
            outputs: (0..self.procs).map(|_| None).collect(),
            remaining: self.procs,
            max_busy: Duration::ZERO,
            err: None,
        });
        Ok(id)
    }

    /// Route one reply into its wave's slots. The wave a reply belongs to
    /// is implied by arrival order per worker: workers run their queued
    /// jobs in scatter order. A reply whose wave was never registered (the
    /// partial wave behind a failed scatter) pairs with nothing and is
    /// dropped — the pool is already poisoned at that point.
    fn take_reply(&self, reply: JobReply) {
        if reply.worker == WAKER_SENTINEL {
            // A waker's wakeup message: its whole purpose was to
            // interrupt a blocking recv; it routes to no wave.
            return;
        }
        let wave_id = {
            let mut replied = self.replied.borrow_mut();
            let id = replied[reply.worker];
            replied[reply.worker] += 1;
            id
        };
        let mut pending = self.pending.borrow_mut();
        if let Some(slot) = pending.iter_mut().find(|s| s.id == wave_id) {
            slot.max_busy = slot.max_busy.max(reply.busy);
            slot.remaining -= 1;
            match reply.output {
                Ok(out) => slot.outputs[reply.worker] = Some(out),
                Err(e) => {
                    if slot.err.is_none() {
                        slot.err = Some(e);
                    }
                }
            }
        }
    }

    /// Drain every reply already sitting in the channel without blocking.
    fn pump(&self) -> Result<()> {
        loop {
            match self.replies.try_recv() {
                Ok(reply) => self.take_reply(reply),
                Err(TryRecvError::Empty) => return Ok(()),
                Err(TryRecvError::Disconnected) => {
                    self.poisoned.set(true);
                    return Err(Error::Coordinator("reply channel closed".into()));
                }
            }
        }
    }

    /// Block until a reply (or a waker's sentinel) arrives, for at most
    /// `timeout`: the readiness wait behind `io = "reactor"` on the
    /// in-proc transport. Whatever lands is routed immediately (and the
    /// channel drained), so `Ok(true)` means "state advanced — re-check
    /// your waves"; `Ok(false)` means the timeout lapsed untouched.
    pub fn wait_reply(&self, timeout: Duration) -> Result<bool> {
        match self.replies.recv_timeout(timeout) {
            Ok(reply) => {
                self.take_reply(reply);
                self.pump()?;
                Ok(true)
            }
            Err(RecvTimeoutError::Timeout) => Ok(false),
            Err(RecvTimeoutError::Disconnected) => {
                self.poisoned.set(true);
                Err(Error::Coordinator("reply channel closed".into()))
            }
        }
    }

    /// A clone of the reply-channel sender, for plane wakers (see
    /// [`WAKER_SENTINEL`]).
    pub(crate) fn reply_sender(&self) -> Sender<JobReply> {
        self.reply_tx.clone()
    }

    /// Non-blocking readiness check: true when every reply of `wave` has
    /// arrived (buffered), so its gather will not block.
    pub fn try_ready(&self, wave: WaveId) -> Result<bool> {
        self.pump()?;
        let pending = self.pending.borrow();
        match pending.iter().find(|s| s.id == wave) {
            Some(s) => Ok(s.remaining == 0),
            None => Err(Error::Coordinator("try_ready on an unknown wave".into())),
        }
    }

    /// Pump-free readiness probe: reports from already-buffered replies
    /// only (false for unknown ids). Pair with one [`WorkerPool::try_ready`]
    /// — which drains the channel for every wave at once — when polling
    /// several in-flight waves.
    pub fn ready_hint(&self, wave: WaveId) -> bool {
        self.pending.borrow().iter().find(|s| s.id == wave).is_some_and(|s| s.remaining == 0)
    }

    /// Retire one outstanding wave by id: one reply per worker, sorted by
    /// worker id, plus the maximum per-worker busy time (the critical-path
    /// worker time for metrics). Blocks until the wave is fully drained;
    /// replies for *other* in-flight waves arriving meanwhile buffer into
    /// their own slots. On a worker failure the whole wave is still
    /// drained before the error is returned, so the pool stays usable.
    pub fn gather_wave(&self, wave: WaveId) -> Result<(Vec<JobOutput>, Duration)> {
        assert!(
            self.pending.borrow().iter().any(|s| s.id == wave),
            "gather without a scattered wave"
        );
        loop {
            {
                let pending = self.pending.borrow();
                let slot = pending.iter().find(|s| s.id == wave).expect("wave registered");
                if slot.remaining == 0 {
                    break;
                }
            }
            let Ok(reply) = self.replies.recv() else {
                self.poisoned.set(true);
                return Err(Error::Coordinator("reply channel closed".into()));
            };
            self.take_reply(reply);
        }
        let mut pending = self.pending.borrow_mut();
        let at = pending.iter().position(|s| s.id == wave).expect("wave registered");
        let slot = pending.remove(at).expect("position valid");
        if let Some(e) = slot.err {
            return Err(e);
        }
        Ok((slot.outputs.into_iter().map(|o| o.expect("worker replied")).collect(), slot.max_busy))
    }

    /// Gather the *oldest* outstanding wave — the classic split-call shape.
    pub fn gather(&self) -> Result<(Vec<JobOutput>, Duration)> {
        let front = self.pending.borrow().front().map(|s| s.id);
        let id = front.expect("gather without a scattered wave");
        self.gather_wave(id)
    }

    /// Scatter one job per worker and gather all replies — the BSP barrier.
    pub fn scatter_gather(&self, jobs: Vec<Job>) -> Result<(Vec<JobOutput>, Duration)> {
        let wave = self.scatter(jobs)?;
        self.gather_wave(wave)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Shutdown must be infallible even when a prior scatter/gather
        // errored mid-wave: send Shutdown best-effort, then *drop the
        // senders* so any worker still parked in `recv` sees a disconnect
        // regardless of whether its Shutdown arrived. Replies never block
        // (the mpsc channel is unbounded) and panicking jobs are caught in
        // the worker loop, so every thread reaches its exit and the joins
        // below cannot hang.
        for tx in &self.senders {
            let _ = tx.send(Job::Shutdown);
        }
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Render a caught panic payload as an error message.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

/// Execute one job against the peer's dataset and backend — the single
/// executor behind every transport (thread workers and TCP peers).
/// `Job::Shutdown` is a control message, not computable work.
pub(crate) fn run_job(
    data: &Dataset,
    backend: &Arc<dyn ComputeBackend>,
    job: Job,
) -> Result<JobOutput> {
    run_job_with(DataView::Dense(data), backend, job, None)
}

/// [`run_job`] over any [`DataView`] (dense dataset or sparse block
/// store — the TCP peer's `store` knob decides), with an optional cached
/// per-center squared-norm slice for `Nearest` jobs (one `norm2` per
/// snapshot row, canonical schedule). The TCP peer keeps such a cache
/// keyed to its installed snapshot and extends it on deltas; passing
/// `None` makes the kernel derive the norms itself — bit-identical either
/// way, the cache only saves the recompute.
pub(crate) fn run_job_with(
    view: DataView<'_>,
    backend: &Arc<dyn ComputeBackend>,
    job: Job,
    cnorms: Option<&[f32]>,
) -> Result<JobOutput> {
    match job {
        Job::Shutdown => Err(Error::Coordinator("shutdown is not a computable job".into())),
        Job::Nearest { range, centers } => run_nearest(view, backend, range, &centers, cnorms),
        Job::SuffStats { range, assignments, k } => {
            run_suffstats(view, backend, range, &assignments, k)
        }
        Job::BpDescend { range, features, sweeps } => {
            run_bp_descend(view, backend, range, &features, sweeps)
        }
        Job::BpStats { range, z, k } => run_bp_stats(view, range, &z, k),
        Job::PairCache { vectors, positions, shards } => {
            run_pair_cache(&vectors, &positions, &shards)
        }
    }
}

fn worker_loop(
    id: usize,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    rx: Receiver<Job>,
    reply_tx: Sender<JobReply>,
) {
    while let Ok(job) = rx.recv() {
        if matches!(job, Job::Shutdown) {
            return;
        }
        let start = Instant::now();
        // A panic inside a job (poisoned inputs, bad geometry) must not
        // kill the thread: the master counts one reply per peer per wave,
        // and a silently-dead worker would deadlock the gather.
        let output =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_job(&data, &backend, job)
            }))
            .unwrap_or_else(|p| Err(Error::Coordinator(panic_message(&*p))));
        let busy = start.elapsed();
        if reply_tx.send(JobReply { worker: id, output, busy }).is_err() {
            return; // master gone
        }
    }
}

fn run_nearest(
    view: DataView<'_>,
    backend: &Arc<dyn ComputeBackend>,
    range: Range<usize>,
    centers: &Matrix,
    cnorms: Option<&[f32]>,
) -> Result<JobOutput> {
    let n = range.end - range.start;
    let mut idx = vec![0u32; n];
    let mut d2 = vec![0.0f32; n];
    // Nearest is per-point independent and every view piece carries its
    // cached point norms, so computing piece-by-piece into the range's
    // output slots is bit-identical to one dense pass (pieces break only
    // on 64-row block boundaries — always panel boundaries).
    for (r, block) in view.pieces(&range) {
        let off = r.start - range.start;
        let len = r.end - r.start;
        backend.nearest_with(block, centers, cnorms, &mut idx[off..off + len], &mut d2[off..off + len])?;
    }
    Ok(JobOutput::Nearest { idx, d2 })
}

fn run_suffstats(
    view: DataView<'_>,
    backend: &Arc<dyn ComputeBackend>,
    range: Range<usize>,
    assignments: &Arc<Vec<u32>>,
    k: usize,
) -> Result<JobOutput> {
    // One partial per globally-aligned REDUCE_CHUNK so the master's
    // combination order is P-independent (range is chunk-aligned by
    // split_range_chunked). Within a chunk the pieces accumulate into the
    // same partial in ascending row order — the exact per-point addition
    // sequence of the one-slice dense pass.
    let mut chunks = Vec::new();
    let mut lo = range.start;
    while lo < range.end {
        let hi = ((lo / REDUCE_CHUNK + 1) * REDUCE_CHUNK).min(range.end);
        let mut sums = Matrix::zeros(k, view.dim());
        let mut counts = vec![0u64; k];
        for (r, block) in view.pieces(&(lo..hi)) {
            backend.suffstats(block, &assignments[r.start..r.end], &mut sums, &mut counts)?;
        }
        chunks.push((lo / REDUCE_CHUNK, sums, counts));
        lo = hi;
    }
    Ok(JobOutput::SuffStats { chunks })
}

fn run_bp_descend(
    view: DataView<'_>,
    backend: &Arc<dyn ComputeBackend>,
    range: Range<usize>,
    features: &Matrix,
    sweeps: usize,
) -> Result<JobOutput> {
    let n = range.end - range.start;
    if n == 0 {
        return Ok(JobOutput::BpDescend { z: vec![], k: features.rows, residuals: vec![], r2: vec![] });
    }
    // Coordinate descent is per-point independent, so concatenating the
    // per-piece outputs in row order is bit-identical to one dense pass.
    let mut z = Vec::with_capacity(n * features.rows);
    let mut residuals = Vec::with_capacity(n * view.dim());
    let mut r2 = Vec::with_capacity(n);
    for (_, block) in view.pieces(&range) {
        let out = backend.bp_descend(block, features, sweeps)?;
        z.extend(out.z);
        residuals.extend(out.residuals);
        r2.extend(out.r2);
    }
    Ok(JobOutput::BpDescend { z, k: features.rows, residuals, r2 })
}

/// Validate a `PairCache` job's geometry: when `positions` is non-empty it
/// must be a strictly increasing local→global map covering exactly the
/// shipped rows, and every shard position must resolve to a shipped row
/// (`< rows` under the identity map). This is the single source both the
/// wire decoder ([`super::wire::decode_job_snap`]) and the executor run
/// through, so the two validations cannot drift apart.
pub(crate) fn check_pair_cache_geometry(
    rows: usize,
    positions: &[u32],
    shards: &[Vec<u32>],
) -> Result<()> {
    if !positions.is_empty() {
        if positions.len() != rows {
            return Err(Error::Coordinator(format!(
                "pair-cache positions cover {} rows, matrix has {rows}",
                positions.len()
            )));
        }
        if !positions.windows(2).all(|w| w[0] < w[1]) {
            return Err(Error::Coordinator(
                "pair-cache positions are not strictly increasing".into(),
            ));
        }
    }
    for shard in shards {
        for &p in shard {
            let ok = if positions.is_empty() {
                (p as usize) < rows
            } else {
                positions.binary_search(&p).is_ok()
            };
            if !ok {
                return Err(Error::Coordinator(format!(
                    "pair-cache position {p} not among the {rows} shipped rows"
                )));
            }
        }
    }
    Ok(())
}

/// Compute a `PairCache` job: resolve the shards' *global* positions to
/// local `vectors` rows (identity when `positions` is empty), compute every
/// within-shard pair distance, and report the pairs keyed by their global
/// positions again. The local→global map is strictly increasing, so the
/// peer's sorted-by-`(a, b)` output order — and every distance bit — is
/// identical whether the peer received the full matrix or just its subset.
fn run_pair_cache(
    vectors: &Matrix,
    positions: &[u32],
    shards: &[Vec<u32>],
) -> Result<JobOutput> {
    check_pair_cache_geometry(vectors.rows, positions, shards)?;
    // Infallible after the geometry check above.
    let to_local = |p: u32| -> u32 {
        if positions.is_empty() {
            p
        } else {
            positions.binary_search(&p).expect("position validated above") as u32
        }
    };
    let local_shards: Vec<Vec<u32>> = shards
        .iter()
        .map(|s| s.iter().map(|&p| to_local(p)).collect())
        .collect();
    let rows: Vec<&[f32]> = (0..vectors.rows).map(|r| vectors.row(r)).collect();
    let mut pairs = super::validator::shard_pairs_sorted(&rows, &local_shards);
    if !positions.is_empty() {
        // Monotone remap: local (a, b) order is global (a, b) order, so the
        // sorted invariant survives untouched.
        for p in pairs.iter_mut() {
            p.0 = positions[p.0 as usize];
            p.1 = positions[p.1 as usize];
        }
    }
    Ok(JobOutput::PairCache { pairs })
}

fn run_bp_stats(
    view: DataView<'_>,
    range: Range<usize>,
    z: &Arc<Vec<Vec<bool>>>,
    k: usize,
) -> Result<JobOutput> {
    let d = view.dim();
    let mut chunks = Vec::new();
    let mut lo = range.start;
    while lo < range.end {
        let hi = ((lo / REDUCE_CHUNK + 1) * REDUCE_CHUNK).min(range.end);
        let mut ztz = Matrix::zeros(k, k);
        let mut ztx = Matrix::zeros(k, d);
        for i in lo..hi {
            let zi = &z[i];
            let x = view.point(i);
            for a in 0..zi.len().min(k) {
                if !zi[a] {
                    continue;
                }
                crate::linalg::axpy(1.0, x, ztx.row_mut(a));
                for b in a..zi.len().min(k) {
                    if zi[b] {
                        let v = ztz.get(a, b) + 1.0;
                        ztz.set(a, b, v);
                        if a != b {
                            ztz.set(b, a, v);
                        }
                    }
                }
            }
        }
        chunks.push((lo / REDUCE_CHUNK, ztz, ztx));
        lo = hi;
    }
    Ok(JobOutput::BpStats { chunks })
}

/// Split `range` into `procs` contiguous pieces whose boundaries fall on
/// `range.start + k ·` [`PANEL_POINTS`] — each worker's block starts on a
/// panel boundary of the assignment kernel, so only the final piece can end
/// with a partial panel. Panels are dealt near-equally (first pieces get the
/// remainder); when there are fewer panels than workers the trailing pieces
/// are empty. Used for the worker-block scatter within an epoch.
pub fn split_range(range: Range<usize>, procs: usize) -> Vec<Range<usize>> {
    let n_panels = (range.end - range.start).div_ceil(PANEL_POINTS);
    let base = n_panels / procs;
    let rem = n_panels % procs;
    let mut out = Vec::with_capacity(procs);
    let mut at = range.start;
    for p in 0..procs {
        let len_panels = base + usize::from(p < rem);
        let end = (at + len_panels * PANEL_POINTS).min(range.end);
        out.push(at..end);
        at = end;
    }
    out
}

/// Split `range` into `procs` contiguous pieces whose boundaries fall on
/// global [`REDUCE_CHUNK`] multiples — every chunk is computed wholly by one
/// worker, so per-chunk float partials are identical for every `procs`.
/// Used for the phase-2 reduction scatter.
pub fn split_range_chunked(range: Range<usize>, procs: usize) -> Vec<Range<usize>> {
    let n_chunks = (range.end - range.start).div_ceil(REDUCE_CHUNK);
    let base = n_chunks / procs;
    let rem = n_chunks % procs;
    let mut out = Vec::with_capacity(procs);
    let mut at = range.start;
    for p in 0..procs {
        let len_chunks = base + usize::from(p < rem);
        let end = (at + len_chunks * REDUCE_CHUNK).min(range.end);
        out.push(at..end);
        at = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{dp_clusters, GenConfig};
    use crate::runtime::native::NativeBackend;

    fn pool(n: usize, procs: usize) -> (Arc<Dataset>, WorkerPool) {
        let data = Arc::new(dp_clusters(&GenConfig { n, dim: 8, theta: 1.0, seed: 1 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let pool = WorkerPool::spawn(data.clone(), backend, procs);
        (data, pool)
    }

    #[test]
    fn scatter_gather_nearest_matches_direct() {
        let (data, pool) = pool(100, 4);
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        centers.push_row(data.point(50));
        let centers = Arc::new(centers);
        let ranges = split_range(0..100, 4);
        let jobs: Vec<Job> = ranges
            .iter()
            .map(|r| Job::Nearest { range: r.clone(), centers: centers.clone() })
            .collect();
        let (outs, busy) = pool.scatter_gather(jobs).unwrap();
        assert!(busy > Duration::ZERO);
        for (w, out) in outs.iter().enumerate() {
            if let JobOutput::Nearest { idx, d2 } = out {
                for (off, i) in ranges[w].clone().enumerate() {
                    let (bi, bd) = crate::linalg::nearest(data.point(i), &centers);
                    assert_eq!(idx[off], bi as u32);
                    assert_eq!(d2[off].to_bits(), bd.to_bits());
                }
            } else {
                panic!("wrong output kind");
            }
        }
    }

    #[test]
    fn suffstats_partials_sum_to_full() {
        let (data, pool) = pool(100, 3);
        let assignments = Arc::new((0..100u32).map(|i| i % 4).collect::<Vec<_>>());
        let jobs: Vec<Job> = split_range_chunked(0..100, 3)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: assignments.clone(), k: 4 })
            .collect();
        let (outs, _) = pool.scatter_gather(jobs).unwrap();
        let mut sums = Matrix::zeros(4, 8);
        let mut counts = vec![0u64; 4];
        for out in outs {
            if let JobOutput::SuffStats { chunks } = out {
                for (_, s, c) in chunks {
                    for k in 0..4 {
                        counts[k] += c[k];
                        crate::linalg::axpy(1.0, s.row(k), sums.row_mut(k));
                    }
                }
            }
        }
        assert_eq!(counts, vec![25, 25, 25, 25]);
        // Direct computation.
        let mut dsums = Matrix::zeros(4, 8);
        let mut dcounts = vec![0u64; 4];
        crate::linalg::blocked::suffstats_accumulate(&data.points, &assignments, &mut dsums, &mut dcounts);
        assert_eq!(counts, dcounts);
        crate::testing::assert_allclose(&sums.data, &dsums.data, 1e-3, 1e-5).unwrap();
    }

    #[test]
    fn split_range_chunked_aligns_to_reduce_chunks() {
        let parts = split_range_chunked(0..REDUCE_CHUNK * 5 + 17, 3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].start, 0);
        assert_eq!(parts.last().unwrap().end, REDUCE_CHUNK * 5 + 17);
        for w in parts.windows(2) {
            assert_eq!(w[0].end, w[1].start);
            assert_eq!(w[0].end % REDUCE_CHUNK, 0, "boundary not chunk-aligned");
        }
        // More workers than chunks: trailing workers get empty ranges.
        let parts = split_range_chunked(0..10, 4);
        assert_eq!(parts.iter().map(|r| r.end - r.start).sum::<usize>(), 10);
    }

    #[test]
    fn split_range_covers_exactly_and_aligns_to_panels() {
        for &(s, e, p) in &[
            (0usize, 10usize, 3usize),
            (5, 5, 2),
            (0, 7, 7),
            (2, 103, 8),
            (0, PANEL_POINTS * 5 + 17, 3),
            (PANEL_POINTS, PANEL_POINTS * 9 + 1, 4),
        ] {
            let parts = split_range(s..e, p);
            assert_eq!(parts.len(), p);
            assert_eq!(parts[0].start, s);
            assert_eq!(parts.last().unwrap().end, e);
            for w in parts.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            // Every boundary sits on a panel multiple relative to the range
            // start (or at the range end): only the end panel is partial.
            for r in &parts {
                assert!(r.start == e || (r.start - s) % PANEL_POINTS == 0, "{r:?}");
                assert!(r.end == e || (r.end - s) % PANEL_POINTS == 0, "{r:?}");
            }
        }
    }

    #[test]
    fn bp_stats_partials_match_direct() {
        let (data, pool) = pool(60, 2);
        let z: Vec<Vec<bool>> = (0..60).map(|i| vec![i % 2 == 0, i % 3 == 0]).collect();
        let z = Arc::new(z);
        let jobs: Vec<Job> = split_range_chunked(0..60, 2)
            .into_iter()
            .map(|range| Job::BpStats { range, z: z.clone(), k: 2 })
            .collect();
        let (outs, _) = pool.scatter_gather(jobs).unwrap();
        let mut ztz = Matrix::zeros(2, 2);
        for out in outs {
            if let JobOutput::BpStats { chunks } = out {
                for (_, a, _) in chunks {
                    for i in 0..4 {
                        ztz.data[i] += a.data[i];
                    }
                }
            }
        }
        // z0 count = 30, z1 count = 20, overlap (i % 6 == 0) = 10.
        assert_eq!(ztz.get(0, 0), 30.0);
        assert_eq!(ztz.get(1, 1), 20.0);
        assert_eq!(ztz.get(0, 1), 10.0);
        assert_eq!(ztz.get(1, 0), 10.0);
        let _ = data;
    }

    #[test]
    fn pool_shutdown_clean() {
        let (_, pool) = pool(10, 2);
        drop(pool); // must not hang
    }

    /// A wave whose job panics inside a worker (assignments shorter than
    /// the scattered range → out-of-bounds slice) must surface as an `Err`
    /// from gather — not a deadlock — and the pool must still drop cleanly.
    #[test]
    fn poisoned_wave_reports_error_and_pool_stays_joinable() {
        let (_, pool) = pool(100, 2);
        let short = Arc::new(vec![0u32; 10]); // too short for range 0..100
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: short.clone(), k: 2 })
            .collect();
        pool.scatter(jobs).unwrap();
        let err = pool.gather();
        assert!(err.is_err(), "panicking worker must produce a wave error");
        // The pool survived the poisoned wave: a fresh wave still works.
        let ok = Arc::new(vec![0u32; 100]);
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: ok.clone(), k: 2 })
            .collect();
        pool.scatter_gather(jobs).unwrap();
        drop(pool); // must not hang
    }

    /// Dropping a pool with a wave still outstanding (scattered, never
    /// gathered — the shape left behind by an errored scatter/gather) must
    /// join all workers without hanging.
    #[test]
    fn drop_with_outstanding_poisoned_wave_does_not_hang() {
        let (_, pool) = pool(100, 2);
        let short = Arc::new(vec![0u32; 10]);
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: short.clone(), k: 2 })
            .collect();
        pool.scatter(jobs).unwrap();
        drop(pool); // wave never gathered; drop must still join
    }

    #[test]
    fn pair_cache_job_computes_shard_pairs() {
        let (_, pool) = pool(10, 2);
        let mut vectors = Matrix::zeros(0, 2);
        vectors.push_row(&[0.0, 0.0]);
        vectors.push_row(&[3.0, 4.0]);
        vectors.push_row(&[0.0, 1.0]);
        let vectors = Arc::new(vectors);
        let jobs = vec![
            Job::PairCache {
                vectors: vectors.clone(),
                positions: vec![],
                shards: vec![vec![0, 1, 2]],
            },
            Job::PairCache { vectors: vectors.clone(), positions: vec![], shards: vec![] },
        ];
        let (outs, _) = pool.scatter_gather(jobs).unwrap();
        let JobOutput::PairCache { pairs } = &outs[0] else { panic!("wrong output kind") };
        assert_eq!(pairs.len(), 3);
        assert_eq!(pairs[0], (0, 1, 25.0));
        assert_eq!(pairs[1], (0, 2, 1.0));
        assert_eq!(pairs[2], (1, 2, 18.0));
        let JobOutput::PairCache { pairs } = &outs[1] else { panic!("wrong output kind") };
        assert!(pairs.is_empty());
    }

    /// A row-subset job (only the referenced rows shipped, plus the
    /// local→global position map) must produce the exact pairs of the
    /// full-matrix job — same global keys, same distance bits.
    #[test]
    fn pair_cache_row_subset_matches_full_matrix() {
        let (_, pool) = pool(10, 2);
        let mut full = Matrix::zeros(0, 2);
        for i in 0..6 {
            full.push_row(&[i as f32 * 1.5, (i * i) as f32 * 0.25]);
        }
        let full = Arc::new(full);
        // Shards reference global positions {1, 3, 4} and {0, 5}.
        let shards = vec![vec![1u32, 3, 4], vec![0, 5]];
        let jobs = vec![
            Job::PairCache { vectors: full.clone(), positions: vec![], shards: shards.clone() },
            Job::PairCache { vectors: full.clone(), positions: vec![], shards: vec![] },
        ];
        let (full_outs, _) = pool.scatter_gather(jobs).unwrap();
        // Subset: rows {0, 1, 3, 4, 5} shipped (the union), mapped by
        // positions.
        let positions = vec![0u32, 1, 3, 4, 5];
        let mut sub = Matrix::zeros(0, 2);
        for &p in &positions {
            sub.push_row(full.row(p as usize));
        }
        let jobs = vec![
            Job::PairCache { vectors: Arc::new(sub), positions, shards },
            Job::PairCache { vectors: full.clone(), positions: vec![], shards: vec![] },
        ];
        let (sub_outs, _) = pool.scatter_gather(jobs).unwrap();
        let (JobOutput::PairCache { pairs: a }, JobOutput::PairCache { pairs: b }) =
            (&full_outs[0], &sub_outs[0])
        else {
            panic!("wrong output kind");
        };
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!((x.0, x.1), (y.0, y.1), "global pair keys must survive the remap");
            assert_eq!(x.2.to_bits(), y.2.to_bits(), "distance bits must survive the remap");
        }
    }

    #[test]
    fn pair_cache_job_rejects_out_of_range_positions() {
        let (_, pool) = pool(10, 1);
        let vectors = Arc::new(Matrix::zeros(2, 2));
        let jobs =
            vec![Job::PairCache { vectors, positions: vec![], shards: vec![vec![0, 7]] }];
        assert!(pool.scatter_gather(jobs).is_err());
    }

    #[test]
    fn pair_cache_job_rejects_bad_position_maps() {
        let (_, pool) = pool(10, 1);
        // A shard position that is not among the shipped rows.
        let vectors = Arc::new(Matrix::zeros(2, 2));
        let jobs = vec![Job::PairCache {
            vectors,
            positions: vec![3, 9],
            shards: vec![vec![3, 5]],
        }];
        assert!(pool.scatter_gather(jobs).is_err());
        // Positions not strictly increasing.
        let vectors = Arc::new(Matrix::zeros(2, 2));
        let jobs = vec![Job::PairCache {
            vectors,
            positions: vec![4, 4],
            shards: vec![vec![4]],
        }];
        assert!(pool.scatter_gather(jobs).is_err());
        // Positions length disagreeing with the shipped rows.
        let vectors = Arc::new(Matrix::zeros(2, 2));
        let jobs = vec![Job::PairCache {
            vectors,
            positions: vec![1],
            shards: vec![vec![1]],
        }];
        assert!(pool.scatter_gather(jobs).is_err());
    }

    #[test]
    fn split_scatter_gather_matches_barrier_call() {
        // The pipelined scheduler's split path must return exactly what the
        // BSP barrier call returns for the same jobs.
        let (data, pool) = pool(80, 3);
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..80, 3)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        pool.scatter(mk()).unwrap();
        // Master-side work would happen here, overlapped with the wave.
        let (split_outs, _) = pool.gather().unwrap();
        let (barrier_outs, _) = pool.scatter_gather(mk()).unwrap();
        for (a, b) in split_outs.iter().zip(&barrier_outs) {
            let (JobOutput::Nearest { idx: ia, d2: da }, JobOutput::Nearest { idx: ib, d2: db }) =
                (a, b)
            else {
                panic!("wrong output kind");
            };
            assert_eq!(ia, ib);
            assert_eq!(da, db);
        }
    }

    #[test]
    #[should_panic(expected = "gather without a scattered wave")]
    fn gather_without_scatter_panics() {
        let (_, pool) = pool(10, 2);
        let _ = pool.gather();
    }

    /// Several waves in flight at once: replies buffer into their own
    /// wave's slots, waves retire in any order, and the outputs are
    /// bit-identical to barrier calls of the same jobs.
    #[test]
    fn multiple_waves_buffer_and_retire_out_of_order() {
        let (data, pool) = pool(60, 2);
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = |r: Range<usize>| -> Vec<Job> {
            split_range(r, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let a = pool.scatter(mk(0..30)).unwrap();
        let b = pool.scatter(mk(30..60)).unwrap();
        assert_ne!(a, b, "wave ids are unique");
        // Retire the younger wave first.
        let (outs_b, _) = pool.gather_wave(b).unwrap();
        let (outs_a, _) = pool.gather_wave(a).unwrap();
        let (ref_a, _) = pool.scatter_gather(mk(0..30)).unwrap();
        let (ref_b, _) = pool.scatter_gather(mk(30..60)).unwrap();
        for (got, want) in [(&outs_a, &ref_a), (&outs_b, &ref_b)] {
            for (x, y) in got.iter().zip(want.iter()) {
                let (
                    JobOutput::Nearest { idx: ia, d2: da },
                    JobOutput::Nearest { idx: ib, d2: db },
                ) = (x, y)
                else {
                    panic!("wrong output kind");
                };
                assert_eq!(ia, ib);
                assert_eq!(
                    da.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    db.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
                );
            }
        }
        // try_ready polls without blocking and flips true once the wave's
        // replies have all buffered; a retired wave is unknown. The
        // pump-free ready_hint agrees once a pumping call has routed the
        // replies.
        assert!(pool.try_ready(a).is_err(), "retired waves are unknown");
        assert!(!pool.ready_hint(a), "retired waves hint not-ready");
        let c = pool.scatter(mk(0..30)).unwrap();
        while !pool.try_ready(c).unwrap() {
            std::thread::yield_now();
        }
        assert!(pool.ready_hint(c), "buffered wave must hint ready without a pump");
        pool.gather_wave(c).unwrap();
    }
}
