//! The streaming ingest service behind `occd serve`.
//!
//! `serve` splits the process in two:
//!
//! * The **gateway thread** owns the client-facing [`TcpListener`] and a
//!   private [`Reactor`](super::reactor::Reactor): every client socket is
//!   nonblocking and registered for readiness, so one thread multiplexes
//!   the whole firehose without a hard sleep. Clients speak the
//!   [`wire`] ingest vocabulary — [`wire::KIND_INGEST`] chunks in,
//!   typed [`wire::KIND_INGEST_ACK`]s out, [`wire::KIND_QUERY`] answered
//!   with a model snapshot. The gateway's **admission stage** batches
//!   admitted points into mini-epochs by size (`batch_points`) *or*
//!   latency SLA (`batch_latency_ms`), whichever trips first, and
//!   publishes each grown dataset generation into the shared [`DataCell`]
//!   *before* announcing the epoch that reads it. The sealed-batch queue
//!   is bounded (`ingest_queue`): while the engine is `ingest_queue`
//!   batches behind, new chunks get a typed `Throttled` ack and are *not*
//!   admitted — backpressure the client can see and retry.
//!
//! * The **engine** (the calling thread) runs
//!   [`driver::run_streaming`] over a [`LiveSource`] view of that queue —
//!   the same wave engine, validators and transports as a static run,
//!   pointed at a different [`EpochSource`]. Each sealed batch wakes the
//!   engine out of its reactor park through the compute plane's
//!   [`PlaneWaker`], so admission→commit latency is bounded by work, not
//!   by the idle-poll cap.
//!
//! Determinism: the model depends only on the *admitted point order* —
//! replaying the same order as a static span list through the same
//! `run_streaming` yields a bit-identical model (Thm 3.1 doesn't care
//! when points arrived). `rust/tests/serve_stream.rs` pins this.

use super::driver::{self, Model, RunOutput};
use super::reactor::Reactor;
use super::scheduler::{EpochSource, SourcePoll, SourcedEpoch};
use super::transport::PlaneWaker;
use super::wire::{self, IngestAck, IngestStatus};
use crate::config::{RunConfig, ShardingKind, StoreKind, TransportKind};
use crate::data::store::{BlockStore, BLOCK_POINTS};
use crate::data::{DataCell, Dataset};
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::metrics::MetricsSink;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Gateway idle-wait cap: bounds how stale an un-flushed ack or an
/// SLA-deadline check can go when no socket readiness fires.
const GATEWAY_WAIT_CAP: Duration = Duration::from_millis(10);

/// How long the gateway lingers after the final model is out and every
/// owed byte is flushed, giving clients time to read and close first.
const LINGER: Duration = Duration::from_secs(2);

#[cfg(unix)]
fn fd_of<T: std::os::unix::io::AsRawFd>(s: &T) -> i32 {
    s.as_raw_fd()
}
#[cfg(not(unix))]
fn fd_of<T>(_s: &T) -> i32 {
    0 // the non-unix Reactor stub ignores fds entirely
}

// ---------------------------------------------------------------------------
// Engine waker hand-off
// ---------------------------------------------------------------------------

/// Late-bound handle to the engine's compute-plane waker. The gateway
/// starts before the cluster spawns; `run_streaming` publishes the waker
/// into this slot once it exists, and every batch seal afterwards pops
/// the engine out of its reactor park. A seal that lands before the slot
/// is filled is safe to miss: the engine polls the source before its
/// first park.
pub struct WakerSlot(Mutex<Option<Arc<dyn PlaneWaker>>>);

impl WakerSlot {
    /// An empty slot.
    pub fn new() -> WakerSlot {
        WakerSlot(Mutex::new(None))
    }

    /// Install the engine's waker (or `None` — poll mode has none).
    pub fn set(&self, w: Option<Arc<dyn PlaneWaker>>) {
        *self.0.lock().expect("waker slot poisoned") = w;
    }

    /// Wake the engine, if a waker has been published.
    pub fn wake(&self) {
        if let Some(w) = self.0.lock().expect("waker slot poisoned").as_ref() {
            w.wake();
        }
    }
}

impl Default for WakerSlot {
    fn default() -> Self {
        WakerSlot::new()
    }
}

// ---------------------------------------------------------------------------
// The live epoch source
// ---------------------------------------------------------------------------

/// One sealed mini-epoch, queued from the admission stage to the engine.
#[derive(Debug)]
pub struct SealedBatch {
    /// Point span in the (already published) dataset generation.
    pub span: Range<usize>,
    /// When the admission stage sealed it — the start of the
    /// admission→commit latency clock.
    pub sealed_at: Instant,
    /// Queue depth right after this batch was enqueued.
    pub queue_depth: usize,
}

/// The engine's view of the admission queue: an [`EpochSource`] that
/// yields sealed mini-epochs as they form, `Pending` while the stream is
/// quiet, and `Ended` once the gateway closes admission (EOS) and the
/// queue drains.
pub struct LiveSource {
    rx: Receiver<SealedBatch>,
    depth: Arc<AtomicUsize>,
    ended: bool,
}

impl LiveSource {
    /// Wrap the admission queue's receiving half.
    pub fn new(rx: Receiver<SealedBatch>, depth: Arc<AtomicUsize>) -> LiveSource {
        LiveSource { rx, depth, ended: false }
    }
}

impl EpochSource for LiveSource {
    fn poll_epoch(&mut self) -> SourcePoll {
        if self.ended {
            return SourcePoll::Ended;
        }
        match self.rx.try_recv() {
            Ok(b) => {
                // The bound covers sealed-but-not-yet-scheduled batches;
                // the engine taking one frees an admission slot.
                self.depth.fetch_sub(1, Ordering::SeqCst);
                SourcePoll::Ready(SourcedEpoch {
                    span: b.span,
                    admitted_at: Some(b.sealed_at),
                    queue_depth: b.queue_depth,
                })
            }
            Err(TryRecvError::Empty) => SourcePoll::Pending,
            // Sender dropped = admission closed; buffered batches above
            // were delivered first, so this really is the end.
            Err(TryRecvError::Disconnected) => {
                self.ended = true;
                SourcePoll::Ended
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Final-model hand-back (engine → gateway)
// ---------------------------------------------------------------------------

/// What the engine publishes when `run_streaming` returns, for the
/// gateway to answer queries and the deferred EOS ack with.
#[derive(Clone)]
struct FinalState {
    /// The learned model matrix (centers / facilities / features); empty
    /// on a failed run.
    model: Matrix,
    /// The run error, if any (turns the EOS ack into `Rejected`).
    err: Option<String>,
}

/// State shared between the engine thread and the gateway thread.
struct Shared {
    waker: WakerSlot,
    fin: Mutex<Option<FinalState>>,
}

impl Shared {
    fn new() -> Shared {
        Shared { waker: WakerSlot::new(), fin: Mutex::new(None) }
    }

    fn publish(&self, f: FinalState) {
        *self.fin.lock().expect("final slot poisoned") = Some(f);
    }

    fn final_state(&self) -> Option<FinalState> {
        self.fin.lock().expect("final slot poisoned").clone()
    }
}

// ---------------------------------------------------------------------------
// The admission stage (socket-free core)
// ---------------------------------------------------------------------------

/// Size-or-SLA batching of admitted points into sealed mini-epochs, plus
/// the grown-generation publish protocol. Kept free of sockets so the
/// sealing rules are unit-testable; the [`Gateway`] feeds it decoded
/// frames.
struct Admission {
    dim: usize,
    batch_points: usize,
    latency: Duration,
    bound: usize,
    store: StoreKind,
    cell: Arc<DataCell>,
    /// Master copy of the admitted points. Under `store = "dense"` this
    /// holds staged + sealed rows (chunks append directly); under
    /// `store = "sparse"` only sealed rows — staged chunks wait in
    /// `staging` until a seal materializes them.
    points: Matrix,
    /// Per-point squared norms, extended incrementally (per admitted
    /// chunk, or per sealed span from the staging blocks) so a seal
    /// never recomputes the whole prefix.
    norms: Vec<f32>,
    /// Un-sealed chunks, staged in the same panel-aligned [`BlockStore`]
    /// the peer data plane uses (`store = "sparse"`); sealed blocks are
    /// evicted once materialized, so the buffer's footprint is O(staged).
    staging: BlockStore,
    /// Rows currently staged in `staging` (sparse mode only).
    staged: usize,
    /// Rows already sealed (and published); [`Admission::staged_rows`]
    /// rows are staged, waiting for size or SLA.
    sealed_rows: usize,
    /// When the oldest staged point arrived (SLA clock). Restarted on
    /// each seal that leaves a remainder.
    oldest: Option<Instant>,
    tx: Option<Sender<SealedBatch>>,
    depth: Arc<AtomicUsize>,
    waker: Option<Arc<Shared>>,
    /// Points admitted over the session (the Accepted ack detail).
    admitted: u64,
    /// Mini-epochs sealed (the model-snapshot id).
    sealed_batches: u64,
}

impl Admission {
    fn new(
        cfg: &RunConfig,
        cell: Arc<DataCell>,
        tx: Sender<SealedBatch>,
        depth: Arc<AtomicUsize>,
        waker: Option<Arc<Shared>>,
    ) -> Admission {
        Admission {
            dim: cfg.dim,
            batch_points: cfg.effective_batch_points(),
            latency: cfg.batch_latency(),
            bound: cfg.ingest_queue,
            store: cfg.store,
            cell,
            points: Matrix::zeros(0, cfg.dim),
            norms: Vec::new(),
            staging: BlockStore::new(cfg.dim),
            staged: 0,
            sealed_rows: 0,
            oldest: None,
            tx: Some(tx),
            depth,
            waker,
            admitted: 0,
            sealed_batches: 0,
        }
    }

    fn staged_rows(&self) -> usize {
        match self.store {
            StoreKind::Dense => self.points.rows - self.sealed_rows,
            StoreKind::Sparse => self.staged,
        }
    }

    fn closed(&self) -> bool {
        self.tx.is_none()
    }

    /// Offer one decoded non-EOS ingest chunk; returns the typed ack.
    /// Admits whole chunks or nothing — a throttled chunk leaves no
    /// partial state, so the client can re-send it verbatim.
    fn offer(&mut self, seq: u64, chunk: &Matrix) -> IngestAck {
        if self.closed() {
            return IngestAck {
                seq,
                status: IngestStatus::Rejected,
                detail: 0,
                message: "admission closed (end-of-stream already seen)".into(),
            };
        }
        if self.depth.load(Ordering::SeqCst) >= self.bound {
            return IngestAck {
                seq,
                status: IngestStatus::Throttled,
                detail: self.bound as u64,
                message: String::new(),
            };
        }
        if chunk.cols != self.dim {
            return IngestAck {
                seq,
                status: IngestStatus::Rejected,
                detail: 0,
                message: format!("point dim {} != configured dim {}", chunk.cols, self.dim),
            };
        }
        if self.oldest.is_none() {
            self.oldest = Some(Instant::now());
        }
        match self.store {
            StoreKind::Dense => {
                self.points.data.extend_from_slice(&chunk.data);
                self.points.rows += chunk.rows;
                self.norms.extend(crate::linalg::panel::point_norms(
                    &chunk.data,
                    chunk.rows,
                    chunk.cols,
                ));
            }
            StoreKind::Sparse => {
                // Stage at the chunk's global row offset; install
                // computes the canonical per-row norms in the blocks.
                self.staging.install(self.sealed_rows + self.staged, &chunk.data, chunk.rows);
                self.staged += chunk.rows;
            }
        }
        self.admitted += chunk.rows as u64;
        while self.staged_rows() >= self.batch_points {
            self.seal(self.batch_points);
        }
        IngestAck {
            seq,
            status: IngestStatus::Accepted,
            detail: self.admitted,
            message: String::new(),
        }
    }

    /// Seal the next `rows` staged rows into a mini-epoch: publish the
    /// grown generation *first*, then announce the span, then wake the
    /// engine.
    fn seal(&mut self, rows: usize) {
        let span = self.sealed_rows..self.sealed_rows + rows;
        if self.store == StoreKind::Sparse {
            // Materialize the span out of the staging blocks into the
            // master copy, reusing their per-block norms (the canonical
            // `norm2` — bitwise what the dense append path computes),
            // then evict what no longer backs staged rows.
            let d = self.dim;
            self.points.grow_rows(span.end);
            for (r, block) in self.staging.pieces(&span) {
                self.points.data[r.start * d..r.end * d].copy_from_slice(block.data);
                self.norms.extend_from_slice(block.norms.expect("staging blocks carry norms"));
            }
            self.staged -= rows;
            // A block straddling the seal boundary stays only while it
            // still backs staged rows; a fully-drained staging buffer
            // holds nothing.
            self.staging
                .evict_below(if self.staged == 0 { span.end + BLOCK_POINTS } else { span.end });
        }
        self.sealed_rows = span.end;
        self.oldest = if self.staged_rows() > 0 { Some(Instant::now()) } else { None };
        // Every sealed row is published, staged rows ride along harmlessly
        // (no epoch names them yet).
        self.cell.set(Arc::new(Dataset::with_norms(
            self.points.clone(),
            None,
            self.norms.clone(),
        )));
        let queue_depth = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
        self.sealed_batches += 1;
        if let Some(tx) = &self.tx {
            let _ = tx.send(SealedBatch { span, sealed_at: Instant::now(), queue_depth });
        }
        if let Some(s) = &self.waker {
            s.waker.wake();
        }
    }

    /// The SLA deadline for the oldest staged point, if any.
    fn deadline(&self) -> Option<Instant> {
        self.oldest.map(|t| t + self.latency)
    }

    /// Seal a partial batch if the SLA deadline has passed.
    fn tick(&mut self, now: Instant) {
        if let Some(dl) = self.deadline() {
            if now >= dl && self.staged_rows() > 0 {
                // SLA seals ignore the queue bound: these points were
                // already admitted (acked Accepted) — holding them would
                // break the latency promise they were admitted under.
                self.seal(self.staged_rows());
            }
        }
    }

    /// End of stream: seal any remainder and close admission. Dropping
    /// the sender is what eventually turns the engine's source `Ended`.
    fn eos(&mut self) {
        if self.staged_rows() > 0 {
            self.seal(self.staged_rows());
        }
        self.tx = None;
        if let Some(s) = &self.waker {
            s.waker.wake();
        }
    }
}

// ---------------------------------------------------------------------------
// The gateway
// ---------------------------------------------------------------------------

/// One connected ingest client.
struct Client {
    stream: TcpStream,
    inbuf: Vec<u8>,
    outq: Vec<u8>,
    /// Set on a framing error or EOF: stop reading, flush what's owed,
    /// then close.
    closing: bool,
}

/// The gateway thread's state: listener + clients on one reactor, the
/// admission stage, and the deferred EOS ack.
struct Gateway {
    listener: TcpListener,
    reactor: Reactor,
    clients: Vec<Client>,
    admission: Admission,
    shared: Arc<Shared>,
    /// The EOS frame's origin (client index, seq) — acked only once the
    /// model is final.
    eos: Option<(usize, u64)>,
    eos_acked: bool,
    saw_client: bool,
    linger_from: Option<Instant>,
}

impl Gateway {
    fn new(
        listener: TcpListener,
        reactor: Reactor,
        admission: Admission,
        shared: Arc<Shared>,
    ) -> Gateway {
        Gateway {
            listener,
            reactor,
            clients: Vec::new(),
            admission,
            shared,
            eos: None,
            eos_acked: false,
            saw_client: false,
            linger_from: None,
        }
    }

    fn accept_new(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = self.reactor.register(fd_of(&stream));
                    self.saw_client = true;
                    self.clients.push(Client {
                        stream,
                        inbuf: Vec::new(),
                        outq: Vec::new(),
                        closing: false,
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Drain one client's socket and handle every complete frame.
    fn pump_client(&mut self, ci: usize) {
        let mut tmp = [0u8; 64 * 1024];
        loop {
            let c = &mut self.clients[ci];
            if c.closing {
                return;
            }
            match c.stream.read(&mut tmp) {
                Ok(0) => {
                    c.closing = true;
                    break;
                }
                Ok(n) => c.inbuf.extend_from_slice(&tmp[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    c.closing = true;
                    break;
                }
            }
        }
        loop {
            let parsed = wire::poll_frame(&mut self.clients[ci].inbuf);
            match parsed {
                Ok(Some((kind, payload))) => self.handle_frame(ci, kind, &payload),
                Ok(None) => break,
                Err(e) => {
                    // Framing is gone; one last typed word, then close.
                    self.push_ack(
                        ci,
                        IngestAck {
                            seq: 0,
                            status: IngestStatus::Rejected,
                            detail: 0,
                            message: format!("unreadable frame: {e}"),
                        },
                    );
                    self.clients[ci].closing = true;
                    break;
                }
            }
        }
    }

    fn handle_frame(&mut self, ci: usize, kind: u16, payload: &[u8]) {
        match kind {
            wire::KIND_INGEST => match wire::decode_ingest(payload) {
                Ok(ing) if ing.is_eos() => {
                    if self.admission.closed() {
                        self.push_ack(
                            ci,
                            IngestAck {
                                seq: ing.seq,
                                status: IngestStatus::Rejected,
                                detail: 0,
                                message: "admission closed (end-of-stream already seen)".into(),
                            },
                        );
                    } else {
                        self.admission.eos();
                        self.eos = Some((ci, ing.seq));
                    }
                }
                Ok(ing) => {
                    let ack = self.admission.offer(ing.seq, &ing.points);
                    self.push_ack(ci, ack);
                }
                Err(e) => self.push_ack(
                    ci,
                    IngestAck {
                        seq: 0,
                        status: IngestStatus::Rejected,
                        detail: 0,
                        message: format!("bad ingest payload: {e}"),
                    },
                ),
            },
            wire::KIND_QUERY => {
                let (id, model) = match self.shared.final_state() {
                    Some(f) => (self.admission.sealed_batches, f.model),
                    None => (0, Matrix::zeros(0, self.admission.dim)),
                };
                if let Ok(frame) = wire::snapshot_frame(id, &model) {
                    self.clients[ci].outq.extend_from_slice(&frame);
                }
            }
            other => self.push_ack(
                ci,
                IngestAck {
                    seq: 0,
                    status: IngestStatus::Rejected,
                    detail: 0,
                    message: format!("unexpected frame kind {other} on an ingest session"),
                },
            ),
        }
    }

    fn push_ack(&mut self, ci: usize, ack: IngestAck) {
        if let Ok(frame) = wire::ingest_ack_frame(&ack) {
            self.clients[ci].outq.extend_from_slice(&frame);
        }
    }

    /// Send the deferred EOS ack once the engine has published the final
    /// model: `Accepted` with the admitted total, or `Rejected` carrying
    /// the run error.
    fn flush_eos_ack(&mut self) {
        if self.eos_acked {
            return;
        }
        let Some((ci, seq)) = self.eos else { return };
        let Some(fin) = self.shared.final_state() else { return };
        let ack = match fin.err {
            None => IngestAck {
                seq,
                status: IngestStatus::Accepted,
                detail: self.admission.admitted,
                message: String::new(),
            },
            Some(msg) => IngestAck {
                seq,
                status: IngestStatus::Rejected,
                detail: 0,
                message: format!("run failed: {msg}"),
            },
        };
        if ci < self.clients.len() {
            self.push_ack(ci, ack);
        }
        self.eos_acked = true;
    }

    /// Write every client's owed bytes until the sockets push back.
    fn flush_out(&mut self) {
        for c in &mut self.clients {
            while !c.outq.is_empty() {
                match c.stream.write(&c.outq) {
                    Ok(0) => {
                        c.closing = true;
                        break;
                    }
                    Ok(n) => {
                        c.outq.drain(..n);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.closing = true;
                        break;
                    }
                }
            }
        }
    }

    /// Drop clients that are closing and owe nothing.
    fn reap(&mut self) {
        let mut i = 0;
        while i < self.clients.len() {
            if self.clients[i].closing && self.clients[i].outq.is_empty() {
                let c = self.clients.remove(i);
                self.reactor.deregister(fd_of(&c.stream));
                // A reaped client can no longer receive the deferred EOS
                // ack; shifting indices would misdirect it.
                match &mut self.eos {
                    Some((ei, _)) if *ei == i => self.eos_acked = true,
                    Some((ei, _)) if *ei > i => *ei -= 1,
                    _ => {}
                }
            } else {
                i += 1;
            }
        }
    }

    /// One full service pass; returns false when the gateway is done.
    fn run(mut self) {
        let _ = self.listener.set_nonblocking(true);
        let _ = self.reactor.register(fd_of(&self.listener));
        loop {
            self.accept_new();
            for ci in 0..self.clients.len() {
                self.pump_client(ci);
            }
            let now = Instant::now();
            self.admission.tick(now);
            // A fully departed firehose ends the stream implicitly: no
            // client is left to send EOS, and the engine must not wait
            // forever on a queue nobody feeds.
            if self.saw_client && self.clients.is_empty() && !self.admission.closed() {
                self.admission.eos();
            }
            self.flush_eos_ack();
            self.flush_out();
            self.reap();

            if self.shared.final_state().is_some() {
                let drained = self.clients.iter().all(|c| c.outq.is_empty());
                let acked = self.eos.is_none() || self.eos_acked;
                if drained && acked {
                    if self.clients.is_empty() {
                        break;
                    }
                    match self.linger_from {
                        None => self.linger_from = Some(Instant::now()),
                        Some(t) if t.elapsed() >= LINGER => break,
                        Some(_) => {}
                    }
                }
            }

            let timeout = self
                .admission
                .deadline()
                .map(|dl| dl.saturating_duration_since(Instant::now()))
                .unwrap_or(GATEWAY_WAIT_CAP)
                .min(GATEWAY_WAIT_CAP);
            let _ = self.reactor.wait(timeout);
        }
    }
}

// ---------------------------------------------------------------------------
// Entry point
// ---------------------------------------------------------------------------

/// Run the streaming ingest service on an already-bound listener until
/// the firehose ends, and return the streamed run's output.
///
/// The config is taken as-is except for the serve invariants: transport
/// is forced to TCP (workers must read the dataset through the shipping
/// path — an `Arc` snapshot taken at spawn would go stale as the stream
/// grows it), sharding to `hash` (conflict packing keys off a full
/// dataset scan), and `bootstrap_div` to 0 (no prefix exists to
/// bootstrap over before the stream starts).
pub fn serve(cfg: &RunConfig, listener: TcpListener) -> Result<RunOutput> {
    let mut cfg = cfg.clone();
    cfg.transport = TransportKind::Tcp;
    cfg.sharding = ShardingKind::Hash;
    cfg.bootstrap_div = 0;
    cfg.validate()?;

    let cell = Arc::new(DataCell::new(Arc::new(Dataset::new(
        Matrix::zeros(0, cfg.dim),
        None,
    ))));
    let (tx, rx) = mpsc::channel();
    let depth = Arc::new(AtomicUsize::new(0));
    let shared = Arc::new(Shared::new());
    // Created here (not in the thread) so a reactor failure is a typed
    // serve error instead of a silent empty run.
    let reactor = Reactor::new().map_err(Error::Io)?;
    let admission = Admission::new(&cfg, cell.clone(), tx, depth.clone(), Some(shared.clone()));
    let gateway = Gateway::new(listener, reactor, admission, shared.clone());
    let gw = std::thread::Builder::new()
        .name("occ-gateway".into())
        .spawn(move || gateway.run())
        .map_err(Error::Io)?;

    let mut source = LiveSource::new(rx, depth);
    let mut sink = MetricsSink::open(cfg.metrics_path.as_deref())?;
    let result = driver::run_streaming(&cfg, cell, &mut source, &mut sink, |w| {
        shared.waker.set(w)
    });
    sink.flush();

    let fin = match &result {
        Ok(out) => FinalState { model: model_matrix(&out.model), err: None },
        Err(e) => FinalState { model: Matrix::zeros(0, cfg.dim), err: Some(e.to_string()) },
    };
    shared.publish(fin);
    let _ = gw.join();
    result
}

/// The queryable face of a model: centers / facilities / features.
fn model_matrix(m: &Model) -> Matrix {
    match m {
        Model::Dp(m) => m.centers.clone(),
        Model::Ofl(m) => m.centers.clone(),
        Model::Bp(m) => m.features.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(dim: usize, batch_points: usize, latency_ms: u64, queue: usize) -> RunConfig {
        RunConfig {
            dim,
            batch_points,
            batch_latency_ms: latency_ms,
            ingest_queue: queue,
            ..RunConfig::default()
        }
    }

    fn cell(dim: usize) -> Arc<DataCell> {
        Arc::new(DataCell::new(Arc::new(Dataset::new(Matrix::zeros(0, dim), None))))
    }

    fn chunk(rows: usize, dim: usize, fill: f32) -> Matrix {
        Matrix { rows, cols: dim, data: vec![fill; rows * dim] }
    }

    fn admission(
        c: &RunConfig,
    ) -> (Admission, Receiver<SealedBatch>, Arc<AtomicUsize>, Arc<DataCell>) {
        let (tx, rx) = mpsc::channel();
        let depth = Arc::new(AtomicUsize::new(0));
        let dc = cell(c.dim);
        let a = Admission::new(c, dc.clone(), tx, depth.clone(), None);
        (a, rx, depth, dc)
    }

    #[test]
    fn seals_by_size_and_publishes_before_announcing() {
        let c = cfg(3, 4, 60_000, 64);
        let (mut a, rx, depth, dc) = admission(&c);
        let ack = a.offer(7, &chunk(10, 3, 1.0));
        assert_eq!(ack.status, IngestStatus::Accepted);
        assert_eq!(ack.seq, 7);
        assert_eq!(ack.detail, 10);
        // 10 admitted at batch_points=4: two sealed batches, 2 staged.
        let b0 = rx.try_recv().unwrap();
        let b1 = rx.try_recv().unwrap();
        assert_eq!(b0.span, 0..4);
        assert_eq!(b1.span, 4..8);
        assert!(rx.try_recv().is_err());
        assert_eq!(depth.load(Ordering::SeqCst), 2);
        assert_eq!(b0.queue_depth, 1);
        assert_eq!(b1.queue_depth, 2);
        // The published generation covers (at least) every sealed row.
        assert!(dc.get().len() >= 8);
        assert_eq!(a.staged_rows(), 2);
    }

    #[test]
    fn seals_partial_batch_on_latency_sla() {
        let c = cfg(2, 100, 0, 64); // SLA trips immediately
        let (mut a, rx, _depth, dc) = admission(&c);
        a.offer(1, &chunk(3, 2, 0.5));
        assert!(rx.try_recv().is_err(), "size alone must not seal 3 < 100");
        a.tick(Instant::now());
        let b = rx.try_recv().unwrap();
        assert_eq!(b.span, 0..3);
        assert_eq!(dc.get().len(), 3);
        assert_eq!(a.staged_rows(), 0);
        assert!(a.deadline().is_none(), "SLA clock clears once staged drains");
    }

    #[test]
    fn throttles_whole_chunks_at_the_queue_bound() {
        let c = cfg(2, 2, 60_000, 1);
        let (mut a, rx, depth, _dc) = admission(&c);
        assert_eq!(a.offer(1, &chunk(2, 2, 1.0)).status, IngestStatus::Accepted);
        assert_eq!(depth.load(Ordering::SeqCst), 1);
        // Queue full: the next chunk bounces, admitting nothing.
        let ack = a.offer(2, &chunk(2, 2, 2.0));
        assert_eq!(ack.status, IngestStatus::Throttled);
        assert_eq!(ack.detail, 1, "detail = the configured bound");
        assert_eq!(a.admitted, 2);
        assert_eq!(a.staged_rows(), 0);
        // The engine draining the queue reopens admission.
        let _ = rx.try_recv().unwrap();
        depth.fetch_sub(1, Ordering::SeqCst);
        assert_eq!(a.offer(2, &chunk(2, 2, 2.0)).status, IngestStatus::Accepted);
    }

    #[test]
    fn rejects_dim_mismatch_with_a_typed_reason() {
        let c = cfg(4, 8, 60_000, 64);
        let (mut a, rx, _depth, _dc) = admission(&c);
        let ack = a.offer(3, &chunk(2, 3, 1.0));
        assert_eq!(ack.status, IngestStatus::Rejected);
        assert!(ack.message.contains("dim 3"), "{}", ack.message);
        assert_eq!(a.admitted, 0);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn eos_seals_the_remainder_and_ends_the_source() {
        let c = cfg(2, 100, 60_000, 64);
        let (mut a, rx, depth, _dc) = admission(&c);
        a.offer(1, &chunk(5, 2, 1.0));
        a.eos();
        assert!(a.closed());
        let mut src = LiveSource::new(rx, depth);
        // Buffered batches come out before Ended.
        let SourcePoll::Ready(e) = src.poll_epoch() else { panic!("expected the remainder") };
        assert_eq!(e.span, 0..5);
        assert!(e.admitted_at.is_some());
        assert!(matches!(src.poll_epoch(), SourcePoll::Ended));
        assert!(matches!(src.poll_epoch(), SourcePoll::Ended), "Ended is sticky");
        // Admission stays closed.
        assert_eq!(a.offer(9, &chunk(1, 2, 0.0)).status, IngestStatus::Rejected);
    }

    #[test]
    fn staging_store_variants_publish_identical_generations() {
        // The sparse staging buffer (block store + seal-time
        // materialization) must publish byte-for-byte the generations the
        // dense append path does — points and norms.
        let mut published: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
        for kind in [StoreKind::Sparse, StoreKind::Dense] {
            let mut c = cfg(3, 70, 60_000, 64); // unaligned batch: seals cut inside blocks
            c.store = kind;
            let (mut a, _rx, _depth, dc) = admission(&c);
            // Chunk sizes chosen to straddle 64-row block boundaries.
            for (i, rows) in [50usize, 30, 100, 7].into_iter().enumerate() {
                let mut m = chunk(rows, 3, 0.0);
                for (j, v) in m.data.iter_mut().enumerate() {
                    *v = ((i * 131 + j) as f32).sin();
                }
                assert_eq!(a.offer(i as u64, &m).status, IngestStatus::Accepted);
            }
            a.eos(); // seals the remainder: every admitted row publishes
            let ds = dc.get();
            assert_eq!(ds.len(), 187);
            published.push((
                ds.points.data.iter().map(|v| v.to_bits()).collect(),
                ds.norms.iter().map(|v| v.to_bits()).collect(),
            ));
        }
        assert_eq!(published[0].0, published[1].0, "points must match bitwise");
        assert_eq!(published[0].1, published[1].1, "norms must match bitwise");
    }

    #[test]
    fn sparse_staging_evicts_sealed_blocks() {
        let mut c = cfg(2, 64, 60_000, 64);
        c.store = StoreKind::Sparse;
        let (mut a, _rx, _depth, _dc) = admission(&c);
        a.offer(1, &chunk(200, 2, 1.5)); // seals 0..64, 64..128, 128..192
        assert_eq!(a.staged_rows(), 8);
        // Only the straddling block 3 (rows 192..200 staged) survives.
        assert_eq!(a.staging.block_count(), 1);
        a.eos();
        assert_eq!(a.staging.block_count(), 0, "eos seal evicts the tail");
        assert_eq!(a.points.rows, 200);
    }

    #[test]
    fn live_source_is_pending_while_the_stream_is_quiet() {
        let c = cfg(2, 4, 60_000, 64);
        let (mut a, rx, depth, _dc) = admission(&c);
        let mut src = LiveSource::new(rx, depth.clone());
        assert!(matches!(src.poll_epoch(), SourcePoll::Pending));
        a.offer(1, &chunk(4, 2, 1.0));
        let SourcePoll::Ready(e) = src.poll_epoch() else { panic!("sealed batch owed") };
        assert_eq!(e.span, 0..4);
        assert_eq!(e.queue_depth, 1);
        assert_eq!(depth.load(Ordering::SeqCst), 0, "engine uptake frees the slot");
        assert!(matches!(src.poll_epoch(), SourcePoll::Pending));
    }
}
