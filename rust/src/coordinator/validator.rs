//! Validation — the master's epoch-boundary step.
//!
//! Each validator consumes the epoch's proposals *in point-index order*
//! (the serial order of Thm 3.1 / App B) and mutates the global state by
//! appending accepted centers/features. Rejected proposals are *corrected*:
//! the validator resolves the proposing point's assignment to the already-
//! accepted center that covers it (the paper's `Ref`).
//!
//! ## Sharded validation
//!
//! [`dp_validate_sharded`] and [`ofl_validate_sharded`] split the expensive
//! half of validation — proposal-pair distances — across validator shards
//! without touching the serial order. Proposals are partitioned by
//! *conflict key* (the proposing point's nearest committed center/facility:
//! points that would collide tend to come from the same region of state
//! space); same-key pair distances are precomputed in parallel as per-shard
//! conflict caches ([`shard_pairs_sorted`]), the caches are combined with a
//! deterministic tree reduce in point-index order
//! ([`ConflictCache::tree_reduce`]), then a serial merge walks all
//! proposals in point-index order, reading a cached distance when one
//! exists and computing it inline otherwise. Because a cached
//! `sqdist(a, b)` is bit-identical to the inline one — every path computes
//! distances on the canonical reduction schedule of [`crate::linalg`]
//! (8-lane strided dot, fixed combine order, per-pair clamp), regardless of
//! the configured assignment kernel — the merge's accept/reject decisions —
//! and therefore the appended state — are bit-for-bit those of the serial
//! validator for *any* key assignment and shard count.
//!
//! The shard caches can come from two places: scoped threads inside this
//! process (`dp_validate_sharded` / `ofl_validate_sharded` — the zero-setup
//! path) or *validator peers on the cluster's validation plane*
//! ([`dp_validate_clustered`] / [`ofl_validate_clustered`], driven through
//! the [`super::transport::ValidatePlane`] handle, which the wave engine's
//! dedicated validation thread owns so the fan-out overlaps compute
//! waves): each peer owns a contiguous conflict-key range and receives —
//! as a [`super::engine::Job::PairCache`] job — only the proposal rows its
//! shards
//! read, with a monotone local→global position map so its reply keys stay
//! global (`O(M·d)` wire total across the plane, since every proposal
//! belongs to exactly one shard), and replies with its sorted cache. The
//! master tree-reduces the per-peer caches and runs the same serial merge —
//! so the distributed validation plane is bit-identical to the serial
//! validator too. BP-means has no sharded variant: its accepted features
//! are *derived* residuals (each depends on the re-representation against
//! all earlier acceptances), so there is no pairwise quantity to
//! precompute.

//!
//! ## Conflict components
//!
//! [`conflict_components`] is the shared conflict-graph partitioner: a
//! zero-dependency union-find over the same per-proposal conflict keys
//! groups an epoch's points into connected components (points conflict
//! when their jobs read the same state row). The wave engine packs whole
//! components onto workers (`sharding = "conflict"`, CYCLADES-style — see
//! [`super::scheduler`]), and [`component_shards`] deals whole components
//! to validator peers so each peer's key ranges are component-aligned.
//! Like [`shard_positions`], the component grouping never splits a key
//! class, so the pair-cache invariant — and with it bit-identity — holds
//! in either sharding mode.

use super::transport::ValidatePlane;
use crate::algorithms::bpmeans::descend_z;
use crate::config::ShardingKind;
use crate::error::Result;
use crate::linalg::{sqdist, Matrix};
use std::sync::Arc;

/// A DP-means proposal: point `idx` (global) wants to open a cluster at its
/// own coordinates (the worker certified `d² > λ²` against `C^{t-1}`).
#[derive(Debug, Clone)]
pub struct DpProposal {
    /// Global point index (defines validation order).
    pub idx: u32,
    /// The proposed center coordinates (= the point).
    pub center: Vec<f32>,
}

/// Outcome of validating one epoch's DP proposals.
#[derive(Debug, Clone, Default)]
pub struct DpOutcome {
    /// `(point, global center index)` assignment for every proposal.
    pub resolved: Vec<(u32, u32)>,
    /// Number of proposals accepted as new centers.
    pub accepted: usize,
    /// Number rejected (covered by a newly accepted center).
    pub rejected: usize,
}

/// The single DP merge loop both the serial and the sharded entry points
/// share: walk proposals in point-index order, resolving each against the
/// epoch's accepted set via `dist(a, j)` — the squared distance between
/// accepted proposal `a` and proposal `j` (global positions). The provider
/// is the only thing that varies (inline `sqdist` vs the shard cache), so
/// the two paths cannot drift apart.
fn dp_merge(
    centers: &mut Matrix,
    base: usize,
    proposals: &[DpProposal],
    lambda2: f32,
    mut dist: impl FnMut(u32, u32) -> f32,
) -> DpOutcome {
    debug_assert!(proposals.windows(2).all(|w| w[0].idx < w[1].idx));
    let mut out = DpOutcome::default();
    let mut accepted: Vec<u32> = Vec::new(); // positions of accepted proposals
    for (j, p) in proposals.iter().enumerate() {
        // Nearest among the *newly accepted* centers only — the worker
        // already certified distance > λ against C^{t-1}.
        let mut best = f32::INFINITY;
        let mut best_k = usize::MAX;
        for (a_i, &a) in accepted.iter().enumerate() {
            let d = dist(a, j as u32);
            if d < best {
                best = d;
                best_k = base + a_i;
            }
        }
        if best < lambda2 {
            // Reject: Ref(x) ← nearest accepted center.
            out.resolved.push((p.idx, best_k as u32));
            out.rejected += 1;
        } else {
            centers.push_row(&p.center);
            accepted.push(j as u32);
            out.resolved.push((p.idx, (centers.rows - 1) as u32));
            out.accepted += 1;
        }
    }
    out
}

/// `DPValidate` (Alg 2). `centers[base..]` is the epoch's accepted set `Ĉ`
/// (starts empty: `base == centers.rows` on entry); accepted proposals are
/// appended to `centers`. Proposals must be sorted by `idx`.
pub fn dp_validate(centers: &mut Matrix, base: usize, proposals: &[DpProposal], lambda2: f32) -> DpOutcome {
    dp_merge(centers, base, proposals, lambda2, |a, j| {
        sqdist(&proposals[a as usize].center, &proposals[j as usize].center)
    })
}

/// Minimum proposal count for the sharded path; below this the pair-cache
/// setup costs more than the serial scan it saves.
const SHARD_MIN_PROPOSALS: usize = 48;

/// Pair-cache budget for the sharded path: the cache is `O(Σ M_s²)` and
/// stops paying for itself once an epoch's same-key pair count explodes
/// (e.g. a cold-start epoch where every point proposes under one key).
const SHARD_MAX_PAIRS: usize = 1 << 20;

/// True when sharding `keys` across `shards` buckets is worth the cache:
/// at least two non-trivial shards (degenerate keys — e.g. all `u32::MAX`
/// on a cold start — serialize the pre-computation AND pay the cache) and
/// a bounded total pair count.
fn sharding_profitable(shard_lists: &[Vec<u32>]) -> bool {
    let nontrivial = shard_lists.iter().filter(|s| s.len() >= 2).count();
    let pairs: usize =
        shard_lists.iter().map(|s| s.len() * s.len().saturating_sub(1) / 2).sum();
    nontrivial >= 2 && pairs <= SHARD_MAX_PAIRS
}

/// Partition positions `0..keys.len()` into `shards` buckets by conflict
/// key. Iteration order is preserved, so two proposals with the same key
/// land in the same bucket *in their original (point-index) order* — the
/// invariant the pair cache relies on.
pub fn shard_positions(keys: &[u32], shards: usize) -> Vec<Vec<u32>> {
    let s = shards.max(1);
    let mut out = vec![Vec::new(); s];
    for (pos, &k) in keys.iter().enumerate() {
        out[(k as usize) % s].push(pos as u32);
    }
    out
}

/// Minimal union-find over positions `0..n`: path-halving `find`, and a
/// `union` that always keeps the *smaller* root as representative, so a
/// component's representative is its smallest member no matter the union
/// order — the determinism the partitioner's output ordering rests on.
struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn new(n: usize) -> UnionFind {
        UnionFind { parent: (0..n as u32).collect() }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let grandparent = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = grandparent;
            x = grandparent;
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra < rb {
            self.parent[rb as usize] = ra;
        } else if rb < ra {
            self.parent[ra as usize] = rb;
        }
    }
}

/// Connected components of an epoch's conflict graph: positions `i` and
/// `j` conflict when `keys[i] == keys[j]` — their jobs read (or their
/// proposals contend for) the same state row. `u32::MAX` ("no committed
/// row yet") is a key class like any other, which makes the cold-start
/// pile-up one big component rather than a false all-clear.
///
/// Components are emitted in deterministic point-index order — ordered by
/// smallest member, members ascending within each — so the partition is a
/// pure function of the key sequence: relabeling key values bijectively or
/// discovering the unions in a different order cannot change the output
/// (`tests/coordinator_props.rs` pins this down, along with exact cover
/// and conflict-closure).
pub fn conflict_components(keys: &[u32]) -> Vec<Vec<u32>> {
    let mut uf = UnionFind::new(keys.len());
    // Sort (key, position) pairs to find same-key neighbours without
    // hashing; unioning consecutive occurrences chains each class.
    let mut by_key: Vec<(u32, u32)> =
        keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
    by_key.sort_unstable();
    for w in by_key.windows(2) {
        if w[0].0 == w[1].0 {
            uf.union(w[0].1, w[1].1);
        }
    }
    // Ascending scan ⇒ components ordered by smallest member, members
    // ascending. A root is ≤ every member of its component, so its slot is
    // always allocated before any later member arrives.
    let mut slot: Vec<usize> = vec![usize::MAX; keys.len()];
    let mut out: Vec<Vec<u32>> = Vec::new();
    for i in 0..keys.len() as u32 {
        let r = uf.find(i) as usize;
        if slot[r] == usize::MAX {
            slot[r] = out.len();
            out.push(Vec::new());
        }
        out[slot[r]].push(i);
    }
    out
}

/// Component-aligned shard lists for the validation plane: whole
/// [`conflict_components`] are dealt to `shards` buckets (least-loaded
/// bucket first, lowest index on ties), then each bucket is sorted back
/// into point-index order. Like [`shard_positions`] this never splits a
/// key class across buckets — the pair-cache invariant — but each
/// validator now owns whole conflict neighbourhoods instead of a
/// hash-residue scatter, and the load is balanced by actual proposal
/// count rather than by key arithmetic.
pub fn component_shards(keys: &[u32], shards: usize) -> Vec<Vec<u32>> {
    let s = shards.max(1);
    let mut out: Vec<Vec<u32>> = vec![Vec::new(); s];
    for comp in conflict_components(keys) {
        let target = (0..s).min_by_key(|&b| out[b].len()).unwrap_or(0);
        out[target].extend_from_slice(&comp);
    }
    for bucket in &mut out {
        bucket.sort_unstable();
    }
    out
}

/// The shard-list choice every sharded entry point shares: hash-residue
/// buckets or component-aligned buckets. Either satisfies the same-key ⇒
/// same-shard invariant, so the merge below is bit-identical regardless.
fn shard_lists_for(keys: &[u32], buckets: usize, sharding: ShardingKind) -> Vec<Vec<u32>> {
    match sharding {
        ShardingKind::Hash => shard_positions(keys, buckets),
        ShardingKind::Conflict => component_shards(keys, buckets),
    }
}

/// Pairwise squared distances between all proposals of one shard, keyed by
/// `(earlier position, later position)` in the global proposal list.
fn shard_pair_cache(vectors: &[&[f32]], shard: &[u32]) -> Vec<(u32, u32, f32)> {
    let mut out = Vec::with_capacity(shard.len().saturating_sub(1) * shard.len() / 2);
    for (i, &a) in shard.iter().enumerate() {
        for &b in &shard[i + 1..] {
            out.push((a, b, sqdist(vectors[a as usize], vectors[b as usize])));
        }
    }
    out
}

/// One peer's conflict-cache contribution: every within-shard pair distance
/// of `shard_lists`, lexicographically sorted by `(a, b)` — global proposal
/// positions, i.e. point-index order. This is the payload a validator peer
/// computes for a [`super::engine::Job::PairCache`] job.
pub fn shard_pairs_sorted(vectors: &[&[f32]], shard_lists: &[Vec<u32>]) -> Vec<(u32, u32, f32)> {
    let mut out = Vec::new();
    for shard in shard_lists {
        out.extend(shard_pair_cache(vectors, shard));
    }
    out.sort_unstable_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
    out
}

/// The combined cross-proposal conflict cache the serial merge reads from:
/// `(a, b, d²)` pairs sorted by `(a, b)` global proposal position.
#[derive(Debug, Clone, Default)]
pub struct ConflictCache {
    pairs: Vec<(u32, u32, f32)>,
}

impl ConflictCache {
    /// Combine per-shard caches with a deterministic pairwise tree reduce:
    /// each round merges neighbouring sorted lists in point-index order
    /// until one remains. Every pair lives in exactly one shard (same key ⇒
    /// same shard), so the merge never sees duplicates and the result is
    /// independent of how shards were grouped onto peers or threads.
    pub fn tree_reduce(mut lists: Vec<Vec<(u32, u32, f32)>>) -> ConflictCache {
        while lists.len() > 1 {
            let mut next = Vec::with_capacity(lists.len().div_ceil(2));
            let mut it = lists.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => next.push(merge_sorted(a, b)),
                    None => next.push(a),
                }
            }
            lists = next;
        }
        ConflictCache { pairs: lists.pop().unwrap_or_default() }
    }

    /// Cached distance between accepted proposal `a` and proposal `b`.
    #[inline]
    pub fn get(&self, a: u32, b: u32) -> Option<f32> {
        self.pairs
            .binary_search_by(|probe| (probe.0, probe.1).cmp(&(a, b)))
            .ok()
            .map(|i| self.pairs[i].2)
    }

    /// Number of cached pairs.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// True when no pairs are cached.
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

/// Merge two `(a, b, d²)` lists sorted by `(a, b)` into one.
fn merge_sorted(
    a: Vec<(u32, u32, f32)>,
    b: Vec<(u32, u32, f32)>,
) -> Vec<(u32, u32, f32)> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if (a[i].0, a[i].1) <= (b[j].0, b[j].1) {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

/// Build the cross-proposal distance cache on scoped threads: same-key
/// pairs in parallel, tree-reduced into one [`ConflictCache`].
///
/// Threads are capped at half the shard count (≥ 1): under the pipelined
/// scheduler this precompute runs while all `P` workers are busy on the
/// next epoch's speculative wave, so claiming a full `P` threads here would
/// oversubscribe the machine during exactly the window the overlap exists
/// to exploit.
fn build_pair_cache(vectors: &[&[f32]], shard_lists: &[Vec<u32>]) -> ConflictCache {
    let work: Vec<&Vec<u32>> = shard_lists.iter().filter(|s| s.len() >= 2).collect();
    let threads = (shard_lists.len() / 2).clamp(1, work.len().max(1));
    let per_thread = work.len().div_ceil(threads);
    let mut lists = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = work
            .chunks(per_thread)
            .map(|group| {
                let group: Vec<Vec<u32>> = group.iter().map(|s| (*s).clone()).collect();
                scope.spawn(move || shard_pairs_sorted(vectors, &group))
            })
            .collect();
        for h in handles {
            lists.push(h.join().expect("shard thread panicked"));
        }
    });
    ConflictCache::tree_reduce(lists)
}

/// Distance from proposal `j` to accepted proposal `a` (`a < j` in the
/// global order): cache hit when they shared a conflict key, inline
/// `sqdist` otherwise — bit-identical either way.
#[inline]
fn pair_d2(cache: &ConflictCache, vectors: &[&[f32]], a: u32, j: u32) -> f32 {
    match cache.get(a, j) {
        Some(d) => d,
        None => sqdist(vectors[a as usize], vectors[j as usize]),
    }
}

/// Build the conflict cache on the cluster's validation plane: partition
/// the shard lists into contiguous conflict-key ranges (one per validator
/// peer), ship them as [`super::engine::Job::PairCache`] jobs through the
/// transport, and tree-reduce the gathered per-shard caches.
///
/// Cost note: materializing the proposal vectors as one `Matrix` is an
/// `O(M·d)` copy per engaged epoch — paid on both transports, because the
/// design point of the validation plane is that shards are *peers* (the
/// in-proc transport then ships the matrix by `Arc`, zero further
/// copies). It is dwarfed by the `O(ΣM_s²·d)` pair computation that
/// follows; embedders who want the copy-free scoped-thread variant can
/// still call [`dp_validate_sharded`] / [`ofl_validate_sharded`] directly.
fn build_pair_cache_clustered(
    vplane: &mut ValidatePlane,
    vectors: &[&[f32]],
    shard_lists: Vec<Vec<u32>>,
) -> Result<ConflictCache> {
    let dim = vectors.first().map(|v| v.len()).unwrap_or(0);
    let mut vmat =
        Matrix { rows: 0, cols: dim, data: Vec::with_capacity(vectors.len() * dim) };
    for v in vectors {
        vmat.push_row(v);
    }
    let lists = vplane.pair_cache(Arc::new(vmat), shard_lists)?;
    Ok(ConflictCache::tree_reduce(lists))
}

/// The one guard-and-merge skeleton every sharded DP entry point shares:
/// fall back to the serial validator unless the cache is `engaged` and
/// profitable, otherwise build the conflict cache via `build` (scoped
/// threads or validator peers — the only varying part) and run the serial
/// merge over it. Keeping the skeleton single-sourced is what guarantees
/// the thread path and the peer path cannot drift apart.
#[allow(clippy::too_many_arguments)]
fn dp_validate_with(
    centers: &mut Matrix,
    base: usize,
    proposals: &[DpProposal],
    keys: &[u32],
    lambda2: f32,
    buckets: usize,
    sharding: ShardingKind,
    engaged: bool,
    build: impl FnOnce(&[&[f32]], Vec<Vec<u32>>) -> Result<ConflictCache>,
) -> Result<DpOutcome> {
    debug_assert_eq!(proposals.len(), keys.len());
    if !engaged || proposals.len() < SHARD_MIN_PROPOSALS {
        return Ok(dp_validate(centers, base, proposals, lambda2));
    }
    let shard_lists = shard_lists_for(keys, buckets, sharding);
    if !sharding_profitable(&shard_lists) {
        return Ok(dp_validate(centers, base, proposals, lambda2));
    }
    let vectors: Vec<&[f32]> = proposals.iter().map(|p| p.center.as_slice()).collect();
    let cache = build(&vectors, shard_lists)?;
    // Same merge loop as the serial path, fed from the cache — the Thm 3.1
    // point-index order, bit-for-bit.
    Ok(dp_merge(centers, base, proposals, lambda2, |a, j| pair_d2(&cache, &vectors, a, j)))
}

/// `DPValidate` with sharded conflict pre-computation on scoped threads.
/// Produces the exact [`dp_validate`] outcome (same resolutions, same
/// appended rows, same bits) for any `keys`/`shards`; `keys[i]` is
/// proposal `i`'s conflict key (e.g. its nearest committed center,
/// `u32::MAX` when none).
pub fn dp_validate_sharded(
    centers: &mut Matrix,
    base: usize,
    proposals: &[DpProposal],
    keys: &[u32],
    lambda2: f32,
    shards: usize,
) -> DpOutcome {
    // shards < 4 would leave build_pair_cache with a single thread (it caps
    // at shards/2): all cache cost, no parallelism — serial wins there.
    dp_validate_with(
        centers,
        base,
        proposals,
        keys,
        lambda2,
        shards,
        ShardingKind::Hash,
        shards >= 4,
        |v, lists| Ok(build_pair_cache(v, &lists)),
    )
    .expect("in-process cache build cannot fail")
}

/// `DPValidate` with the conflict pre-computation dispatched to validator
/// peers on the cluster's validation plane (the [`ValidatePlane`] handle —
/// owned by the wave engine's dedicated validation thread, so the fan-out
/// overlaps compute waves). Produces the exact [`dp_validate`] outcome —
/// same resolutions, same appended rows, same bits — for any `keys`, shard
/// count, sharding mode and transport; falls back to the serial validator
/// when sharding would not pay for itself.
#[allow(clippy::too_many_arguments)]
pub fn dp_validate_clustered(
    vplane: &mut ValidatePlane,
    centers: &mut Matrix,
    base: usize,
    proposals: &[DpProposal],
    keys: &[u32],
    lambda2: f32,
    shards: usize,
    sharding: ShardingKind,
) -> Result<DpOutcome> {
    let engaged = vplane.validators >= 2;
    dp_validate_with(
        centers,
        base,
        proposals,
        keys,
        lambda2,
        shards.max(2),
        sharding,
        engaged,
        |v, lists| build_pair_cache_clustered(vplane, v, lists),
    )
}

/// The OFL counterpart of [`dp_validate_with`]: one skeleton, two cache
/// builders.
#[allow(clippy::too_many_arguments)]
fn ofl_validate_with(
    centers: &mut Matrix,
    base: usize,
    proposals: &[OflProposal],
    keys: &[u32],
    lambda2: f64,
    draw: impl FnMut(u32) -> f64,
    buckets: usize,
    sharding: ShardingKind,
    engaged: bool,
    build: impl FnOnce(&[&[f32]], Vec<Vec<u32>>) -> Result<ConflictCache>,
) -> Result<OflOutcome> {
    debug_assert_eq!(proposals.len(), keys.len());
    if !engaged || proposals.len() < SHARD_MIN_PROPOSALS {
        return Ok(ofl_validate(centers, base, proposals, lambda2, draw));
    }
    let shard_lists = shard_lists_for(keys, buckets, sharding);
    if !sharding_profitable(&shard_lists) {
        return Ok(ofl_validate(centers, base, proposals, lambda2, draw));
    }
    let vectors: Vec<&[f32]> = proposals.iter().map(|p| p.center.as_slice()).collect();
    let cache = build(&vectors, shard_lists)?;
    Ok(ofl_merge(centers, base, proposals, lambda2, draw, |a, j| {
        pair_d2(&cache, &vectors, a, j)
    }))
}

/// `OFLValidate` over the cluster's validation plane — the exact
/// [`ofl_validate`] outcome for any `keys`, shard count and transport (see
/// [`dp_validate_clustered`]).
#[allow(clippy::too_many_arguments)]
pub fn ofl_validate_clustered(
    vplane: &mut ValidatePlane,
    centers: &mut Matrix,
    base: usize,
    proposals: &[OflProposal],
    keys: &[u32],
    lambda2: f64,
    draw: impl FnMut(u32) -> f64,
    shards: usize,
    sharding: ShardingKind,
) -> Result<OflOutcome> {
    let engaged = vplane.validators >= 2;
    ofl_validate_with(
        centers,
        base,
        proposals,
        keys,
        lambda2,
        draw,
        shards.max(2),
        sharding,
        engaged,
        |v, lists| build_pair_cache_clustered(vplane, v, lists),
    )
}

/// `OFLValidate` with sharded conflict pre-computation — the exact
/// [`ofl_validate`] outcome for any `keys`/`shards` (see
/// [`dp_validate_sharded`]).
pub fn ofl_validate_sharded(
    centers: &mut Matrix,
    base: usize,
    proposals: &[OflProposal],
    keys: &[u32],
    lambda2: f64,
    draw: impl FnMut(u32) -> f64,
    shards: usize,
) -> OflOutcome {
    // shards < 4 would leave build_pair_cache with a single thread (it caps
    // at shards/2): all cache cost, no parallelism — serial wins there.
    ofl_validate_with(
        centers,
        base,
        proposals,
        keys,
        lambda2,
        draw,
        shards,
        ShardingKind::Hash,
        shards >= 4,
        |v, lists| Ok(build_pair_cache(v, &lists)),
    )
    .expect("in-process cache build cannot fail")
}

/// An OFL proposal: point `idx` was sent to the master with probability
/// `min(1, d²_prev/λ²)` using its pre-drawn uniform.
#[derive(Debug, Clone)]
pub struct OflProposal {
    /// Global point index (defines validation order).
    pub idx: u32,
    /// The point's coordinates (candidate facility).
    pub center: Vec<f32>,
    /// Squared distance to the nearest center of `C^{t-1}` (`+inf` if none).
    pub d2_prev: f32,
    /// Index of that nearest center (`u32::MAX` if none).
    pub idx_prev: u32,
}

/// Outcome of validating one epoch's OFL proposals.
#[derive(Debug, Clone, Default)]
pub struct OflOutcome {
    /// `(point, global facility index)` for every proposal.
    pub resolved: Vec<(u32, u32)>,
    /// Facilities opened.
    pub accepted: usize,
    /// Proposals assigned to an existing facility instead.
    pub rejected: usize,
    /// The point that opened each accepted facility, in acceptance order
    /// (parallel to the appended center rows).
    pub opened: Vec<u32>,
}

/// The single OFL merge loop both the serial and the sharded entry points
/// share (see [`dp_merge`] for the pattern): `dist(a, j)` provides the
/// squared distance between accepted proposal `a` and proposal `j`.
fn ofl_merge(
    centers: &mut Matrix,
    base: usize,
    proposals: &[OflProposal],
    lambda2: f64,
    mut draw: impl FnMut(u32) -> f64,
    mut dist: impl FnMut(u32, u32) -> f32,
) -> OflOutcome {
    debug_assert!(proposals.windows(2).all(|w| w[0].idx < w[1].idx));
    let mut out = OflOutcome::default();
    let mut accepted: Vec<u32> = Vec::new();
    for (j, p) in proposals.iter().enumerate() {
        // Nearest among this epoch's accepted facilities Ĉ.
        let mut best_new = f32::INFINITY;
        let mut best_new_k = usize::MAX;
        for (a_i, &a) in accepted.iter().enumerate() {
            let d = dist(a, j as u32);
            if d < best_new {
                best_new = d;
                best_new_k = base + a_i;
            }
        }
        let d2_full = p.d2_prev.min(best_new) as f64;
        let p_acc = if d2_full.is_infinite() { 1.0 } else { (d2_full / lambda2).min(1.0) };
        if draw(p.idx) < p_acc {
            centers.push_row(&p.center);
            accepted.push(j as u32);
            out.resolved.push((p.idx, (centers.rows - 1) as u32));
            out.opened.push(p.idx);
            out.accepted += 1;
        } else {
            // Assign to the nearest open facility (old or newly accepted).
            let target = if best_new < p.d2_prev { best_new_k as u32 } else { p.idx_prev };
            out.resolved.push((p.idx, target));
            out.rejected += 1;
        }
    }
    out
}

/// `OFLValidate` (Alg 5), with the telescoped acceptance probability of the
/// Thm 3.1 proof: accept with probability `min(1, d²_full/λ²) /
/// min(1, d²_prev/λ²)`, realized by re-using the point's own uniform draw
/// `draw(idx)` — this makes the distributed run *bit-identical* to the
/// serial OFL pass with the same per-point draws.
pub fn ofl_validate(
    centers: &mut Matrix,
    base: usize,
    proposals: &[OflProposal],
    lambda2: f64,
    draw: impl FnMut(u32) -> f64,
) -> OflOutcome {
    ofl_merge(centers, base, proposals, lambda2, draw, |a, j| {
        sqdist(&proposals[a as usize].center, &proposals[j as usize].center)
    })
}

/// A BP-means proposal: point `idx`'s residual after coordinate descent
/// over `F^{t-1}` exceeded λ².
#[derive(Debug, Clone)]
pub struct BpProposal {
    /// Global point index (defines validation order).
    pub idx: u32,
    /// The residual `x − Σ z f` proposed as a new feature.
    pub residual: Vec<f32>,
}

/// Resolution of one BP proposal.
#[derive(Debug, Clone)]
pub struct BpResolution {
    /// Proposing point.
    pub idx: u32,
    /// Global indices of newly-accepted features the residual was
    /// re-represented with (the `Ref` combination, `z_i ⊕ Ref(f_new)`).
    pub extra_features: Vec<u32>,
    /// Global index of the point's own accepted feature, if any.
    pub own_feature: Option<u32>,
}

/// Outcome of validating one epoch's BP proposals.
#[derive(Debug, Clone, Default)]
pub struct BpOutcome {
    /// Per-proposal resolution, in order.
    pub resolved: Vec<BpResolution>,
    /// Features accepted.
    pub accepted: usize,
    /// Proposals fully represented by earlier-accepted features.
    pub rejected: usize,
}

/// `BPValidate` (Alg 8). Re-represents each proposed residual over the
/// epoch's accepted feature set `features[base..]`; if the re-representation
/// error still exceeds λ², the *remaining* residual is accepted as a new
/// feature. Proposals must be sorted by `idx`.
pub fn bp_validate(
    features: &mut Matrix,
    base: usize,
    proposals: &[BpProposal],
    lambda2: f32,
    sweeps: usize,
) -> BpOutcome {
    debug_assert!(proposals.windows(2).all(|w| w[0].idx < w[1].idx));
    let mut out = BpOutcome::default();
    let d = features.cols;
    let mut residual = vec![0.0f32; d];
    for p in proposals {
        // View of the newly accepted features only.
        let new_k = features.rows - base;
        let mut z = vec![false; new_k];
        let r2 = if new_k == 0 {
            residual.copy_from_slice(&p.residual);
            crate::linalg::norm2(&residual)
        } else {
            // Build a temporary matrix over the accepted slice (cheap: K_new
            // is small — it is bounded by the epoch's acceptances).
            let view = Matrix {
                rows: new_k,
                cols: d,
                data: features.data[base * d..].to_vec(),
            };
            descend_z(&p.residual, &view, &mut z, &mut residual, sweeps)
        };
        let extra: Vec<u32> =
            z.iter().enumerate().filter(|(_, &on)| on).map(|(j, _)| (base + j) as u32).collect();
        if r2 > lambda2 {
            features.push_row(&residual);
            out.resolved.push(BpResolution {
                idx: p.idx,
                extra_features: extra,
                own_feature: Some((features.rows - 1) as u32),
            });
            out.accepted += 1;
        } else {
            out.resolved.push(BpResolution { idx: p.idx, extra_features: extra, own_feature: None });
            out.rejected += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: &[&[f32]]) -> Matrix {
        let cols = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut m = Matrix::zeros(0, cols);
        for r in rows {
            m.push_row(r);
        }
        m
    }

    #[test]
    fn dp_validate_accepts_spread_rejects_covered() {
        let mut centers = mat(&[&[100.0, 100.0]]); // pre-existing center (ignored)
        let proposals = vec![
            DpProposal { idx: 1, center: vec![0.0, 0.0] },
            DpProposal { idx: 3, center: vec![0.5, 0.0] },   // within λ of first → reject
            DpProposal { idx: 7, center: vec![10.0, 0.0] },  // far → accept
        ];
        let out = dp_validate(&mut centers, 1, &proposals, 1.0);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(centers.rows, 3);
        assert_eq!(out.resolved[0], (1, 1)); // own new center
        assert_eq!(out.resolved[1], (3, 1)); // Ref → first accepted
        assert_eq!(out.resolved[2], (7, 2));
    }

    #[test]
    fn dp_validate_ignores_preexisting_centers() {
        // The worker certified distance to C^{t-1}; validation must not
        // re-check it (a proposal near an old center is still accepted —
        // matches Alg 2 where C starts empty).
        let mut centers = mat(&[&[0.0, 0.0]]);
        let proposals = vec![DpProposal { idx: 0, center: vec![0.1, 0.0] }];
        let out = dp_validate(&mut centers, 1, &proposals, 1.0);
        assert_eq!(out.accepted, 1);
        assert_eq!(centers.rows, 2);
    }

    #[test]
    fn dp_validate_boundary_exactly_lambda() {
        // d² == λ² is NOT < λ² → accepted (worker-side rule is d² > λ²,
        // so the pair is consistent: both use strict comparisons).
        let mut centers = Matrix::zeros(0, 1);
        let proposals = vec![
            DpProposal { idx: 0, center: vec![0.0] },
            DpProposal { idx: 1, center: vec![1.0] }, // d² = 1 = λ²
        ];
        let out = dp_validate(&mut centers, 0, &proposals, 1.0);
        assert_eq!(out.accepted, 2);
    }

    #[test]
    fn ofl_validate_first_epoch_behaves_serially() {
        // Empty prior state: d2_prev = inf. With draws forcing open/skip we
        // can script the outcome.
        let mut centers = Matrix::zeros(0, 1);
        let proposals = vec![
            OflProposal { idx: 0, center: vec![0.0], d2_prev: f32::INFINITY, idx_prev: u32::MAX },
            OflProposal { idx: 1, center: vec![0.5], d2_prev: f32::INFINITY, idx_prev: u32::MAX },
            OflProposal { idx: 2, center: vec![10.0], d2_prev: f32::INFINITY, idx_prev: u32::MAX },
        ];
        // Point 0: p_acc = 1 → opens. Point 1: d2_full = 0.25 → p = 0.25;
        // draw 0.5 → assigned to facility 0. Point 2: d2_full = 90.25 → p=1.
        let draws = [0.9, 0.5, 0.3];
        let out = ofl_validate(&mut centers, 0, &proposals, 1.0, |i| draws[i as usize]);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(out.resolved[1], (1, 0));
        assert_eq!(centers.rows, 2);
    }

    #[test]
    fn ofl_validate_telescoped_probability() {
        // Worker sent with p_send = min(1, d2_prev/λ²); master must accept
        // iff draw < min(1, d2_full/λ²). d2_full ≤ d2_prev so acceptance is
        // a subset of sends — check the boundary.
        let mut centers = mat(&[&[0.0]]); // facility accepted this epoch
        let proposals = vec![OflProposal {
            idx: 5,
            center: vec![0.6], // d² to new facility = 0.36; d2_prev = 0.81
            d2_prev: 0.81,
            idx_prev: 7,
        }];
        // draw = 0.5: sent (0.5 < 0.81) but NOT accepted (0.5 ≥ 0.36) →
        // assigned to the closer, newly accepted facility 0.
        let out = ofl_validate(&mut centers, 0, &proposals, 1.0, |_| 0.5);
        assert_eq!(out.rejected, 1);
        assert_eq!(out.resolved[0], (5, 0));
        // draw = 0.3: accepted.
        let mut centers2 = mat(&[&[0.0]]);
        let proposals2 = vec![OflProposal { idx: 5, center: vec![0.6], d2_prev: 0.81, idx_prev: 7 }];
        let out2 = ofl_validate(&mut centers2, 0, &proposals2, 1.0, |_| 0.3);
        assert_eq!(out2.accepted, 1);
    }

    #[test]
    fn ofl_rejected_points_keep_old_facility_when_closer() {
        let mut centers = mat(&[&[10.0]]); // new facility far away
        let proposals = vec![OflProposal { idx: 2, center: vec![0.5], d2_prev: 0.25, idx_prev: 3 }];
        let out = ofl_validate(&mut centers, 0, &proposals, 1.0, |_| 0.9);
        assert_eq!(out.resolved[0], (2, 3)); // old facility 3 is closer
    }

    #[test]
    fn bp_validate_accepts_and_rerepresents() {
        let mut features = Matrix::zeros(0, 2);
        let proposals = vec![
            BpProposal { idx: 0, residual: vec![2.0, 0.0] },
            BpProposal { idx: 1, residual: vec![2.0, 0.0] }, // fully covered → reject
            BpProposal { idx: 2, residual: vec![2.0, 2.0] }, // partially covered → accept remainder
        ];
        let out = bp_validate(&mut features, 0, &proposals, 0.01, 2);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(features.rows, 2);
        // Proposal 1: represented by feature 0, no own feature.
        assert_eq!(out.resolved[1].extra_features, vec![0]);
        assert!(out.resolved[1].own_feature.is_none());
        // Proposal 2: uses feature 0, contributes the remainder (0, 2).
        assert_eq!(out.resolved[2].extra_features, vec![0]);
        assert_eq!(out.resolved[2].own_feature, Some(1));
        assert_eq!(features.row(1), &[0.0, 2.0]);
    }

    // -----------------------------------------------------------------
    // Sharded validation: partition/merge invariants + exact equivalence
    // on seeded adversarial interleavings.
    // -----------------------------------------------------------------

    use crate::rng::Pcg64;

    /// Clustered proposal set: points drawn near a few tight modes so that
    /// conflicts are plentiful, with *sparse, shuffled* global indices so
    /// the merge has real interleaving to restore.
    fn adversarial_proposals(seed: u64, n: usize, modes: usize) -> (Vec<DpProposal>, Vec<u32>) {
        let mut rng = Pcg64::new(seed);
        // Sparse strictly-increasing global indices.
        let mut idx = 0u32;
        let mut proposals = Vec::with_capacity(n);
        let mut keys = Vec::with_capacity(n);
        for _ in 0..n {
            idx += 1 + (rng.next_below(7) as u32);
            let mode = rng.next_below(modes as u64) as usize;
            let cx = mode as f32 * 10.0 + rng.next_f32() * 0.8;
            let cy = rng.next_f32() * 0.8;
            proposals.push(DpProposal { idx, center: vec![cx, cy] });
            // Adversarial keys: *uncorrelated* with geometry, so conflicts
            // routinely straddle shards and the merge's inline-distance
            // fallback is exercised. u32::MAX mixed in like a cold start.
            keys.push(if rng.next_u64() & 3 == 0 { u32::MAX } else { rng.next_below(5) as u32 });
        }
        (proposals, keys)
    }

    #[test]
    fn shard_positions_never_reorders_same_key_pairs() {
        let mut rng = Pcg64::new(7);
        let keys: Vec<u32> =
            (0..500).map(|_| if rng.next_u64() & 1 == 0 { u32::MAX } else { rng.next_below(9) as u32 }).collect();
        for shards in [1usize, 2, 3, 8, 64] {
            let lists = shard_positions(&keys, shards);
            // Every position appears exactly once.
            let mut seen = vec![false; keys.len()];
            for list in &lists {
                // Within a shard, positions are strictly increasing — two
                // proposals with the same key can never swap order.
                assert!(list.windows(2).all(|w| w[0] < w[1]), "shard reordered positions");
                for &p in list {
                    assert!(!seen[p as usize], "position {p} duplicated");
                    seen[p as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "positions dropped");
            // Same key ⇒ same shard.
            let mut shard_of = vec![usize::MAX; keys.len()];
            for (s, list) in lists.iter().enumerate() {
                for &p in list {
                    shard_of[p as usize] = s;
                }
            }
            let mut key_shard: std::collections::HashMap<u32, usize> = Default::default();
            for (i, &k) in keys.iter().enumerate() {
                let s = *key_shard.entry(k).or_insert(shard_of[i]);
                assert_eq!(s, shard_of[i], "same-key pair split across shards");
            }
        }
    }

    #[test]
    fn conflict_components_group_key_classes_in_point_order() {
        // keys:  0  7  0  3  7  9  → components {0,2}, {1,4}, {3}, {5},
        // ordered by smallest member, members ascending.
        let comps = conflict_components(&[0u32, 7, 0, 3, 7, 9]);
        assert_eq!(comps, vec![vec![0u32, 2], vec![1, 4], vec![3], vec![5]]);
        // u32::MAX ("no committed row") is a key class like any other — the
        // cold-start pile-up is one component, not a false all-clear.
        let comps = conflict_components(&[u32::MAX, 1, u32::MAX]);
        assert_eq!(comps, vec![vec![0u32, 2], vec![1]]);
        assert!(conflict_components(&[]).is_empty());
        // Bijectively relabeling key values cannot change the partition.
        let a = conflict_components(&[4u32, 8, 4, 8, 2]);
        let b = conflict_components(&[90u32, 3, 90, 3, 77]);
        assert_eq!(a, b);
    }

    #[test]
    fn component_shards_never_split_a_key_class_and_stay_sorted() {
        let (_, keys) = adversarial_proposals(5, 300, 6);
        let lists = component_shards(&keys, 4);
        assert_eq!(lists.len(), 4);
        let mut seen = vec![false; keys.len()];
        let mut bucket_of_key: std::collections::HashMap<u32, usize> = Default::default();
        for (b, list) in lists.iter().enumerate() {
            assert!(list.windows(2).all(|w| w[0] < w[1]), "bucket {b} not in point order");
            for &pos in list {
                assert!(!seen[pos as usize], "position {pos} duplicated");
                seen[pos as usize] = true;
                let k = keys[pos as usize];
                let owner = *bucket_of_key.entry(k).or_insert(b);
                assert_eq!(owner, b, "key {k} split across buckets");
            }
        }
        assert!(seen.iter().all(|&s| s), "positions dropped");
        // The component-aligned lists satisfy the same invariants the hash
        // lists do, so the sharded merge stays exact over them too.
        let (proposals, keys) = adversarial_proposals(6, 200, 5);
        let mut serial_c = Matrix::zeros(0, 2);
        let serial = dp_validate(&mut serial_c, 0, &proposals, 1.0);
        let mut c = Matrix::zeros(0, 2);
        let out = dp_validate_with(
            &mut c,
            0,
            &proposals,
            &keys,
            1.0,
            4,
            ShardingKind::Conflict,
            true,
            |v, lists| Ok(build_pair_cache(v, &lists)),
        )
        .unwrap();
        assert_eq!(out.resolved, serial.resolved);
        assert_eq!(c.data, serial_c.data, "appended state diverged");
    }

    #[test]
    fn dp_sharded_merge_restores_point_index_order() {
        let (proposals, keys) = adversarial_proposals(11, 120, 4);
        let mut centers = Matrix::zeros(0, 2);
        let out = dp_validate_sharded(&mut centers, 0, &proposals, &keys, 1.0, 4);
        // Resolutions come back in exact point-index order regardless of
        // how proposals were sharded.
        let resolved_idx: Vec<u32> = out.resolved.iter().map(|(i, _)| *i).collect();
        let proposal_idx: Vec<u32> = proposals.iter().map(|p| p.idx).collect();
        assert_eq!(resolved_idx, proposal_idx);
    }

    #[test]
    fn dp_sharded_equals_serial_on_adversarial_interleavings() {
        for seed in [1u64, 2, 3, 4, 5] {
            let (proposals, keys) = adversarial_proposals(seed, 200, 5);
            let mut serial_c = mat(&[&[500.0, 500.0]]); // pre-existing row
            let serial = dp_validate(&mut serial_c, 1, &proposals, 1.0);
            // 2 exercises the serial fallback (< 4 shards), 4 and 8 the
            // parallel cache path.
            for shards in [2usize, 4, 8] {
                let mut sharded_c = mat(&[&[500.0, 500.0]]);
                let sharded =
                    dp_validate_sharded(&mut sharded_c, 1, &proposals, &keys, 1.0, shards);
                assert_eq!(sharded.resolved, serial.resolved, "seed={seed} shards={shards}");
                assert_eq!(sharded.accepted, serial.accepted);
                assert_eq!(sharded.rejected, serial.rejected);
                assert_eq!(sharded_c.data, serial_c.data, "appended state diverged");
            }
        }
    }

    #[test]
    fn ofl_sharded_equals_serial_on_adversarial_interleavings() {
        for seed in [21u64, 22, 23] {
            let (dp_props, keys) = adversarial_proposals(seed, 160, 4);
            let mut rng = Pcg64::new(seed ^ 0xBEEF);
            let proposals: Vec<OflProposal> = dp_props
                .into_iter()
                .map(|p| {
                    let far = rng.next_u64() & 3 == 0;
                    OflProposal {
                        idx: p.idx,
                        center: p.center,
                        d2_prev: if far { f32::INFINITY } else { 0.3 + rng.next_f32() },
                        idx_prev: if far { u32::MAX } else { rng.next_below(7) as u32 },
                    }
                })
                .collect();
            // Deterministic per-point draws shared by both paths.
            let draw = |i: u32| ((i as u64).wrapping_mul(0x9E37_79B9) % 1000) as f64 / 1000.0;
            let mut serial_c = Matrix::zeros(0, 2);
            let serial = ofl_validate(&mut serial_c, 0, &proposals, 1.0, draw);
            for shards in [2usize, 4] {
                let mut sharded_c = Matrix::zeros(0, 2);
                let sharded =
                    ofl_validate_sharded(&mut sharded_c, 0, &proposals, &keys, 1.0, draw, shards);
                assert_eq!(sharded.resolved, serial.resolved, "seed={seed} shards={shards}");
                assert_eq!(sharded.opened, serial.opened);
                assert_eq!(sharded_c.data, serial_c.data);
            }
        }
    }

    #[test]
    fn degenerate_keys_fall_back_to_serial_and_stay_exact() {
        // Cold-start shape: every proposal carries the same key (u32::MAX —
        // no committed centers), so sharding would serialize the pair
        // pre-computation; the entry point must skip the cache and still
        // produce the exact serial outcome.
        let (proposals, _) = adversarial_proposals(41, 200, 5);
        let keys = vec![u32::MAX; proposals.len()];
        assert!(!sharding_profitable(&shard_positions(&keys, 8)));
        let mut a = Matrix::zeros(0, 2);
        let mut b = Matrix::zeros(0, 2);
        let serial = dp_validate(&mut a, 0, &proposals, 1.0);
        let sharded = dp_validate_sharded(&mut b, 0, &proposals, &keys, 1.0, 8);
        assert_eq!(serial.resolved, sharded.resolved);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn sharded_small_input_falls_back_to_serial() {
        // Below SHARD_MIN_PROPOSALS the sharded entry point must still be
        // exact (it delegates to the serial validator).
        let (proposals, keys) = adversarial_proposals(31, 10, 2);
        let mut a = Matrix::zeros(0, 2);
        let mut b = Matrix::zeros(0, 2);
        let serial = dp_validate(&mut a, 0, &proposals, 1.0);
        let sharded = dp_validate_sharded(&mut b, 0, &proposals, &keys, 1.0, 8);
        assert_eq!(serial.resolved, sharded.resolved);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn tree_reduce_is_grouping_independent_and_sorted() {
        let vectors_data: Vec<Vec<f32>> =
            (0..12).map(|i| vec![i as f32, (i * i) as f32 * 0.25]).collect();
        let vectors: Vec<&[f32]> = vectors_data.iter().map(|v| v.as_slice()).collect();
        let shard_lists =
            vec![vec![0u32, 3, 6, 9], vec![1, 4, 7], vec![2, 5, 8, 10, 11], vec![]];
        let flat = shard_pairs_sorted(&vectors, &shard_lists);
        assert!(flat.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)), "sorted, unique");
        // Any grouping of shards onto "peers" reduces to the same cache.
        let one = ConflictCache::tree_reduce(vec![flat.clone()]);
        let per_shard = ConflictCache::tree_reduce(
            shard_lists.iter().map(|s| shard_pairs_sorted(&vectors, &[s.clone()])).collect(),
        );
        let grouped = ConflictCache::tree_reduce(vec![
            shard_pairs_sorted(&vectors, &shard_lists[..2]),
            shard_pairs_sorted(&vectors, &shard_lists[2..]),
        ]);
        assert_eq!(one.pairs, per_shard.pairs);
        assert_eq!(one.pairs, grouped.pairs);
        assert_eq!(one.len(), flat.len());
        // Lookups hit exactly the cached pairs, bitwise.
        for &(a, b, d) in &flat {
            assert_eq!(one.get(a, b).unwrap().to_bits(), d.to_bits());
        }
        assert!(one.get(0, 1).is_none(), "cross-shard pair is not cached");
        assert!(ConflictCache::tree_reduce(vec![]).is_empty());
    }

    #[test]
    fn clustered_validation_matches_serial_over_both_transports() {
        use crate::config::TransportKind;
        use crate::coordinator::transport::Cluster;
        use crate::data::generators::{dp_clusters, GenConfig};
        use crate::runtime::native::NativeBackend;
        let data =
            std::sync::Arc::new(dp_clusters(&GenConfig { n: 16, dim: 2, theta: 1.0, seed: 9 }));
        let backend: std::sync::Arc<dyn crate::runtime::ComputeBackend> =
            std::sync::Arc::new(NativeBackend::new());
        let (proposals, keys) = adversarial_proposals(91, 200, 5);
        let mut serial_c = mat(&[&[500.0, 500.0]]);
        let serial = dp_validate(&mut serial_c, 1, &proposals, 1.0);
        for kind in [TransportKind::InProc, TransportKind::Tcp] {
            for validators in [2usize, 3] {
                for sharding in [ShardingKind::Hash, ShardingKind::Conflict] {
                    let (d, b) = (data.clone(), backend.clone());
                    let mut cluster = Cluster::spawn(kind, d, b, 2, validators).unwrap();
                    let mut c = mat(&[&[500.0, 500.0]]);
                    let out = dp_validate_clustered(
                        &mut cluster.validate,
                        &mut c,
                        1,
                        &proposals,
                        &keys,
                        1.0,
                        8,
                        sharding,
                    )
                    .unwrap();
                    assert_eq!(
                        out.resolved,
                        serial.resolved,
                        "{kind:?} V={validators} {sharding:?}"
                    );
                    assert_eq!(out.accepted, serial.accepted);
                    assert_eq!(c.data, serial_c.data, "appended state diverged");
                }
            }
        }
    }

    #[test]
    fn clustered_ofl_matches_serial_over_both_transports() {
        use crate::config::TransportKind;
        use crate::coordinator::transport::Cluster;
        use crate::data::generators::{dp_clusters, GenConfig};
        use crate::runtime::native::NativeBackend;
        let data =
            std::sync::Arc::new(dp_clusters(&GenConfig { n: 8, dim: 2, theta: 1.0, seed: 3 }));
        let backend: std::sync::Arc<dyn crate::runtime::ComputeBackend> =
            std::sync::Arc::new(NativeBackend::new());
        let (dp_props, keys) = adversarial_proposals(77, 160, 4);
        let proposals: Vec<OflProposal> = dp_props
            .into_iter()
            .map(|p| OflProposal { idx: p.idx, center: p.center, d2_prev: 0.9, idx_prev: 2 })
            .collect();
        let draw = |i: u32| ((i as u64).wrapping_mul(0x9E37_79B9) % 1000) as f64 / 1000.0;
        let mut serial_c = Matrix::zeros(0, 2);
        let serial = ofl_validate(&mut serial_c, 0, &proposals, 1.0, draw);
        for kind in [TransportKind::InProc, TransportKind::Tcp] {
            for sharding in [ShardingKind::Hash, ShardingKind::Conflict] {
                let mut cluster =
                    Cluster::spawn(kind, data.clone(), backend.clone(), 2, 2).unwrap();
                let mut c = Matrix::zeros(0, 2);
                let out = ofl_validate_clustered(
                    &mut cluster.validate,
                    &mut c,
                    0,
                    &proposals,
                    &keys,
                    1.0,
                    draw,
                    8,
                    sharding,
                )
                .unwrap();
                assert_eq!(out.resolved, serial.resolved, "{kind:?} {sharding:?}");
                assert_eq!(out.opened, serial.opened);
                assert_eq!(c.data, serial_c.data);
            }
        }
    }

    #[test]
    fn bp_validate_small_residuals_rejected_against_nothing() {
        // No accepted features yet, residual norm² ≤ λ² — cannot happen from
        // a correct worker (it only proposes when r² > λ²), but validation
        // must still behave sanely: accepts iff r² > λ².
        let mut features = Matrix::zeros(0, 2);
        let proposals = vec![BpProposal { idx: 0, residual: vec![0.1, 0.0] }];
        let out = bp_validate(&mut features, 0, &proposals, 1.0, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(features.rows, 0);
    }
}
