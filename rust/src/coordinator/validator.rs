//! Serial validation — the master's epoch-boundary step.
//!
//! Each validator consumes the epoch's proposals *in point-index order*
//! (the serial order of Thm 3.1 / App B) and mutates the global state by
//! appending accepted centers/features. Rejected proposals are *corrected*:
//! the validator resolves the proposing point's assignment to the already-
//! accepted center that covers it (the paper's `Ref`).

use crate::algorithms::bpmeans::descend_z;
use crate::linalg::{sqdist, Matrix};

/// A DP-means proposal: point `idx` (global) wants to open a cluster at its
/// own coordinates (the worker certified `d² > λ²` against `C^{t-1}`).
#[derive(Debug, Clone)]
pub struct DpProposal {
    /// Global point index (defines validation order).
    pub idx: u32,
    /// The proposed center coordinates (= the point).
    pub center: Vec<f32>,
}

/// Outcome of validating one epoch's DP proposals.
#[derive(Debug, Clone, Default)]
pub struct DpOutcome {
    /// `(point, global center index)` assignment for every proposal.
    pub resolved: Vec<(u32, u32)>,
    /// Number of proposals accepted as new centers.
    pub accepted: usize,
    /// Number rejected (covered by a newly accepted center).
    pub rejected: usize,
}

/// `DPValidate` (Alg 2). `centers[base..]` is the epoch's accepted set `Ĉ`
/// (starts empty: `base == centers.rows` on entry); accepted proposals are
/// appended to `centers`. Proposals must be sorted by `idx`.
pub fn dp_validate(centers: &mut Matrix, base: usize, proposals: &[DpProposal], lambda2: f32) -> DpOutcome {
    debug_assert!(proposals.windows(2).all(|w| w[0].idx < w[1].idx));
    let mut out = DpOutcome::default();
    for p in proposals {
        // Nearest among the *newly accepted* centers only — the worker
        // already certified distance > λ against C^{t-1}.
        let mut best = f32::INFINITY;
        let mut best_k = usize::MAX;
        for k in base..centers.rows {
            let d = sqdist(&p.center, centers.row(k));
            if d < best {
                best = d;
                best_k = k;
            }
        }
        if best < lambda2 {
            // Reject: Ref(x) ← nearest accepted center.
            out.resolved.push((p.idx, best_k as u32));
            out.rejected += 1;
        } else {
            centers.push_row(&p.center);
            out.resolved.push((p.idx, (centers.rows - 1) as u32));
            out.accepted += 1;
        }
    }
    out
}

/// An OFL proposal: point `idx` was sent to the master with probability
/// `min(1, d²_prev/λ²)` using its pre-drawn uniform.
#[derive(Debug, Clone)]
pub struct OflProposal {
    /// Global point index (defines validation order).
    pub idx: u32,
    /// The point's coordinates (candidate facility).
    pub center: Vec<f32>,
    /// Squared distance to the nearest center of `C^{t-1}` (`+inf` if none).
    pub d2_prev: f32,
    /// Index of that nearest center (`u32::MAX` if none).
    pub idx_prev: u32,
}

/// Outcome of validating one epoch's OFL proposals.
#[derive(Debug, Clone, Default)]
pub struct OflOutcome {
    /// `(point, global facility index)` for every proposal.
    pub resolved: Vec<(u32, u32)>,
    /// Facilities opened.
    pub accepted: usize,
    /// Proposals assigned to an existing facility instead.
    pub rejected: usize,
    /// The point that opened each accepted facility, in acceptance order
    /// (parallel to the appended center rows).
    pub opened: Vec<u32>,
}

/// `OFLValidate` (Alg 5), with the telescoped acceptance probability of the
/// Thm 3.1 proof: accept with probability `min(1, d²_full/λ²) /
/// min(1, d²_prev/λ²)`, realized by re-using the point's own uniform draw
/// `draw(idx)` — this makes the distributed run *bit-identical* to the
/// serial OFL pass with the same per-point draws.
pub fn ofl_validate(
    centers: &mut Matrix,
    base: usize,
    proposals: &[OflProposal],
    lambda2: f64,
    mut draw: impl FnMut(u32) -> f64,
) -> OflOutcome {
    debug_assert!(proposals.windows(2).all(|w| w[0].idx < w[1].idx));
    let mut out = OflOutcome::default();
    for p in proposals {
        // Nearest among this epoch's accepted facilities Ĉ.
        let mut best_new = f32::INFINITY;
        let mut best_new_k = usize::MAX;
        for k in base..centers.rows {
            let d = sqdist(&p.center, centers.row(k));
            if d < best_new {
                best_new = d;
                best_new_k = k;
            }
        }
        let d2_full = p.d2_prev.min(best_new) as f64;
        let p_acc = if d2_full.is_infinite() { 1.0 } else { (d2_full / lambda2).min(1.0) };
        if draw(p.idx) < p_acc {
            centers.push_row(&p.center);
            out.resolved.push((p.idx, (centers.rows - 1) as u32));
            out.opened.push(p.idx);
            out.accepted += 1;
        } else {
            // Assign to the nearest open facility (old or newly accepted).
            let target = if best_new < p.d2_prev { best_new_k as u32 } else { p.idx_prev };
            out.resolved.push((p.idx, target));
            out.rejected += 1;
        }
    }
    out
}

/// A BP-means proposal: point `idx`'s residual after coordinate descent
/// over `F^{t-1}` exceeded λ².
#[derive(Debug, Clone)]
pub struct BpProposal {
    /// Global point index (defines validation order).
    pub idx: u32,
    /// The residual `x − Σ z f` proposed as a new feature.
    pub residual: Vec<f32>,
}

/// Resolution of one BP proposal.
#[derive(Debug, Clone)]
pub struct BpResolution {
    /// Proposing point.
    pub idx: u32,
    /// Global indices of newly-accepted features the residual was
    /// re-represented with (the `Ref` combination, `z_i ⊕ Ref(f_new)`).
    pub extra_features: Vec<u32>,
    /// Global index of the point's own accepted feature, if any.
    pub own_feature: Option<u32>,
}

/// Outcome of validating one epoch's BP proposals.
#[derive(Debug, Clone, Default)]
pub struct BpOutcome {
    /// Per-proposal resolution, in order.
    pub resolved: Vec<BpResolution>,
    /// Features accepted.
    pub accepted: usize,
    /// Proposals fully represented by earlier-accepted features.
    pub rejected: usize,
}

/// `BPValidate` (Alg 8). Re-represents each proposed residual over the
/// epoch's accepted feature set `features[base..]`; if the re-representation
/// error still exceeds λ², the *remaining* residual is accepted as a new
/// feature. Proposals must be sorted by `idx`.
pub fn bp_validate(
    features: &mut Matrix,
    base: usize,
    proposals: &[BpProposal],
    lambda2: f32,
    sweeps: usize,
) -> BpOutcome {
    debug_assert!(proposals.windows(2).all(|w| w[0].idx < w[1].idx));
    let mut out = BpOutcome::default();
    let d = features.cols;
    let mut residual = vec![0.0f32; d];
    for p in proposals {
        // View of the newly accepted features only.
        let new_k = features.rows - base;
        let mut z = vec![false; new_k];
        let r2 = if new_k == 0 {
            residual.copy_from_slice(&p.residual);
            crate::linalg::norm2(&residual)
        } else {
            // Build a temporary matrix over the accepted slice (cheap: K_new
            // is small — it is bounded by the epoch's acceptances).
            let view = Matrix {
                rows: new_k,
                cols: d,
                data: features.data[base * d..].to_vec(),
            };
            descend_z(&p.residual, &view, &mut z, &mut residual, sweeps)
        };
        let extra: Vec<u32> =
            z.iter().enumerate().filter(|(_, &on)| on).map(|(j, _)| (base + j) as u32).collect();
        if r2 > lambda2 {
            features.push_row(&residual);
            out.resolved.push(BpResolution {
                idx: p.idx,
                extra_features: extra,
                own_feature: Some((features.rows - 1) as u32),
            });
            out.accepted += 1;
        } else {
            out.resolved.push(BpResolution { idx: p.idx, extra_features: extra, own_feature: None });
            out.rejected += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: &[&[f32]]) -> Matrix {
        let cols = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut m = Matrix::zeros(0, cols);
        for r in rows {
            m.push_row(r);
        }
        m
    }

    #[test]
    fn dp_validate_accepts_spread_rejects_covered() {
        let mut centers = mat(&[&[100.0, 100.0]]); // pre-existing center (ignored)
        let proposals = vec![
            DpProposal { idx: 1, center: vec![0.0, 0.0] },
            DpProposal { idx: 3, center: vec![0.5, 0.0] },   // within λ of first → reject
            DpProposal { idx: 7, center: vec![10.0, 0.0] },  // far → accept
        ];
        let out = dp_validate(&mut centers, 1, &proposals, 1.0);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(centers.rows, 3);
        assert_eq!(out.resolved[0], (1, 1)); // own new center
        assert_eq!(out.resolved[1], (3, 1)); // Ref → first accepted
        assert_eq!(out.resolved[2], (7, 2));
    }

    #[test]
    fn dp_validate_ignores_preexisting_centers() {
        // The worker certified distance to C^{t-1}; validation must not
        // re-check it (a proposal near an old center is still accepted —
        // matches Alg 2 where C starts empty).
        let mut centers = mat(&[&[0.0, 0.0]]);
        let proposals = vec![DpProposal { idx: 0, center: vec![0.1, 0.0] }];
        let out = dp_validate(&mut centers, 1, &proposals, 1.0);
        assert_eq!(out.accepted, 1);
        assert_eq!(centers.rows, 2);
    }

    #[test]
    fn dp_validate_boundary_exactly_lambda() {
        // d² == λ² is NOT < λ² → accepted (worker-side rule is d² > λ²,
        // so the pair is consistent: both use strict comparisons).
        let mut centers = Matrix::zeros(0, 1);
        let proposals = vec![
            DpProposal { idx: 0, center: vec![0.0] },
            DpProposal { idx: 1, center: vec![1.0] }, // d² = 1 = λ²
        ];
        let out = dp_validate(&mut centers, 0, &proposals, 1.0);
        assert_eq!(out.accepted, 2);
    }

    #[test]
    fn ofl_validate_first_epoch_behaves_serially() {
        // Empty prior state: d2_prev = inf. With draws forcing open/skip we
        // can script the outcome.
        let mut centers = Matrix::zeros(0, 1);
        let proposals = vec![
            OflProposal { idx: 0, center: vec![0.0], d2_prev: f32::INFINITY, idx_prev: u32::MAX },
            OflProposal { idx: 1, center: vec![0.5], d2_prev: f32::INFINITY, idx_prev: u32::MAX },
            OflProposal { idx: 2, center: vec![10.0], d2_prev: f32::INFINITY, idx_prev: u32::MAX },
        ];
        // Point 0: p_acc = 1 → opens. Point 1: d2_full = 0.25 → p = 0.25;
        // draw 0.5 → assigned to facility 0. Point 2: d2_full = 90.25 → p=1.
        let draws = [0.9, 0.5, 0.3];
        let out = ofl_validate(&mut centers, 0, &proposals, 1.0, |i| draws[i as usize]);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(out.resolved[1], (1, 0));
        assert_eq!(centers.rows, 2);
    }

    #[test]
    fn ofl_validate_telescoped_probability() {
        // Worker sent with p_send = min(1, d2_prev/λ²); master must accept
        // iff draw < min(1, d2_full/λ²). d2_full ≤ d2_prev so acceptance is
        // a subset of sends — check the boundary.
        let mut centers = mat(&[&[0.0]]); // facility accepted this epoch
        let proposals = vec![OflProposal {
            idx: 5,
            center: vec![0.6], // d² to new facility = 0.36; d2_prev = 0.81
            d2_prev: 0.81,
            idx_prev: 7,
        }];
        // draw = 0.5: sent (0.5 < 0.81) but NOT accepted (0.5 ≥ 0.36) →
        // assigned to the closer, newly accepted facility 0.
        let out = ofl_validate(&mut centers, 0, &proposals, 1.0, |_| 0.5);
        assert_eq!(out.rejected, 1);
        assert_eq!(out.resolved[0], (5, 0));
        // draw = 0.3: accepted.
        let mut centers2 = mat(&[&[0.0]]);
        let proposals2 = vec![OflProposal { idx: 5, center: vec![0.6], d2_prev: 0.81, idx_prev: 7 }];
        let out2 = ofl_validate(&mut centers2, 0, &proposals2, 1.0, |_| 0.3);
        assert_eq!(out2.accepted, 1);
    }

    #[test]
    fn ofl_rejected_points_keep_old_facility_when_closer() {
        let mut centers = mat(&[&[10.0]]); // new facility far away
        let proposals = vec![OflProposal { idx: 2, center: vec![0.5], d2_prev: 0.25, idx_prev: 3 }];
        let out = ofl_validate(&mut centers, 0, &proposals, 1.0, |_| 0.9);
        assert_eq!(out.resolved[0], (2, 3)); // old facility 3 is closer
    }

    #[test]
    fn bp_validate_accepts_and_rerepresents() {
        let mut features = Matrix::zeros(0, 2);
        let proposals = vec![
            BpProposal { idx: 0, residual: vec![2.0, 0.0] },
            BpProposal { idx: 1, residual: vec![2.0, 0.0] }, // fully covered → reject
            BpProposal { idx: 2, residual: vec![2.0, 2.0] }, // partially covered → accept remainder
        ];
        let out = bp_validate(&mut features, 0, &proposals, 0.01, 2);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(features.rows, 2);
        // Proposal 1: represented by feature 0, no own feature.
        assert_eq!(out.resolved[1].extra_features, vec![0]);
        assert!(out.resolved[1].own_feature.is_none());
        // Proposal 2: uses feature 0, contributes the remainder (0, 2).
        assert_eq!(out.resolved[2].extra_features, vec![0]);
        assert_eq!(out.resolved[2].own_feature, Some(1));
        assert_eq!(features.row(1), &[0.0, 2.0]);
    }

    #[test]
    fn bp_validate_small_residuals_rejected_against_nothing() {
        // No accepted features yet, residual norm² ≤ λ² — cannot happen from
        // a correct worker (it only proposes when r² > λ²), but validation
        // must still behave sanely: accepts iff r² > λ².
        let mut features = Matrix::zeros(0, 2);
        let proposals = vec![BpProposal { idx: 0, residual: vec![0.1, 0.0] }];
        let out = bp_validate(&mut features, 0, &proposals, 1.0, 2);
        assert_eq!(out.rejected, 1);
        assert_eq!(features.rows, 0);
    }
}
