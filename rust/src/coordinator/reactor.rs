//! Readiness reactor: the coordinator's single blocking point.
//!
//! Every wait in the coordinator's hot path used to be a sleep slice —
//! 100 µs idle ticks in the scheduler event loop, 200 µs naps in
//! `TcpPlane::gather`, a 200 µs `recv_timeout` spin on the validation
//! hand-off. At small epoch sizes those quanta dominate latency: the
//! machine is idle-but-sleeping while bytes sit readable in socket
//! buffers. This module replaces all of them with one OS readiness
//! queue the event loop blocks on directly.
//!
//! # Backends
//!
//! * **Linux** — `epoll` via raw FFI (`epoll_create1` / `epoll_ctl` /
//!   `epoll_wait`), level-triggered, plus an `eventfd` wakeup. Zero
//!   crates: the declarations below are the whole binding surface.
//! * **Other unix** — portable `poll(2)` over the registered fd set,
//!   with a nonblocking self-pipe standing in for the eventfd.
//! * **Non-unix** — a sleep stub: `wait` naps for the timeout and
//!   reports no events. Everything degrades to the old polling
//!   behavior; nothing breaks.
//!
//! # Protocol
//!
//! The reactor is **thread-confined**: one owner (the scheduler /
//! `TcpPlane` thread) registers fds and calls [`Reactor::wait`]. The
//! only cross-thread door is [`Wakeup`], a cheap `Send + Sync` handle
//! the validation thread clones and signals after each commit it
//! pushes. A wake is an 8-byte counter add on the eventfd (one byte
//! down the self-pipe elsewhere); N signals between waits **coalesce**
//! into one readable event, which is exactly right — the waiter
//! re-checks its queues once, not N times.
//!
//! Registration is level-triggered and read-interest by default;
//! [`Reactor::set_write_interest`] flips `EPOLLOUT` on for a peer
//! while its pending-write queue is non-empty and off once it drains.
//! [`Reactor::wait`] retries `EINTR` against a fixed deadline, drains
//! the wakeup fd internally, and returns `Ok(true)` when *anything*
//! fired — callers own nonblocking pumps and re-poll their own state,
//! so they never need to know which fd was hot. Spurious returns are
//! harmless by construction.
//!
//! # Lost-wakeup discipline
//!
//! Callers must check their queues *after* registering interest and
//! *before* blocking (try-recv, then wait, then try-recv again), and
//! every wait passes a bounded safety-net timeout. A missed edge can
//! therefore cost one timeout slice at worst — the failure mode is
//! "slightly slower", never "hung".

#![allow(dead_code)] // non-default backends keep their full API

use std::io;
use std::sync::Arc;
use std::time::Duration;

#[cfg(unix)]
use std::os::unix::io::RawFd;
#[cfg(unix)]
use std::time::Instant;

/// Longest single kernel wait we ever request, in ms. Waits longer
/// than this loop around the deadline check; keeps the ms conversion
/// comfortably inside `c_int`.
const MAX_WAIT_MS: u128 = 60_000;

#[cfg(unix)]
fn timeout_ms(deadline: Instant) -> i32 {
    let now = Instant::now();
    if now >= deadline {
        return 0;
    }
    // Round up: a 200 µs cap must not become a 0 ms busy spin.
    let us = (deadline - now).as_micros();
    ((us + 999) / 1000).min(MAX_WAIT_MS) as i32
}

// ---------------------------------------------------------------------------
// Linux backend: epoll + eventfd.
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// Mirrors the kernel's `struct epoll_event`; packed on x86-64,
    /// where the kernel ABI has no padding between `events` and `data`.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn eventfd(initval: u32, flags: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }
}

// ---------------------------------------------------------------------------
// Portable unix backend: poll(2) + self-pipe.
// ---------------------------------------------------------------------------

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use std::os::raw::{c_int, c_short, c_ulong, c_void};

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const F_GETFL: c_int = 3;
    pub const F_SETFL: c_int = 4;
    pub const O_NONBLOCK: c_int = 0o4000;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
        pub fn close(fd: c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    }
}

#[cfg(unix)]
fn cvt(r: std::os::raw::c_int) -> io::Result<std::os::raw::c_int> {
    if r < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(r)
    }
}

// ---------------------------------------------------------------------------
// Wakeup fd: eventfd on Linux, nonblocking self-pipe elsewhere.
// ---------------------------------------------------------------------------

/// The fd pair behind [`Wakeup`]. On Linux an eventfd is both ends
/// (`rd == wr`); on other unix a pipe. Owned by an `Arc` shared
/// between the reactor and every `Wakeup` clone, so the fds close
/// only after the last holder drops — a waker can never write into a
/// recycled fd number.
#[cfg(unix)]
struct WakeFd {
    rd: RawFd,
    wr: RawFd,
}

#[cfg(target_os = "linux")]
impl WakeFd {
    fn new() -> io::Result<Self> {
        let fd = cvt(unsafe { sys::eventfd(0, sys::EFD_NONBLOCK | sys::EFD_CLOEXEC) })?;
        Ok(WakeFd { rd: fd, wr: fd })
    }

    fn wake(&self) {
        // Adds 1 to the eventfd counter; N adds coalesce into one
        // readable event. EAGAIN (counter saturated) still leaves the
        // fd readable, so the signal is never lost — ignore errors.
        let one: u64 = 1;
        unsafe {
            sys::write(self.wr, (&one as *const u64).cast(), 8);
        }
    }

    fn drain(&self) {
        // A single read returns and zeroes the whole counter.
        let mut buf = [0u8; 8];
        unsafe {
            sys::read(self.rd, buf.as_mut_ptr().cast(), 8);
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
impl WakeFd {
    fn new() -> io::Result<Self> {
        let mut fds = [0 as std::os::raw::c_int; 2];
        cvt(unsafe { sys::pipe(fds.as_mut_ptr()) })?;
        for fd in fds {
            let flags = cvt(unsafe { sys::fcntl(fd, sys::F_GETFL, 0) })?;
            cvt(unsafe { sys::fcntl(fd, sys::F_SETFL, flags | sys::O_NONBLOCK) })?;
        }
        Ok(WakeFd { rd: fds[0], wr: fds[1] })
    }

    fn wake(&self) {
        // One byte per signal; a full pipe is already "readable", so
        // dropping the write on EAGAIN loses nothing.
        let b = [1u8];
        unsafe {
            sys::write(self.wr, b.as_ptr().cast(), 1);
        }
    }

    fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { sys::read(self.rd, buf.as_mut_ptr().cast(), buf.len()) };
            if n < buf.len() as isize {
                return;
            }
        }
    }
}

#[cfg(unix)]
impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.rd);
            if self.wr != self.rd {
                sys::close(self.wr);
            }
        }
    }
}

/// Cross-thread wake handle: cheap to clone, `Send + Sync`, safe to
/// signal from any thread. The validation thread calls [`Wakeup::wake`]
/// after every commit it pushes; the blocked event loop returns from
/// [`Reactor::wait`] and re-checks its channels.
#[derive(Clone)]
pub struct Wakeup {
    #[cfg(unix)]
    fd: Arc<WakeFd>,
    #[cfg(not(unix))]
    _stub: Arc<()>,
}

impl Wakeup {
    /// Signal the reactor. Nonblocking, never fails, coalesces.
    pub fn wake(&self) {
        #[cfg(unix)]
        self.fd.wake();
    }
}

// ---------------------------------------------------------------------------
// Reactor proper.
// ---------------------------------------------------------------------------

/// Readiness queue the coordinator blocks on. Thread-confined; see
/// the module docs for the wakeup and lost-wakeup protocol.
pub struct Reactor {
    #[cfg(target_os = "linux")]
    epfd: RawFd,
    #[cfg(target_os = "linux")]
    events: Vec<sys::EpollEvent>,
    /// fd → interest mask currently installed in the kernel (Linux) or
    /// polled each wait (portable backend).
    #[cfg(unix)]
    interest: std::collections::HashMap<RawFd, bool>, // true = also write
    #[cfg(unix)]
    wake: Arc<WakeFd>,
    /// Test hook: pretend the next N kernel waits were interrupted.
    #[cfg(all(test, unix))]
    inject_eintr: std::cell::Cell<u32>,
}

#[cfg(target_os = "linux")]
impl Reactor {
    pub fn new() -> io::Result<Self> {
        let epfd = cvt(unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) })?;
        let wake = match WakeFd::new() {
            Ok(w) => Arc::new(w),
            Err(e) => {
                unsafe {
                    sys::close(epfd);
                }
                return Err(e);
            }
        };
        let mut ev = sys::EpollEvent {
            events: sys::EPOLLIN,
            data: wake.rd as u64,
        };
        cvt(unsafe { sys::epoll_ctl(epfd, sys::EPOLL_CTL_ADD, wake.rd, &mut ev) })?;
        Ok(Reactor {
            epfd,
            events: vec![sys::EpollEvent { events: 0, data: 0 }; 64],
            interest: std::collections::HashMap::new(),
            wake,
            #[cfg(test)]
            inject_eintr: std::cell::Cell::new(0),
        })
    }

    /// Watch `fd` for read readiness (level-triggered) until
    /// [`Reactor::deregister`].
    pub fn register(&mut self, fd: RawFd) -> io::Result<()> {
        let mut ev = sys::EpollEvent {
            events: sys::EPOLLIN,
            data: fd as u64,
        };
        cvt(unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_ADD, fd, &mut ev) })?;
        self.interest.insert(fd, false);
        Ok(())
    }

    /// Stop watching `fd`. Must run before the fd is closed, or a
    /// recycled fd number could alias a stale registration.
    pub fn deregister(&mut self, fd: RawFd) {
        if self.interest.remove(&fd).is_some() {
            unsafe {
                sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_DEL, fd, std::ptr::null_mut());
            }
        }
    }

    /// Add or drop write-readiness interest for `fd`. On while the
    /// peer's pending-write queue is non-empty, off once it drains.
    /// No-op (and no error) for unregistered fds.
    pub fn set_write_interest(&mut self, fd: RawFd, on: bool) -> io::Result<()> {
        let Some(cur) = self.interest.get_mut(&fd) else {
            return Ok(());
        };
        if *cur == on {
            return Ok(());
        }
        let mask = sys::EPOLLIN | if on { sys::EPOLLOUT } else { 0 };
        let mut ev = sys::EpollEvent {
            events: mask,
            data: fd as u64,
        };
        cvt(unsafe { sys::epoll_ctl(self.epfd, sys::EPOLL_CTL_MOD, fd, &mut ev) })?;
        *cur = on;
        Ok(())
    }

    /// Block until a registered fd is ready or a wakeup arrives, for
    /// at most `timeout`. `Ok(true)` means *something* fired (possibly
    /// only a wakeup signal, already drained); `Ok(false)` means the
    /// timeout lapsed. Retries `EINTR` against a fixed deadline.
    pub fn wait(&mut self, timeout: Duration) -> io::Result<bool> {
        let deadline = Instant::now() + timeout;
        loop {
            #[cfg(test)]
            if self.inject_eintr.get() > 0 {
                self.inject_eintr.set(self.inject_eintr.get() - 1);
                continue; // simulated EINTR: re-derive the remaining wait
            }
            let n = unsafe {
                sys::epoll_wait(
                    self.epfd,
                    self.events.as_mut_ptr(),
                    self.events.len() as std::os::raw::c_int,
                    timeout_ms(deadline),
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(err);
            }
            if n == 0 {
                return Ok(false);
            }
            let wake_token = self.wake.rd as u64;
            for ev in &self.events[..n as usize] {
                let token = ev.data; // copy out of the packed struct
                if token == wake_token {
                    self.wake.drain();
                }
            }
            return Ok(true);
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
impl Reactor {
    pub fn new() -> io::Result<Self> {
        Ok(Reactor {
            interest: std::collections::HashMap::new(),
            wake: Arc::new(WakeFd::new()?),
            #[cfg(test)]
            inject_eintr: std::cell::Cell::new(0),
        })
    }

    pub fn register(&mut self, fd: RawFd) -> io::Result<()> {
        self.interest.insert(fd, false);
        Ok(())
    }

    pub fn deregister(&mut self, fd: RawFd) {
        self.interest.remove(&fd);
    }

    pub fn set_write_interest(&mut self, fd: RawFd, on: bool) -> io::Result<()> {
        if let Some(cur) = self.interest.get_mut(&fd) {
            *cur = on;
        }
        Ok(())
    }

    pub fn wait(&mut self, timeout: Duration) -> io::Result<bool> {
        let deadline = Instant::now() + timeout;
        let mut fds: Vec<sys::PollFd> = Vec::with_capacity(self.interest.len() + 1);
        fds.push(sys::PollFd {
            fd: self.wake.rd,
            events: sys::POLLIN,
            revents: 0,
        });
        for (&fd, &write) in &self.interest {
            fds.push(sys::PollFd {
                fd,
                events: sys::POLLIN | if write { sys::POLLOUT } else { 0 },
                revents: 0,
            });
        }
        loop {
            #[cfg(test)]
            if self.inject_eintr.get() > 0 {
                self.inject_eintr.set(self.inject_eintr.get() - 1);
                continue; // simulated EINTR: re-derive the remaining wait
            }
            for f in fds.iter_mut() {
                f.revents = 0;
            }
            let n = unsafe {
                sys::poll(
                    fds.as_mut_ptr(),
                    fds.len() as std::os::raw::c_ulong,
                    timeout_ms(deadline),
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(err);
            }
            if n == 0 {
                return Ok(false);
            }
            if fds[0].revents != 0 {
                self.wake.drain();
            }
            return Ok(true);
        }
    }
}

#[cfg(unix)]
impl Reactor {
    /// A `Send + Sync` handle other threads use to interrupt
    /// [`Reactor::wait`].
    pub fn wakeup(&self) -> Wakeup {
        Wakeup {
            fd: Arc::clone(&self.wake),
        }
    }

    /// Park until `deadline`, absorbing readiness events and wakeup
    /// signals along the way — the reactor-path replacement for a
    /// `thread::sleep` backoff. Unlike [`Reactor::wait`], spurious
    /// wakeups (a readable peer, a coalesced commit signal) do *not*
    /// end the park early: the loop re-waits for the remaining time,
    /// so the caller observes a plain bounded delay while the fd set
    /// stays armed and signals keep coalescing instead of piling into
    /// a stale sleep.
    pub fn wait_until(&mut self, deadline: Instant) -> io::Result<()> {
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Ok(());
            }
            self.wait(deadline - now)?;
        }
    }

    /// Test hook: make the next `n` kernel waits look `EINTR`-ed.
    #[cfg(test)]
    fn inject_eintr(&self, n: u32) {
        self.inject_eintr.set(n);
    }
}

#[cfg(target_os = "linux")]
impl Drop for Reactor {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.epfd);
        }
        // self.wake closes via its Arc once the last Wakeup drops.
    }
}

// ---------------------------------------------------------------------------
// Non-unix stub: degrade to sleep-polling, keep the API.
// ---------------------------------------------------------------------------

#[cfg(not(unix))]
impl Reactor {
    pub fn new() -> io::Result<Self> {
        Ok(Reactor {})
    }

    pub fn register(&mut self, _fd: i32) -> io::Result<()> {
        Ok(())
    }

    pub fn deregister(&mut self, _fd: i32) {}

    pub fn set_write_interest(&mut self, _fd: i32, _on: bool) -> io::Result<()> {
        Ok(())
    }

    /// No readiness source: nap for the timeout, report nothing fired.
    pub fn wait(&mut self, timeout: Duration) -> io::Result<bool> {
        std::thread::sleep(timeout.min(Duration::from_millis(1))); // poll-mode: non-unix stub has no readiness source
        Ok(false)
    }

    pub fn wakeup(&self) -> Wakeup {
        Wakeup {
            _stub: Arc::new(()),
        }
    }

    /// Sleep-stub twin of the unix `wait_until`: naps to the deadline.
    pub fn wait_until(&mut self, deadline: std::time::Instant) -> io::Result<()> {
        let now = std::time::Instant::now();
        if deadline > now {
            std::thread::sleep(deadline - now); // poll-mode: non-unix stub has no readiness source
        }
        Ok(())
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        (a, b)
    }

    #[test]
    fn wakeups_coalesce_and_drain() {
        let mut r = Reactor::new().unwrap();
        let w = r.wakeup();
        for _ in 0..5 {
            w.wake();
        }
        // Five signals → one readable event, drained inside wait.
        assert!(r.wait(Duration::from_millis(200)).unwrap());
        // Nothing left: the next wait times out.
        assert!(!r.wait(Duration::from_millis(10)).unwrap());
    }

    #[test]
    fn wake_from_another_thread_interrupts_wait() {
        let mut r = Reactor::new().unwrap();
        let w = r.wakeup();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w.wake();
        });
        let start = std::time::Instant::now();
        assert!(r.wait(Duration::from_secs(5)).unwrap());
        assert!(start.elapsed() < Duration::from_secs(4));
        t.join().unwrap();
    }

    #[test]
    fn level_triggered_readiness_persists_until_read() {
        let (mut a, mut b) = pair();
        let mut r = Reactor::new().unwrap();
        r.register(b.as_raw_fd()).unwrap();
        a.write_all(b"ping").unwrap();
        // Readable now, and still readable on a second (spurious-style)
        // wait because nothing consumed the bytes.
        assert!(r.wait(Duration::from_millis(500)).unwrap());
        assert!(r.wait(Duration::from_millis(500)).unwrap());
        let mut buf = [0u8; 8];
        let n = b.read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");
        assert!(!r.wait(Duration::from_millis(10)).unwrap());
        r.deregister(b.as_raw_fd());
        drop(a);
    }

    #[test]
    fn eintr_is_retried_against_the_deadline() {
        let mut r = Reactor::new().unwrap();
        let w = r.wakeup();
        w.wake();
        r.inject_eintr(3);
        // Three simulated interruptions, then the real wait still sees
        // the pending wakeup.
        assert!(r.wait(Duration::from_millis(200)).unwrap());
        // And with nothing pending, injected EINTRs terminate at the
        // deadline instead of looping forever.
        r.inject_eintr(2);
        assert!(!r.wait(Duration::from_millis(10)).unwrap());
    }

    #[test]
    fn write_interest_fires_on_writable_socket() {
        let (a, _b) = pair();
        let mut r = Reactor::new().unwrap();
        r.register(a.as_raw_fd()).unwrap();
        // Read-only interest on an idle socket: nothing fires.
        assert!(!r.wait(Duration::from_millis(10)).unwrap());
        // Write interest on an empty send buffer: fires immediately.
        r.set_write_interest(a.as_raw_fd(), true).unwrap();
        assert!(r.wait(Duration::from_millis(500)).unwrap());
        r.set_write_interest(a.as_raw_fd(), false).unwrap();
        assert!(!r.wait(Duration::from_millis(10)).unwrap());
    }

    /// `wait_until` is a real park: mid-park wakeup signals are absorbed
    /// (the fd set stays armed) but the deadline still holds — the backoff
    /// delay the caller asked for is the delay it gets.
    #[test]
    fn wait_until_absorbs_wakeups_and_holds_the_deadline() {
        let mut r = Reactor::new().unwrap();
        let w = r.wakeup();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            w.wake();
            std::thread::sleep(Duration::from_millis(10));
            w.wake();
        });
        let start = std::time::Instant::now();
        r.wait_until(std::time::Instant::now() + Duration::from_millis(80)).unwrap();
        assert!(start.elapsed() >= Duration::from_millis(75));
        // The signals were drained inside the park: nothing pending now.
        assert!(!r.wait(Duration::from_millis(10)).unwrap());
        t.join().unwrap();
    }

    #[test]
    fn deregister_silences_a_ready_fd() {
        let (mut a, b) = pair();
        let mut r = Reactor::new().unwrap();
        r.register(b.as_raw_fd()).unwrap();
        a.write_all(b"x").unwrap();
        assert!(r.wait(Duration::from_millis(500)).unwrap());
        r.deregister(b.as_raw_fd());
        assert!(!r.wait(Duration::from_millis(10)).unwrap());
    }
}
