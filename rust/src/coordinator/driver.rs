//! Full OCC runs: Alg 3 (DP-means), Alg 4 (OFL), Alg 6 (BP-means).
//!
//! The driver owns the global state and the epoch loop; workers compute, the
//! master validates (in point-index order — the Thm 3.1 serial order) and
//! replicates state by handing the next epoch an updated snapshot.
//!
//! Epoch structure (Fig 5): epoch `t` covers the contiguous index range
//! `[start + t·P·b, start + (t+1)·P·b)`; each worker gets a contiguous
//! block of it. Because proposals are merged and validated by point index,
//! the result is identical for every worker count `P` at fixed `P·b`.

use super::engine::{split_range, split_range_chunked, Job, JobOutput, WorkerPool};
use super::validator::{
    bp_validate, dp_validate, ofl_validate, BpProposal, DpProposal, OflProposal,
};
use crate::algorithms::bpmeans::{descend_z, BpModel, RIDGE_EPS};
use crate::algorithms::dpmeans::DpModel;
use crate::algorithms::objective;
use crate::algorithms::ofl::{ofl_draws, OflModel};
use crate::config::{Algo, BackendKind, DataSource, RunConfig};
use crate::data::{generators, Dataset};
use crate::error::{Error, Result};
use crate::linalg::{blocked, cholesky, Matrix};
use crate::metrics::{EpochRecord, MetricsSink, RunSummary, Stopwatch};
use crate::runtime::{native::NativeBackend, xla::XlaBackend, ComputeBackend};
use std::sync::Arc;

/// The learned model, by algorithm.
#[derive(Debug, Clone)]
pub enum Model {
    /// DP-means output.
    Dp(DpModel),
    /// OFL output.
    Ofl(OflModel),
    /// BP-means output.
    Bp(BpModel),
}

impl Model {
    /// Number of clusters / facilities / features.
    pub fn k(&self) -> usize {
        match self {
            Model::Dp(m) => m.centers.rows,
            Model::Ofl(m) => m.centers.rows,
            Model::Bp(m) => m.features.rows,
        }
    }
}

/// A complete run result.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// Metrics summary (per-epoch records, objective, totals).
    pub summary: RunSummary,
    /// The learned model.
    pub model: Model,
}

/// Generate or load the dataset a config names.
pub fn load_or_generate(cfg: &RunConfig) -> Result<Dataset> {
    let gen = generators::GenConfig { n: cfg.n, dim: cfg.dim, theta: cfg.theta, seed: cfg.seed };
    match &cfg.source {
        DataSource::DpClusters => Ok(generators::dp_clusters(&gen)),
        DataSource::BpFeatures => Ok(generators::bp_features(&gen)),
        DataSource::Separable => Ok(generators::separable_clusters(&gen)),
        DataSource::File(path) => crate::data::io::read_occb(path),
    }
}

/// Build the configured compute backend.
pub fn make_backend(cfg: &RunConfig) -> Result<Arc<dyn ComputeBackend>> {
    match cfg.backend {
        BackendKind::Native => Ok(Arc::new(NativeBackend::new())),
        BackendKind::Xla => Ok(Arc::new(XlaBackend::load(&cfg.artifacts_dir)?)),
    }
}

/// Run the configured algorithm end to end (data + backend from config).
pub fn run(cfg: &RunConfig) -> Result<RunOutput> {
    let data = Arc::new(load_or_generate(cfg)?);
    let backend = make_backend(cfg)?;
    run_with(cfg, data, backend)
}

/// Run with an explicit dataset and backend (the embedding API used by the
/// examples, benches and tests).
pub fn run_with(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
) -> Result<RunOutput> {
    cfg.validate()?;
    let mut sink = MetricsSink::open(cfg.metrics_path.as_deref())?;
    let out = match cfg.algo {
        Algo::DpMeans => run_dpmeans(cfg, data, backend, &mut sink),
        Algo::Ofl => run_ofl(cfg, data, backend, &mut sink),
        Algo::BpMeans => run_bpmeans(cfg, data, backend, &mut sink),
    };
    sink.flush();
    out
}

/// Bootstrap size (§4.2): 1/`bootstrap_div` of the first `P·b` points,
/// clamped to the dataset.
fn bootstrap_size(cfg: &RunConfig, n: usize) -> usize {
    if cfg.bootstrap_div == 0 {
        0
    } else {
        (cfg.points_per_epoch() / cfg.bootstrap_div).min(n)
    }
}

// ---------------------------------------------------------------------------
// OCC DP-means (Alg 3)
// ---------------------------------------------------------------------------

/// Distributed DP-means.
pub fn run_dpmeans(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    sink: &mut MetricsSink,
) -> Result<RunOutput> {
    let n = data.len();
    let d = data.dim();
    let lambda2 = (cfg.lambda * cfg.lambda) as f32;
    let pool = WorkerPool::spawn(data.clone(), backend, cfg.procs);
    let total = Stopwatch::start();

    let mut centers = Matrix::zeros(0, d);
    let mut assignments = vec![u32::MAX; n];
    let mut epochs_log = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    let mut created_per_pass = Vec::new();

    // Bootstrap: serially pre-process the first Pb/div points (first pass
    // only). They are the first points of the serial order, so this
    // preserves serializability.
    let boot_n = bootstrap_size(cfg, n);
    for i in 0..boot_n {
        let x = data.point(i);
        let (k, d2) = crate::linalg::nearest(x, &centers);
        assignments[i] = if d2 > lambda2 {
            centers.push_row(x);
            (centers.rows - 1) as u32
        } else {
            k as u32
        };
    }

    for pass in 0..cfg.iterations {
        iterations += 1;
        let start = if pass == 0 { boot_n } else { 0 };
        let mut changed = boot_n > 0 && pass == 0; // bootstrap assigned points
        let mut created = if pass == 0 { centers.rows } else { 0 };

        let per_epoch = cfg.points_per_epoch();
        let num_epochs = (n - start).div_ceil(per_epoch).max(1);
        for t in 0..num_epochs {
            let epoch_sw = Stopwatch::start();
            let lo = start + t * per_epoch;
            let hi = (lo + per_epoch).min(n);
            if lo >= hi {
                continue;
            }
            let snapshot = Arc::new(centers.clone());
            let base = snapshot.rows;
            let ranges = split_range(lo..hi, cfg.procs);
            let jobs: Vec<Job> = ranges
                .iter()
                .map(|r| Job::Nearest { range: r.clone(), centers: snapshot.clone() })
                .collect();
            let (outs, worker_time) = pool.scatter_gather(jobs)?;

            // Merge results by index; collect proposals in index order.
            let mut proposals = Vec::new();
            for (w, out) in outs.iter().enumerate() {
                let JobOutput::Nearest { idx, d2 } = out else {
                    return Err(Error::Coordinator("unexpected job output".into()));
                };
                for (off, i) in ranges[w].clone().enumerate() {
                    if d2[off] > lambda2 {
                        proposals.push(DpProposal { idx: i as u32, center: data.point(i).to_vec() });
                    } else if assignments[i] != idx[off] {
                        assignments[i] = idx[off];
                        changed = true;
                    }
                }
            }
            proposals.sort_by_key(|p| p.idx);

            // Serial validation at the master.
            let master_sw = Stopwatch::start();
            let outcome = dp_validate(&mut centers, base, &proposals, lambda2);
            for (i, c) in &outcome.resolved {
                if assignments[*i as usize] != *c {
                    assignments[*i as usize] = *c;
                    changed = true;
                }
            }
            created += outcome.accepted;
            let master_time = master_sw.elapsed();

            let rec = EpochRecord {
                iteration: pass,
                epoch: t,
                points: hi - lo,
                proposed: proposals.len(),
                accepted: outcome.accepted,
                rejected: outcome.rejected,
                centers: centers.rows,
                worker_time,
                master_time,
                total_time: epoch_sw.elapsed(),
            };
            sink.emit(&rec);
            epochs_log.push(rec);
        }
        created_per_pass.push(created);

        // Phase 2: recompute centers as means (parallel suffstats).
        let recompute_sw = Stopwatch::start();
        let k = centers.rows;
        if k > 0 {
            let shared = Arc::new(assignments.clone());
            let jobs: Vec<Job> = split_range_chunked(0..n, cfg.procs)
                .into_iter()
                .map(|range| Job::SuffStats { range, assignments: shared.clone(), k })
                .collect();
            let (outs, worker_time) = pool.scatter_gather(jobs)?;
            // Deterministic reduce: combine per-chunk partials in global
            // chunk order, independent of the worker count.
            let mut all_chunks = Vec::new();
            for out in outs {
                let JobOutput::SuffStats { chunks } = out else {
                    return Err(Error::Coordinator("unexpected job output".into()));
                };
                all_chunks.extend(chunks);
            }
            all_chunks.sort_by_key(|(id, _, _)| *id);
            let mut sums = Matrix::zeros(k, d);
            let mut counts = vec![0u64; k];
            for (_, s, c) in &all_chunks {
                for kk in 0..k {
                    counts[kk] += c[kk];
                    crate::linalg::axpy(1.0, s.row(kk), sums.row_mut(kk));
                }
            }
            blocked::finalize_means(&sums, &counts, &mut centers);
            let rec = EpochRecord {
                iteration: pass,
                epoch: usize::MAX, // convention: the recompute "epoch"
                points: n,
                centers: k,
                worker_time,
                total_time: recompute_sw.elapsed(),
                ..Default::default()
            };
            sink.emit(&rec);
            epochs_log.push(rec);
        }

        if !changed {
            converged = true;
            break;
        }
    }

    let model = DpModel {
        centers: centers.clone(),
        assignments,
        iterations,
        converged,
        created_per_pass,
    };
    let summary = RunSummary {
        epochs: epochs_log,
        final_centers: centers.rows,
        objective: Some(objective::dp_objective(&data, &centers, cfg.lambda)),
        total_time: total.elapsed(),
    };
    Ok(RunOutput { summary, model: Model::Dp(model) })
}

// ---------------------------------------------------------------------------
// OCC OFL (Alg 4)
// ---------------------------------------------------------------------------

/// Distributed online facility location. Single pass, no bootstrap (§4.2);
/// stochastic proposals and validation share per-point uniform draws with
/// the serial algorithm, making the returned facilities bit-identical to
/// [`crate::algorithms::ofl::serial_ofl`] with the same seed.
pub fn run_ofl(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    sink: &mut MetricsSink,
) -> Result<RunOutput> {
    let n = data.len();
    let d = data.dim();
    let lambda2 = cfg.lambda * cfg.lambda;
    let pool = WorkerPool::spawn(data.clone(), backend, cfg.procs);
    let total = Stopwatch::start();

    let draws = ofl_draws(n, cfg.seed);
    let mut centers = Matrix::zeros(0, d);
    let mut assignments = vec![u32::MAX; n];
    let mut opened_by = Vec::new();
    let mut epochs_log = Vec::new();

    let per_epoch = cfg.points_per_epoch();
    let num_epochs = n.div_ceil(per_epoch).max(1);
    for t in 0..num_epochs {
        let epoch_sw = Stopwatch::start();
        let lo = t * per_epoch;
        let hi = (lo + per_epoch).min(n);
        if lo >= hi {
            continue;
        }
        let snapshot = Arc::new(centers.clone());
        let base = snapshot.rows;
        let ranges = split_range(lo..hi, cfg.procs);
        let jobs: Vec<Job> = ranges
            .iter()
            .map(|r| Job::Nearest { range: r.clone(), centers: snapshot.clone() })
            .collect();
        let (outs, worker_time) = pool.scatter_gather(jobs)?;

        let mut proposals = Vec::new();
        for (w, out) in outs.iter().enumerate() {
            let JobOutput::Nearest { idx, d2 } = out else {
                return Err(Error::Coordinator("unexpected job output".into()));
            };
            for (off, i) in ranges[w].clone().enumerate() {
                let d2_prev = if base == 0 { f32::INFINITY } else { d2[off] };
                let p_send =
                    if d2_prev.is_infinite() { 1.0 } else { (d2_prev as f64 / lambda2).min(1.0) };
                if draws[i] < p_send {
                    proposals.push(OflProposal {
                        idx: i as u32,
                        center: data.point(i).to_vec(),
                        d2_prev,
                        idx_prev: idx[off],
                    });
                } else {
                    assignments[i] = idx[off];
                }
            }
        }
        proposals.sort_by_key(|p| p.idx);

        let master_sw = Stopwatch::start();
        let outcome = ofl_validate(&mut centers, base, &proposals, lambda2, |i| draws[i as usize]);
        for (i, c) in &outcome.resolved {
            assignments[*i as usize] = *c;
        }
        opened_by.extend_from_slice(&outcome.opened);
        let master_time = master_sw.elapsed();

        let rec = EpochRecord {
            iteration: 0,
            epoch: t,
            points: hi - lo,
            proposed: proposals.len(),
            accepted: outcome.accepted,
            rejected: outcome.rejected,
            centers: centers.rows,
            worker_time,
            master_time,
            total_time: epoch_sw.elapsed(),
        };
        sink.emit(&rec);
        epochs_log.push(rec);
    }

    let model = OflModel { centers: centers.clone(), assignments, opened_by };
    let summary = RunSummary {
        epochs: epochs_log,
        final_centers: centers.rows,
        objective: Some(objective::dp_objective(&data, &centers, cfg.lambda)),
        total_time: total.elapsed(),
    };
    Ok(RunOutput { summary, model: Model::Ofl(model) })
}

// ---------------------------------------------------------------------------
// OCC BP-means (Alg 6)
// ---------------------------------------------------------------------------

/// Pad-aware equality of binary assignment vectors (trailing `false`s are
/// insignificant).
fn z_eq(a: &[bool], b: &[bool]) -> bool {
    let n = a.len().max(b.len());
    (0..n).all(|i| a.get(i).copied().unwrap_or(false) == b.get(i).copied().unwrap_or(false))
}

/// Distributed BP-means.
pub fn run_bpmeans(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    sink: &mut MetricsSink,
) -> Result<RunOutput> {
    let n = data.len();
    let d = data.dim();
    let lambda2 = (cfg.lambda * cfg.lambda) as f32;
    let sweeps = 2;
    let pool = WorkerPool::spawn(data.clone(), backend, cfg.procs);
    let total = Stopwatch::start();

    // Init (Alg 7): one feature = grand mean, z_i,0 = 1 for all i.
    let mut features = Matrix::zeros(0, d);
    let mut assignments: Vec<Vec<bool>> = vec![Vec::new(); n];
    if n > 0 {
        let mut mean = vec![0.0f32; d];
        for i in 0..n {
            crate::linalg::axpy(1.0, data.point(i), &mut mean);
        }
        for m in mean.iter_mut() {
            *m /= n as f32;
        }
        features.push_row(&mean);
        for z in assignments.iter_mut() {
            z.push(true);
        }
    }

    let mut epochs_log = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    let mut created_per_pass = Vec::new();
    let mut scratch_resid = vec![0.0f32; d];

    // Bootstrap: serial first-pass BP over the first Pb/div points.
    let boot_n = bootstrap_size(cfg, n);
    for i in 0..boot_n {
        let x = data.point(i);
        let mut z = vec![false; features.rows];
        let r2 = descend_z(x, &features, &mut z, &mut scratch_resid, sweeps);
        if r2 > lambda2 {
            features.push_row(&scratch_resid);
            z.push(true);
        }
        assignments[i] = z;
    }

    for pass in 0..cfg.iterations {
        iterations += 1;
        let start = if pass == 0 { boot_n } else { 0 };
        let mut changed = boot_n > 0 && pass == 0;
        let mut created = if pass == 0 { features.rows.saturating_sub(1) } else { 0 };

        let per_epoch = cfg.points_per_epoch();
        let num_epochs = (n - start).div_ceil(per_epoch).max(1);
        for t in 0..num_epochs {
            let epoch_sw = Stopwatch::start();
            let lo = start + t * per_epoch;
            let hi = (lo + per_epoch).min(n);
            if lo >= hi {
                continue;
            }
            let snapshot = Arc::new(features.clone());
            let base = snapshot.rows;
            let ranges = split_range(lo..hi, cfg.procs);
            let jobs: Vec<Job> = ranges
                .iter()
                .map(|r| Job::BpDescend { range: r.clone(), features: snapshot.clone(), sweeps })
                .collect();
            let (outs, worker_time) = pool.scatter_gather(jobs)?;

            let mut proposals = Vec::new();
            let mut new_z: Vec<(usize, Vec<bool>)> = Vec::new();
            for (w, out) in outs.iter().enumerate() {
                let JobOutput::BpDescend { z, k, residuals, r2 } = out else {
                    return Err(Error::Coordinator("unexpected job output".into()));
                };
                for (off, i) in ranges[w].clone().enumerate() {
                    let zi = z[off * k..(off + 1) * k].to_vec();
                    if r2[off] > lambda2 {
                        proposals.push(BpProposal {
                            idx: i as u32,
                            residual: residuals[off * d..(off + 1) * d].to_vec(),
                        });
                    }
                    new_z.push((i, zi));
                }
            }
            proposals.sort_by_key(|p| p.idx);

            let master_sw = Stopwatch::start();
            let outcome = bp_validate(&mut features, base, &proposals, lambda2, sweeps);
            let master_time = master_sw.elapsed();

            // Apply worker assignments, then overlay validation resolutions.
            for (i, zi) in new_z {
                if !z_eq(&assignments[i], &zi) {
                    changed = true;
                }
                assignments[i] = zi;
            }
            for r in &outcome.resolved {
                let zi = &mut assignments[r.idx as usize];
                zi.resize(features.rows, false);
                for &f in &r.extra_features {
                    zi[f as usize] = true;
                }
                if let Some(f) = r.own_feature {
                    zi[f as usize] = true;
                }
                changed = true;
            }
            created += outcome.accepted;

            let rec = EpochRecord {
                iteration: pass,
                epoch: t,
                points: hi - lo,
                proposed: proposals.len(),
                accepted: outcome.accepted,
                rejected: outcome.rejected,
                centers: features.rows,
                worker_time,
                master_time,
                total_time: epoch_sw.elapsed(),
            };
            sink.emit(&rec);
            epochs_log.push(rec);
        }
        created_per_pass.push(created);

        // Phase 2: F ← (ZᵀZ + εI)⁻¹ ZᵀX via parallel partials.
        let recompute_sw = Stopwatch::start();
        let k = features.rows;
        if k > 0 {
            let shared = Arc::new(assignments.clone());
            let jobs: Vec<Job> = split_range_chunked(0..n, cfg.procs)
                .into_iter()
                .map(|range| Job::BpStats { range, z: shared.clone(), k })
                .collect();
            let (outs, worker_time) = pool.scatter_gather(jobs)?;
            // Deterministic reduce in global chunk order (see SuffStats).
            let mut all_chunks = Vec::new();
            for out in outs {
                let JobOutput::BpStats { chunks } = out else {
                    return Err(Error::Coordinator("unexpected job output".into()));
                };
                all_chunks.extend(chunks);
            }
            all_chunks.sort_by_key(|(id, _, _)| *id);
            let mut ztz = Matrix::zeros(k, k);
            let mut ztx = Matrix::zeros(k, d);
            for (_, a, b) in &all_chunks {
                for i in 0..k * k {
                    ztz.data[i] += a.data[i];
                }
                for i in 0..k * d {
                    ztx.data[i] += b.data[i];
                }
            }
            features = cholesky::solve_ridge(&ztz, &ztx, RIDGE_EPS)?;
            let rec = EpochRecord {
                iteration: pass,
                epoch: usize::MAX,
                points: n,
                centers: k,
                worker_time,
                total_time: recompute_sw.elapsed(),
                ..Default::default()
            };
            sink.emit(&rec);
            epochs_log.push(rec);
        }

        if !changed {
            converged = true;
            break;
        }
    }

    // Normalize assignment lengths.
    for z in assignments.iter_mut() {
        z.resize(features.rows, false);
    }
    let model = BpModel {
        features: features.clone(),
        assignments: assignments.clone(),
        iterations,
        converged,
        created_per_pass,
    };
    let summary = RunSummary {
        epochs: epochs_log,
        final_centers: features.rows,
        objective: Some(objective::bp_objective(&data, &features, &assignments, cfg.lambda)),
        total_time: total.elapsed(),
    };
    Ok(RunOutput { summary, model: Model::Bp(model) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::data::generators::{dp_clusters, GenConfig};

    fn cfg(algo: Algo, n: usize, procs: usize, block: usize) -> RunConfig {
        RunConfig {
            algo,
            n,
            procs,
            block,
            iterations: 2,
            bootstrap_div: 16,
            seed: 3,
            ..RunConfig::default()
        }
    }

    #[test]
    fn dpmeans_end_to_end_native() {
        let c = cfg(Algo::DpMeans, 512, 4, 32);
        let data = Arc::new(dp_clusters(&GenConfig { n: 512, dim: 16, theta: 1.0, seed: 3 }));
        let out = run_with(&c, data.clone(), Arc::new(NativeBackend::new())).unwrap();
        let Model::Dp(m) = &out.model else { panic!() };
        assert!(m.centers.rows >= 1);
        assert_eq!(m.assignments.len(), 512);
        assert!(m.assignments.iter().all(|&a| (a as usize) < m.centers.rows));
        assert!(out.summary.objective.unwrap().is_finite());
        // Every epoch: accepted + rejected == proposed.
        for e in &out.summary.epochs {
            assert_eq!(e.accepted + e.rejected, e.proposed);
        }
    }

    #[test]
    fn ofl_end_to_end_native() {
        let c = cfg(Algo::Ofl, 300, 3, 25);
        let data = Arc::new(dp_clusters(&GenConfig { n: 300, dim: 16, theta: 1.0, seed: 4 }));
        let out = run_with(&c, data, Arc::new(NativeBackend::new())).unwrap();
        let Model::Ofl(m) = &out.model else { panic!() };
        assert!(m.centers.rows >= 1);
        assert!(m.assignments.iter().all(|&a| (a as usize) < m.centers.rows));
    }

    #[test]
    fn bpmeans_end_to_end_native() {
        let c = cfg(Algo::BpMeans, 256, 4, 16);
        let data = Arc::new(crate::data::generators::bp_features(&GenConfig {
            n: 256,
            dim: 16,
            theta: 1.0,
            seed: 5,
        }));
        let out = run_with(&c, data, Arc::new(NativeBackend::new())).unwrap();
        let Model::Bp(m) = &out.model else { panic!() };
        assert!(m.features.rows >= 1);
        assert!(m.assignments.iter().all(|z| z.len() == m.features.rows));
    }

    #[test]
    fn empty_block_epoch_handles() {
        // n not divisible by Pb and smaller than one epoch.
        let c = cfg(Algo::DpMeans, 10, 4, 8);
        let data = Arc::new(dp_clusters(&GenConfig { n: 10, dim: 4, theta: 1.0, seed: 6 }));
        let out = run_with(&c, data, Arc::new(NativeBackend::new())).unwrap();
        assert!(out.model.k() >= 1);
    }
}
