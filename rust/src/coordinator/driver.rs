//! Full OCC runs: Alg 3 (DP-means), Alg 4 (OFL), Alg 6 (BP-means).
//!
//! The driver owns the global state and the per-pass structure; the epoch
//! loop itself is driven by a [`scheduler::Scheduler`] — the depth-K wave
//! engine (`cfg.scheduler` pins depth 1 for `bsp`; `cfg.speculation` sets
//! the depth for `pipelined`) — which calls back into per-algorithm
//! [`EpochAlgo`] hooks for job construction ([`JobSpec`]), merging, and
//! validation. Workers compute, the master validates (in point-index
//! order — the Thm 3.1 serial order) on the engine's dedicated validation
//! thread and replicates state by handing later epochs an updated
//! snapshot. All peer communication goes through a [`Cluster`] built from
//! `cfg.transport` (in-proc channels or TCP; see [`super::transport`]):
//! the engine's event loop drives `cluster.compute` while each pass object
//! carries `cluster.validate` — the split that lets validation-shard
//! fan-out overlap the next waves' scatters and gathers.
//!
//! Epoch structure (Fig 5): epoch `t` covers the contiguous index range
//! `[start + t·P·b, start + (t+1)·P·b)`; each worker gets a contiguous
//! block of it. Because proposals are merged and validated by point index,
//! the result is identical for every worker count `P` at fixed `P·b` — and
//! identical across schedulers (`rust/tests/scheduler_equivalence.rs`).

use super::engine::{split_range_chunked, Job, JobOutput};
use super::scheduler::{
    self, EpochAlgo, EpochCounts, EpochSource, JobSpec, Kernel, PackSpec, Scheduler,
};
use super::transport::{Cluster, PlaneWaker, Topology, ValidatePlane};
use super::validator::{
    bp_validate, dp_validate_clustered, ofl_validate_clustered, BpProposal, DpProposal,
    OflProposal,
};
use crate::algorithms::bpmeans::{descend_z, BpModel, RIDGE_EPS};
use crate::algorithms::dpmeans::DpModel;
use crate::algorithms::objective;
use crate::algorithms::ofl::{ofl_draws, OflModel};
use crate::config::{Algo, BackendKind, DataSource, KernelKind, RunConfig, ShardingKind};
use crate::data::{generators, DataCell, Dataset};
use crate::error::{Error, Result};
use crate::linalg::{blocked, cholesky, Matrix};
use crate::metrics::{EpochRecord, MetricsSink, RunSummary, Stopwatch};
use crate::runtime::{native::NativeBackend, xla::XlaBackend, Block, ComputeBackend};
use std::ops::Range;
use std::sync::Arc;

/// The learned model, by algorithm.
#[derive(Debug, Clone)]
pub enum Model {
    /// DP-means output.
    Dp(DpModel),
    /// OFL output.
    Ofl(OflModel),
    /// BP-means output.
    Bp(BpModel),
}

impl Model {
    /// Number of clusters / facilities / features.
    pub fn k(&self) -> usize {
        match self {
            Model::Dp(m) => m.centers.rows,
            Model::Ofl(m) => m.centers.rows,
            Model::Bp(m) => m.features.rows,
        }
    }
}

/// A complete run result.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// Metrics summary (per-epoch records, objective, totals).
    pub summary: RunSummary,
    /// The learned model.
    pub model: Model,
}

/// Generate or load the dataset a config names.
pub fn load_or_generate(cfg: &RunConfig) -> Result<Dataset> {
    let gen = generators::GenConfig { n: cfg.n, dim: cfg.dim, theta: cfg.theta, seed: cfg.seed };
    match &cfg.source {
        DataSource::DpClusters => Ok(generators::dp_clusters(&gen)),
        DataSource::BpFeatures => Ok(generators::bp_features(&gen)),
        DataSource::Separable => Ok(generators::separable_clusters(&gen)),
        DataSource::File(path) => crate::data::io::read_occb(path),
    }
}

/// Build the configured compute backend.
pub fn make_backend(cfg: &RunConfig) -> Result<Arc<dyn ComputeBackend>> {
    match cfg.backend {
        BackendKind::Native => Ok(Arc::new(NativeBackend::with_kernel(cfg.kernel))),
        BackendKind::Xla => Ok(Arc::new(XlaBackend::load(&cfg.artifacts_dir)?)),
    }
}

/// Run the configured algorithm end to end (data + backend from config).
pub fn run(cfg: &RunConfig) -> Result<RunOutput> {
    let data = Arc::new(load_or_generate(cfg)?);
    let backend = make_backend(cfg)?;
    run_with(cfg, data, backend)
}

/// Run with an explicit dataset and backend (the embedding API used by the
/// examples, benches and tests).
pub fn run_with(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
) -> Result<RunOutput> {
    cfg.validate()?;
    let mut sink = MetricsSink::open(cfg.metrics_path.as_deref())?;
    let out = match cfg.algo {
        Algo::DpMeans => run_dpmeans(cfg, data, backend, &mut sink),
        Algo::Ofl => run_ofl(cfg, data, backend, &mut sink),
        Algo::BpMeans => run_bpmeans(cfg, data, backend, &mut sink),
    };
    sink.flush();
    out
}

/// Bootstrap size (§4.2): 1/`bootstrap_div` of the first `P·b` points,
/// clamped to the dataset.
fn bootstrap_size(cfg: &RunConfig, n: usize) -> usize {
    if cfg.bootstrap_div == 0 {
        0
    } else {
        (cfg.points_per_epoch() / cfg.bootstrap_div).min(n)
    }
}

/// Contiguous non-empty epoch ranges covering `[start, n)` in `per_epoch`
/// steps.
fn epoch_ranges(start: usize, n: usize, per_epoch: usize) -> Vec<Range<usize>> {
    assert!(per_epoch > 0, "points per epoch (P·b) must be ≥ 1");
    let mut out = Vec::new();
    let mut lo = start;
    while lo < n {
        let hi = (lo + per_epoch).min(n);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

/// Patch per-point nearest-center outputs computed against the first
/// `stale_rows` committed rows so they equal a fresh scan of the full
/// committed set, bit for bit: query the delta rows and fold with the
/// kernel's first-minimum tie-break (delta rows sit at strictly higher
/// indices, so they win only on strictly smaller d²).
///
/// No re-query escape hatch is needed: the canonical kernel computes every
/// point×center distance independently — one fixed reduction schedule, one
/// per-*pair* clamp (see [`crate::linalg`]) — so the stale scan, the delta
/// scan and a full scan produce identical distance bits per pair, and the
/// strict-< fold reproduces the full scan's first-minimum exactly. (The old
/// tiled kernel clamped its *running best* per center tile, which could
/// erase sub-zero ordering across the stale/delta boundary and forced a
/// per-point re-query on zeros.) See [`scheduler`](super::scheduler) for
/// why this preserves Thm 3.1.
fn patch_nearest(
    data: &Dataset,
    backend: &Arc<dyn ComputeBackend>,
    centers: &Matrix,
    stale_rows: usize,
    outs: &mut [JobOutput],
    ranges: &[Range<usize>],
) -> Result<()> {
    let committed = centers.rows;
    debug_assert!(stale_rows < committed);
    let d = centers.cols;
    let delta = Matrix {
        rows: committed - stale_rows,
        cols: d,
        data: centers.data[stale_rows * d..committed * d].to_vec(),
    };
    for (w, out) in outs.iter_mut().enumerate() {
        let JobOutput::Nearest { idx, d2 } = out else {
            return Err(Error::Coordinator("unexpected job output".into()));
        };
        let range = ranges[w].clone();
        if range.is_empty() {
            continue;
        }
        let n = range.len();
        let mut di = vec![0u32; n];
        let mut dd = vec![0.0f32; n];
        backend.nearest_with(Block::of_dataset(data, range), &delta, None, &mut di, &mut dd)?;
        for off in 0..n {
            if dd[off] < d2[off] {
                d2[off] = dd[off];
                idx[off] = (stale_rows as u32) + di[off];
            }
        }
    }
    Ok(())
}

/// Phase 2 (DP-means / OFL state shape): recompute `centers` as the means
/// of their assigned points via parallel suffstats, and log the recompute
/// pseudo-epoch (`epoch = usize::MAX`). Shared by the static per-pass loop
/// and the streaming post-drain recompute.
#[allow(clippy::too_many_arguments)]
fn dp_recompute(
    cluster: &mut Cluster,
    procs: usize,
    n: usize,
    pass: usize,
    assignments: &[u32],
    centers: &mut Matrix,
    kernel: KernelKind,
    sink: &mut MetricsSink,
    epochs_log: &mut Vec<EpochRecord>,
) -> Result<()> {
    let net0 = cluster.stats();
    let recompute_sw = Stopwatch::start();
    let k = centers.rows;
    let d = centers.cols;
    if k == 0 {
        return Ok(());
    }
    let shared = Arc::new(assignments.to_vec());
    let jobs: Vec<Job> = split_range_chunked(0..n, procs)
        .into_iter()
        .map(|range| Job::SuffStats { range, assignments: shared.clone(), k })
        .collect();
    let (outs, worker_time) = cluster.scatter_gather(jobs)?;
    // Deterministic reduce: combine per-chunk partials in global chunk
    // order, independent of the worker count.
    let mut all_chunks = Vec::new();
    for out in outs {
        let JobOutput::SuffStats { chunks } = out else {
            return Err(Error::Coordinator("unexpected job output".into()));
        };
        all_chunks.extend(chunks);
    }
    all_chunks.sort_by_key(|(id, _, _)| *id);
    let mut sums = Matrix::zeros(k, d);
    let mut counts = vec![0u64; k];
    for (_, s, c) in &all_chunks {
        for kk in 0..k {
            counts[kk] += c[kk];
            crate::linalg::axpy(1.0, s.row(kk), sums.row_mut(kk));
        }
    }
    blocked::finalize_means(&sums, &counts, centers);
    let net = cluster.stats().since(&net0);
    let rec = EpochRecord {
        resident_data_bytes: net.resident_data_bytes,
        iteration: pass,
        epoch: usize::MAX, // convention: the recompute "epoch"
        points: n,
        centers: k,
        worker_time,
        compute_time: worker_time,
        kernel: kernel.name(),
        total_time: recompute_sw.elapsed(),
        wire_bytes: net.wire_bytes,
        unique_payload_bytes: net.unique_payload_bytes,
        delta_bytes: net.delta_bytes,
        full_snapshot_fallbacks: net.full_snapshot_fallbacks,
        ser_time: net.ser_time,
        gather_wait_time: net.gather_wait_time,
        dataset_bytes: net.dataset_bytes,
        handshake_time: net.handshake_time,
        reactor_wakeups: net.reactor_wakeups,
        writev_batches: net.writev_batches,
        ..Default::default()
    };
    sink.emit(&rec);
    epochs_log.push(rec);
    Ok(())
}

/// Phase 2 (BP-means): `F ← (ZᵀZ + εI)⁻¹ ZᵀX` via parallel partials, and
/// log the recompute pseudo-epoch. Shared like [`dp_recompute`].
#[allow(clippy::too_many_arguments)]
fn bp_recompute(
    cluster: &mut Cluster,
    procs: usize,
    n: usize,
    pass: usize,
    assignments: &[Vec<bool>],
    features: &mut Matrix,
    kernel: KernelKind,
    sink: &mut MetricsSink,
    epochs_log: &mut Vec<EpochRecord>,
) -> Result<()> {
    let net0 = cluster.stats();
    let recompute_sw = Stopwatch::start();
    let k = features.rows;
    let d = features.cols;
    if k == 0 {
        return Ok(());
    }
    let shared = Arc::new(assignments.to_vec());
    let jobs: Vec<Job> = split_range_chunked(0..n, procs)
        .into_iter()
        .map(|range| Job::BpStats { range, z: shared.clone(), k })
        .collect();
    let (outs, worker_time) = cluster.scatter_gather(jobs)?;
    // Deterministic reduce in global chunk order (see SuffStats).
    let mut all_chunks = Vec::new();
    for out in outs {
        let JobOutput::BpStats { chunks } = out else {
            return Err(Error::Coordinator("unexpected job output".into()));
        };
        all_chunks.extend(chunks);
    }
    all_chunks.sort_by_key(|(id, _, _)| *id);
    let mut ztz = Matrix::zeros(k, k);
    let mut ztx = Matrix::zeros(k, d);
    for (_, a, b) in &all_chunks {
        for i in 0..k * k {
            ztz.data[i] += a.data[i];
        }
        for i in 0..k * d {
            ztx.data[i] += b.data[i];
        }
    }
    *features = cholesky::solve_ridge(&ztz, &ztx, RIDGE_EPS)?;
    let net = cluster.stats().since(&net0);
    let rec = EpochRecord {
        resident_data_bytes: net.resident_data_bytes,
        iteration: pass,
        epoch: usize::MAX,
        points: n,
        centers: k,
        worker_time,
        compute_time: worker_time,
        kernel: kernel.name(),
        total_time: recompute_sw.elapsed(),
        wire_bytes: net.wire_bytes,
        unique_payload_bytes: net.unique_payload_bytes,
        delta_bytes: net.delta_bytes,
        full_snapshot_fallbacks: net.full_snapshot_fallbacks,
        ser_time: net.ser_time,
        gather_wait_time: net.gather_wait_time,
        dataset_bytes: net.dataset_bytes,
        handshake_time: net.handshake_time,
        reactor_wakeups: net.reactor_wakeups,
        writev_batches: net.writev_batches,
        ..Default::default()
    };
    sink.emit(&rec);
    epochs_log.push(rec);
    Ok(())
}

// ---------------------------------------------------------------------------
// OCC DP-means (Alg 3)
// ---------------------------------------------------------------------------

/// One DP-means pass's mutable state, driven by a scheduler. The whole
/// pass object (committed state + the validation-plane handle) moves to
/// the wave engine's dedicated validation thread for the pass.
struct DpPass<'a> {
    vplane: &'a mut ValidatePlane,
    data: &'a DataCell,
    backend: &'a Arc<dyn ComputeBackend>,
    centers: &'a mut Matrix,
    assignments: &'a mut Vec<u32>,
    lambda2: f32,
    shards: usize,
    sharding: ShardingKind,
    changed: bool,
    created: usize,
}

/// The packing half of a pass's [`JobSpec`]: conflict packing needs the
/// dataset to key points against the scatter-time snapshot.
fn pack_spec(sharding: ShardingKind, data: &DataCell) -> PackSpec {
    match sharding {
        ShardingKind::Hash => PackSpec::Hash,
        ShardingKind::Conflict => PackSpec::Conflict { data: data.get() },
    }
}

/// Grow a per-point vector to cover every index `ranges` touches — a
/// no-op for static runs (sized up front), the growth step for live
/// sources whose dataset extends between epochs.
fn ensure_len<T: Clone>(v: &mut Vec<T>, ranges: &[Range<usize>], fill: T) {
    let needed = ranges.iter().map(|r| r.end).max().unwrap_or(0);
    if v.len() < needed {
        v.resize(needed, fill);
    }
}

impl EpochAlgo for DpPass<'_> {
    fn snapshot(&self) -> Arc<Matrix> {
        Arc::new(self.centers.clone())
    }

    fn committed_rows(&self) -> usize {
        self.centers.rows
    }

    fn job_spec(&self) -> JobSpec {
        JobSpec { kernel: Kernel::Nearest, pack: pack_spec(self.sharding, self.data) }
    }

    fn can_patch(&self) -> bool {
        true
    }

    fn patch(
        &mut self,
        outs: &mut [JobOutput],
        ranges: &[Range<usize>],
        stale_rows: usize,
    ) -> Result<()> {
        let data = self.data.get();
        patch_nearest(&data, self.backend, self.centers, stale_rows, outs, ranges)
    }

    fn validate(&mut self, outs: &[JobOutput], ranges: &[Range<usize>]) -> Result<EpochCounts> {
        let data = self.data.get();
        ensure_len(self.assignments, ranges, u32::MAX);
        let base = self.centers.rows;
        // Merge results by index; collect proposals (with their conflict
        // key: the proposing point's nearest committed center) in index
        // order.
        let mut pairs: Vec<(DpProposal, u32)> = Vec::new();
        for (w, out) in outs.iter().enumerate() {
            let JobOutput::Nearest { idx, d2 } = out else {
                return Err(Error::Coordinator("unexpected job output".into()));
            };
            for (off, i) in ranges[w].clone().enumerate() {
                if d2[off] > self.lambda2 {
                    pairs.push((
                        DpProposal { idx: i as u32, center: data.point(i).to_vec() },
                        idx[off],
                    ));
                } else if self.assignments[i] != idx[off] {
                    self.assignments[i] = idx[off];
                    self.changed = true;
                }
            }
        }
        pairs.sort_by_key(|(p, _)| p.idx);
        let (proposals, keys): (Vec<DpProposal>, Vec<u32>) = pairs.into_iter().unzip();

        // Validation at the master: conflict pre-computation on the
        // cluster's validator peers (through the validation-plane handle
        // this pass owns), then the serial point-index-order merge.
        let outcome = dp_validate_clustered(
            self.vplane,
            self.centers,
            base,
            &proposals,
            &keys,
            self.lambda2,
            self.shards,
            self.sharding,
        )?;
        for (i, c) in &outcome.resolved {
            if self.assignments[*i as usize] != *c {
                self.assignments[*i as usize] = *c;
                self.changed = true;
            }
        }
        self.created += outcome.accepted;
        Ok(EpochCounts {
            proposed: proposals.len(),
            accepted: outcome.accepted,
            rejected: outcome.rejected,
            state_rows: self.centers.rows,
        })
    }
}

/// Distributed DP-means.
pub fn run_dpmeans(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    sink: &mut MetricsSink,
) -> Result<RunOutput> {
    let n = data.len();
    let d = data.dim();
    let lambda2 = (cfg.lambda * cfg.lambda) as f32;
    let cell = Arc::new(DataCell::new(data.clone()));
    let mut cluster = Cluster::spawn_topology_cell(
        cfg.transport,
        cell.clone(),
        backend.clone(),
        &Topology::of_config(cfg, cfg.effective_validators()),
    )?;
    let sched = scheduler::make(cfg.scheduler, cfg.speculation_spec(), cfg.io, cfg.kernel);
    let total = Stopwatch::start();

    let mut centers = Matrix::zeros(0, d);
    let mut assignments = vec![u32::MAX; n];
    let mut epochs_log = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    let mut created_per_pass = Vec::new();

    // Bootstrap: serially pre-process the first Pb/div points (first pass
    // only). They are the first points of the serial order, so this
    // preserves serializability.
    let boot_n = bootstrap_size(cfg, n);
    for i in 0..boot_n {
        let x = data.point(i);
        let (k, d2) = crate::linalg::nearest(x, &centers);
        assignments[i] = if d2 > lambda2 {
            centers.push_row(x);
            (centers.rows - 1) as u32
        } else {
            k as u32
        };
    }

    for pass in 0..cfg.iterations {
        iterations += 1;
        let start = if pass == 0 { boot_n } else { 0 };
        let changed0 = boot_n > 0 && pass == 0; // bootstrap assigned points
        let created0 = if pass == 0 { centers.rows } else { 0 };

        let epochs = epoch_ranges(start, n, cfg.points_per_epoch());
        // Conflict-key buckets: at least one per validator peer, so every
        // peer can own a non-empty key range (the bucket count never
        // changes the outcome — only the parallelism).
        let shards = cfg.procs.max(cluster.validators);
        let mut st = DpPass {
            vplane: &mut cluster.validate,
            data: &cell,
            backend: &backend,
            centers: &mut centers,
            assignments: &mut assignments,
            lambda2,
            shards,
            sharding: cfg.sharding,
            changed: changed0,
            created: created0,
        };
        sched.run_pass(&mut cluster.compute, &mut st, &epochs, pass, sink, &mut epochs_log)?;
        let changed = st.changed;
        created_per_pass.push(st.created);

        dp_recompute(&mut cluster, cfg.procs, n, pass, &assignments, &mut centers, cfg.kernel, sink, &mut epochs_log)?;

        if !changed {
            converged = true;
            break;
        }
    }

    let model = DpModel {
        centers: centers.clone(),
        assignments,
        iterations,
        converged,
        created_per_pass,
    };
    let summary = RunSummary {
        epochs: epochs_log,
        final_centers: centers.rows,
        objective: Some(objective::dp_objective(&data, &centers, cfg.lambda)),
        total_time: total.elapsed(),
        transport: cluster.stats(),
    };
    Ok(RunOutput { summary, model: Model::Dp(model) })
}

// ---------------------------------------------------------------------------
// OCC OFL (Alg 4)
// ---------------------------------------------------------------------------

/// The OFL single pass's mutable state, driven by a scheduler.
struct OflPass<'a> {
    vplane: &'a mut ValidatePlane,
    data: &'a DataCell,
    backend: &'a Arc<dyn ComputeBackend>,
    centers: &'a mut Matrix,
    assignments: &'a mut Vec<u32>,
    opened_by: &'a mut Vec<u32>,
    /// Per-point uniform draws, shared with the serial algorithm. Grown on
    /// demand under a live source: [`ofl_draws`] is prefix-stable (one
    /// fixed PCG stream), so extending the vector never changes the draws
    /// earlier points already consumed.
    draws: &'a mut Vec<f64>,
    seed: u64,
    lambda2: f64,
    shards: usize,
    sharding: ShardingKind,
}

impl EpochAlgo for OflPass<'_> {
    fn snapshot(&self) -> Arc<Matrix> {
        Arc::new(self.centers.clone())
    }

    fn committed_rows(&self) -> usize {
        self.centers.rows
    }

    fn job_spec(&self) -> JobSpec {
        JobSpec { kernel: Kernel::Nearest, pack: pack_spec(self.sharding, self.data) }
    }

    fn can_patch(&self) -> bool {
        true
    }

    fn patch(
        &mut self,
        outs: &mut [JobOutput],
        ranges: &[Range<usize>],
        stale_rows: usize,
    ) -> Result<()> {
        let data = self.data.get();
        patch_nearest(&data, self.backend, self.centers, stale_rows, outs, ranges)
    }

    fn validate(&mut self, outs: &[JobOutput], ranges: &[Range<usize>]) -> Result<EpochCounts> {
        let data = self.data.get();
        ensure_len(self.assignments, ranges, u32::MAX);
        let needed = ranges.iter().map(|r| r.end).max().unwrap_or(0);
        if self.draws.len() < needed {
            // Prefix-stable regeneration: the first `len` draws come out
            // bit-identical, so streamed points see the exact draws a
            // static run over the final dataset would give them.
            *self.draws = ofl_draws(needed, self.seed);
        }
        let base = self.centers.rows;
        let mut pairs: Vec<(OflProposal, u32)> = Vec::new();
        for (w, out) in outs.iter().enumerate() {
            let JobOutput::Nearest { idx, d2 } = out else {
                return Err(Error::Coordinator("unexpected job output".into()));
            };
            for (off, i) in ranges[w].clone().enumerate() {
                let d2_prev = if base == 0 { f32::INFINITY } else { d2[off] };
                let p_send = if d2_prev.is_infinite() {
                    1.0
                } else {
                    (d2_prev as f64 / self.lambda2).min(1.0)
                };
                if self.draws[i] < p_send {
                    pairs.push((
                        OflProposal {
                            idx: i as u32,
                            center: data.point(i).to_vec(),
                            d2_prev,
                            idx_prev: idx[off],
                        },
                        idx[off],
                    ));
                } else {
                    self.assignments[i] = idx[off];
                }
            }
        }
        pairs.sort_by_key(|(p, _)| p.idx);
        let (proposals, keys): (Vec<OflProposal>, Vec<u32>) = pairs.into_iter().unzip();

        let draws: &[f64] = self.draws;
        let outcome = ofl_validate_clustered(
            self.vplane,
            self.centers,
            base,
            &proposals,
            &keys,
            self.lambda2,
            |i| draws[i as usize],
            self.shards,
            self.sharding,
        )?;
        for (i, c) in &outcome.resolved {
            self.assignments[*i as usize] = *c;
        }
        self.opened_by.extend_from_slice(&outcome.opened);
        Ok(EpochCounts {
            proposed: proposals.len(),
            accepted: outcome.accepted,
            rejected: outcome.rejected,
            state_rows: self.centers.rows,
        })
    }
}

/// Distributed online facility location. Single pass, no bootstrap (§4.2);
/// stochastic proposals and validation share per-point uniform draws with
/// the serial algorithm, making the returned facilities bit-identical to
/// [`crate::algorithms::ofl::serial_ofl`] with the same seed.
pub fn run_ofl(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    sink: &mut MetricsSink,
) -> Result<RunOutput> {
    let n = data.len();
    let d = data.dim();
    let lambda2 = cfg.lambda * cfg.lambda;
    let cell = Arc::new(DataCell::new(data.clone()));
    let mut cluster = Cluster::spawn_topology_cell(
        cfg.transport,
        cell.clone(),
        backend.clone(),
        &Topology::of_config(cfg, cfg.effective_validators()),
    )?;
    let sched = scheduler::make(cfg.scheduler, cfg.speculation_spec(), cfg.io, cfg.kernel);
    let total = Stopwatch::start();

    let mut draws = ofl_draws(n, cfg.seed);
    let mut centers = Matrix::zeros(0, d);
    let mut assignments = vec![u32::MAX; n];
    let mut opened_by = Vec::new();
    let mut epochs_log = Vec::new();

    let epochs = epoch_ranges(0, n, cfg.points_per_epoch());
    // See DpPass: one conflict-key bucket per validator peer minimum.
    let shards = cfg.procs.max(cluster.validators);
    let mut st = OflPass {
        vplane: &mut cluster.validate,
        data: &cell,
        backend: &backend,
        centers: &mut centers,
        assignments: &mut assignments,
        opened_by: &mut opened_by,
        draws: &mut draws,
        seed: cfg.seed,
        lambda2,
        shards,
        sharding: cfg.sharding,
    };
    sched.run_pass(&mut cluster.compute, &mut st, &epochs, 0, sink, &mut epochs_log)?;
    drop(st);

    let model = OflModel { centers: centers.clone(), assignments, opened_by };
    let summary = RunSummary {
        epochs: epochs_log,
        final_centers: centers.rows,
        objective: Some(objective::dp_objective(&data, &centers, cfg.lambda)),
        total_time: total.elapsed(),
        transport: cluster.stats(),
    };
    Ok(RunOutput { summary, model: Model::Ofl(model) })
}

// ---------------------------------------------------------------------------
// OCC BP-means (Alg 6)
// ---------------------------------------------------------------------------

/// Pad-aware equality of binary assignment vectors (trailing `false`s are
/// insignificant).
fn z_eq(a: &[bool], b: &[bool]) -> bool {
    let n = a.len().max(b.len());
    (0..n).all(|i| a.get(i).copied().unwrap_or(false) == b.get(i).copied().unwrap_or(false))
}

/// One BP-means pass's mutable state, driven by a scheduler.
///
/// BP outputs cannot be patched after the fact (`can_patch` = false):
/// coordinate descent over `F^{t}` is a joint optimization, not a per-row
/// reduction of per-feature terms, so the pipelined scheduler redoes the
/// epoch when speculation conflicts with newly-accepted features.
struct BpPass<'a> {
    data: &'a DataCell,
    features: &'a mut Matrix,
    assignments: &'a mut Vec<Vec<bool>>,
    lambda2: f32,
    sweeps: usize,
    sharding: ShardingKind,
    changed: bool,
    created: usize,
}

impl EpochAlgo for BpPass<'_> {
    fn snapshot(&self) -> Arc<Matrix> {
        Arc::new(self.features.clone())
    }

    fn committed_rows(&self) -> usize {
        self.features.rows
    }

    fn job_spec(&self) -> JobSpec {
        JobSpec {
            kernel: Kernel::BpDescend { sweeps: self.sweeps },
            pack: pack_spec(self.sharding, self.data),
        }
    }

    fn can_patch(&self) -> bool {
        false
    }

    fn patch(
        &mut self,
        _outs: &mut [JobOutput],
        _ranges: &[Range<usize>],
        _stale_rows: usize,
    ) -> Result<()> {
        Err(Error::Coordinator("BP-means outputs cannot be patched".into()))
    }

    fn validate(&mut self, outs: &[JobOutput], ranges: &[Range<usize>]) -> Result<EpochCounts> {
        ensure_len(self.assignments, ranges, Vec::new());
        let base = self.features.rows;
        let d = self.features.cols;
        let mut proposals = Vec::new();
        let mut new_z: Vec<(usize, Vec<bool>)> = Vec::new();
        for (w, out) in outs.iter().enumerate() {
            let JobOutput::BpDescend { z, k, residuals, r2 } = out else {
                return Err(Error::Coordinator("unexpected job output".into()));
            };
            for (off, i) in ranges[w].clone().enumerate() {
                let zi = z[off * k..(off + 1) * k].to_vec();
                if r2[off] > self.lambda2 {
                    proposals.push(BpProposal {
                        idx: i as u32,
                        residual: residuals[off * d..(off + 1) * d].to_vec(),
                    });
                }
                new_z.push((i, zi));
            }
        }
        proposals.sort_by_key(|p| p.idx);

        let outcome = bp_validate(self.features, base, &proposals, self.lambda2, self.sweeps);

        // Apply worker assignments, then overlay validation resolutions.
        for (i, zi) in new_z {
            if !z_eq(&self.assignments[i], &zi) {
                self.changed = true;
            }
            self.assignments[i] = zi;
        }
        for r in &outcome.resolved {
            let zi = &mut self.assignments[r.idx as usize];
            zi.resize(self.features.rows, false);
            for &f in &r.extra_features {
                zi[f as usize] = true;
            }
            if let Some(f) = r.own_feature {
                zi[f as usize] = true;
            }
            self.changed = true;
        }
        self.created += outcome.accepted;
        Ok(EpochCounts {
            proposed: proposals.len(),
            accepted: outcome.accepted,
            rejected: outcome.rejected,
            state_rows: self.features.rows,
        })
    }
}

/// Distributed BP-means.
pub fn run_bpmeans(
    cfg: &RunConfig,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    sink: &mut MetricsSink,
) -> Result<RunOutput> {
    let n = data.len();
    let d = data.dim();
    let lambda2 = (cfg.lambda * cfg.lambda) as f32;
    let sweeps = 2;
    // BP validation has no sharded variant (accepted features are derived
    // residuals — see `validator`), so don't spawn a validation plane that
    // would never receive a job: one placeholder peer keeps the Cluster
    // invariants without the thread/socket cost (extra validator_peers
    // addresses are dropped by the topology).
    let cell = Arc::new(DataCell::new(data.clone()));
    let mut cluster = Cluster::spawn_topology_cell(
        cfg.transport,
        cell.clone(),
        backend.clone(),
        &Topology::of_config(cfg, 1),
    )?;
    let sched = scheduler::make(cfg.scheduler, cfg.speculation_spec(), cfg.io, cfg.kernel);
    let total = Stopwatch::start();

    // Init (Alg 7): one feature = grand mean, z_i,0 = 1 for all i.
    let mut features = Matrix::zeros(0, d);
    let mut assignments: Vec<Vec<bool>> = vec![Vec::new(); n];
    if n > 0 {
        let mut mean = vec![0.0f32; d];
        for i in 0..n {
            crate::linalg::axpy(1.0, data.point(i), &mut mean);
        }
        for m in mean.iter_mut() {
            *m /= n as f32;
        }
        features.push_row(&mean);
        for z in assignments.iter_mut() {
            z.push(true);
        }
    }

    let mut epochs_log = Vec::new();
    let mut converged = false;
    let mut iterations = 0;
    let mut created_per_pass = Vec::new();
    let mut scratch_resid = vec![0.0f32; d];

    // Bootstrap: serial first-pass BP over the first Pb/div points.
    let boot_n = bootstrap_size(cfg, n);
    for i in 0..boot_n {
        let x = data.point(i);
        let mut z = vec![false; features.rows];
        let r2 = descend_z(x, &features, &mut z, &mut scratch_resid, sweeps);
        if r2 > lambda2 {
            features.push_row(&scratch_resid);
            z.push(true);
        }
        assignments[i] = z;
    }

    for pass in 0..cfg.iterations {
        iterations += 1;
        let start = if pass == 0 { boot_n } else { 0 };
        let changed0 = boot_n > 0 && pass == 0;
        let created0 = if pass == 0 { features.rows.saturating_sub(1) } else { 0 };

        let epochs = epoch_ranges(start, n, cfg.points_per_epoch());
        let mut st = BpPass {
            data: &cell,
            features: &mut features,
            assignments: &mut assignments,
            lambda2,
            sweeps,
            sharding: cfg.sharding,
            changed: changed0,
            created: created0,
        };
        sched.run_pass(&mut cluster.compute, &mut st, &epochs, pass, sink, &mut epochs_log)?;
        let changed = st.changed;
        created_per_pass.push(st.created);

        // Phase 2: F ← (ZᵀZ + εI)⁻¹ ZᵀX via parallel partials.
        bp_recompute(
            &mut cluster,
            cfg.procs,
            n,
            pass,
            &assignments,
            &mut features,
            cfg.kernel,
            sink,
            &mut epochs_log,
        )?;

        if !changed {
            converged = true;
            break;
        }
    }

    // Normalize assignment lengths.
    for z in assignments.iter_mut() {
        z.resize(features.rows, false);
    }
    let model = BpModel {
        features: features.clone(),
        assignments: assignments.clone(),
        iterations,
        converged,
        created_per_pass,
    };
    let summary = RunSummary {
        epochs: epochs_log,
        final_centers: features.rows,
        objective: Some(objective::bp_objective(&data, &features, &assignments, cfg.lambda)),
        total_time: total.elapsed(),
        transport: cluster.stats(),
    };
    Ok(RunOutput { summary, model: Model::Bp(model) })
}

// ---------------------------------------------------------------------------
// Streaming ingest (the `occd serve` engine half)
// ---------------------------------------------------------------------------

/// Run one streaming pass of the configured algorithm against an
/// [`EpochSource`] instead of a pre-split static dataset.
///
/// The `cell` is the shared dataset generation the transport planes read
/// from; whoever feeds `source` (the live admission queue, or a
/// [`scheduler::StaticSource`] replay) must publish each grown generation
/// into the cell *before* announcing the epoch that reads it. Model state
/// is growable: `validate` extends assignments (and OFL's per-point draws,
/// prefix-stably) to cover whatever spans the source admits, so DP-means,
/// OFL and BP-means run unmodified.
///
/// Replaying the same admitted spans over the same final dataset through
/// this same function yields a bit-identical model — the streamed result
/// *is* the static result for the admitted order (Thm 3.1 doesn't care
/// when the points arrived). `rust/tests/serve_stream.rs` holds that
/// keystone to the bit.
///
/// `publish_waker` receives the compute plane's waker (None in poll mode /
/// in-proc) right after the cluster spawns — the admission side uses it to
/// pop the engine out of its reactor park the moment a batch seals,
/// instead of waiting out the idle-poll cap.
///
/// Streaming is single-pass with a post-drain recompute phase; DP/BP
/// models report `iterations = 1, converged = false`. `bootstrap_div`
/// must be 0: there is no dataset prefix to bootstrap over before the
/// stream starts.
pub fn run_streaming(
    cfg: &RunConfig,
    cell: Arc<DataCell>,
    source: &mut dyn EpochSource,
    sink: &mut MetricsSink,
    publish_waker: impl FnOnce(Option<Arc<dyn PlaneWaker>>),
) -> Result<RunOutput> {
    cfg.validate()?;
    if cfg.bootstrap_div != 0 {
        return Err(Error::Config(
            "streaming runs take bootstrap_div = 0 (no prefix to bootstrap over)".into(),
        ));
    }
    let backend = make_backend(cfg)?;
    // BP validation has no sharded variant (see `run_bpmeans`).
    let validators = match cfg.algo {
        Algo::BpMeans => 1,
        _ => cfg.effective_validators(),
    };
    let mut cluster = Cluster::spawn_topology_cell(
        cfg.transport,
        cell.clone(),
        backend.clone(),
        &Topology::of_config(cfg, validators),
    )?;
    publish_waker(cluster.compute.waker());
    let sched = scheduler::make(cfg.scheduler, cfg.speculation_spec(), cfg.io, cfg.kernel);
    let total = Stopwatch::start();
    let d = cell.get().dim();
    let mut epochs_log = Vec::new();

    let (model, objective) = match cfg.algo {
        Algo::DpMeans => {
            let lambda2 = (cfg.lambda * cfg.lambda) as f32;
            let mut centers = Matrix::zeros(0, d);
            let mut assignments: Vec<u32> = Vec::new();
            let shards = cfg.procs.max(cluster.validators);
            let mut st = DpPass {
                vplane: &mut cluster.validate,
                data: &cell,
                backend: &backend,
                centers: &mut centers,
                assignments: &mut assignments,
                lambda2,
                shards,
                sharding: cfg.sharding,
                changed: false,
                created: 0,
            };
            sched.run_source(&mut cluster.compute, &mut st, source, 0, sink, &mut epochs_log)?;
            let created = st.created;
            drop(st);
            let data = cell.get();
            let n = data.len();
            assignments.resize(n, u32::MAX);
            dp_recompute(&mut cluster, cfg.procs, n, 0, &assignments, &mut centers, cfg.kernel, sink, &mut epochs_log)?;
            let model = DpModel {
                centers: centers.clone(),
                assignments,
                iterations: 1,
                converged: false,
                created_per_pass: vec![created],
            };
            let obj = objective::dp_objective(&data, &centers, cfg.lambda);
            (Model::Dp(model), Some(obj))
        }
        Algo::Ofl => {
            let lambda2 = cfg.lambda * cfg.lambda;
            let mut centers = Matrix::zeros(0, d);
            let mut assignments: Vec<u32> = Vec::new();
            let mut opened_by = Vec::new();
            // Grown on demand, prefix-stably — see `OflPass::draws`.
            let mut draws: Vec<f64> = Vec::new();
            let shards = cfg.procs.max(cluster.validators);
            let mut st = OflPass {
                vplane: &mut cluster.validate,
                data: &cell,
                backend: &backend,
                centers: &mut centers,
                assignments: &mut assignments,
                opened_by: &mut opened_by,
                draws: &mut draws,
                seed: cfg.seed,
                lambda2,
                shards,
                sharding: cfg.sharding,
            };
            sched.run_source(&mut cluster.compute, &mut st, source, 0, sink, &mut epochs_log)?;
            drop(st);
            let data = cell.get();
            assignments.resize(data.len(), u32::MAX);
            let model = OflModel { centers: centers.clone(), assignments, opened_by };
            let obj = objective::dp_objective(&data, &centers, cfg.lambda);
            (Model::Ofl(model), Some(obj))
        }
        Algo::BpMeans => {
            let lambda2 = (cfg.lambda * cfg.lambda) as f32;
            // No grand-mean init (Alg 7 needs the full dataset up front):
            // the stream starts from an empty dictionary and the first
            // proposal — the first point's own residual — seeds it.
            let mut features = Matrix::zeros(0, d);
            let mut assignments: Vec<Vec<bool>> = Vec::new();
            let mut st = BpPass {
                data: &cell,
                features: &mut features,
                assignments: &mut assignments,
                lambda2,
                sweeps: 2,
                sharding: cfg.sharding,
                changed: false,
                created: 0,
            };
            sched.run_source(&mut cluster.compute, &mut st, source, 0, sink, &mut epochs_log)?;
            let created = st.created;
            drop(st);
            let data = cell.get();
            let n = data.len();
            assignments.resize(n, Vec::new());
            bp_recompute(&mut cluster, cfg.procs, n, 0, &assignments, &mut features, cfg.kernel, sink, &mut epochs_log)?;
            for z in assignments.iter_mut() {
                z.resize(features.rows, false);
            }
            let obj = objective::bp_objective(&data, &features, &assignments, cfg.lambda);
            let model = BpModel {
                features: features.clone(),
                assignments,
                iterations: 1,
                converged: false,
                created_per_pass: vec![created],
            };
            (Model::Bp(model), Some(obj))
        }
    };

    let summary = RunSummary {
        epochs: epochs_log,
        final_centers: model.k(),
        objective,
        total_time: total.elapsed(),
        transport: cluster.stats(),
    };
    Ok(RunOutput { summary, model })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RunConfig, SchedulerKind};
    use crate::data::generators::{dp_clusters, GenConfig};

    fn cfg(algo: Algo, n: usize, procs: usize, block: usize) -> RunConfig {
        RunConfig {
            algo,
            n,
            procs,
            block,
            iterations: 2,
            bootstrap_div: 16,
            seed: 3,
            ..RunConfig::default()
        }
    }

    #[test]
    fn dpmeans_end_to_end_native() {
        let c = cfg(Algo::DpMeans, 512, 4, 32);
        let data = Arc::new(dp_clusters(&GenConfig { n: 512, dim: 16, theta: 1.0, seed: 3 }));
        let out = run_with(&c, data.clone(), Arc::new(NativeBackend::new())).unwrap();
        let Model::Dp(m) = &out.model else { panic!() };
        assert!(m.centers.rows >= 1);
        assert_eq!(m.assignments.len(), 512);
        assert!(m.assignments.iter().all(|&a| (a as usize) < m.centers.rows));
        assert!(out.summary.objective.unwrap().is_finite());
        // Every epoch: accepted + rejected == proposed.
        for e in &out.summary.epochs {
            assert_eq!(e.accepted + e.rejected, e.proposed);
        }
    }

    #[test]
    fn ofl_end_to_end_native() {
        let c = cfg(Algo::Ofl, 300, 3, 25);
        let data = Arc::new(dp_clusters(&GenConfig { n: 300, dim: 16, theta: 1.0, seed: 4 }));
        let out = run_with(&c, data, Arc::new(NativeBackend::new())).unwrap();
        let Model::Ofl(m) = &out.model else { panic!() };
        assert!(m.centers.rows >= 1);
        assert!(m.assignments.iter().all(|&a| (a as usize) < m.centers.rows));
    }

    #[test]
    fn bpmeans_end_to_end_native() {
        let c = cfg(Algo::BpMeans, 256, 4, 16);
        let data = Arc::new(crate::data::generators::bp_features(&GenConfig {
            n: 256,
            dim: 16,
            theta: 1.0,
            seed: 5,
        }));
        let out = run_with(&c, data, Arc::new(NativeBackend::new())).unwrap();
        let Model::Bp(m) = &out.model else { panic!() };
        assert!(m.features.rows >= 1);
        assert!(m.assignments.iter().all(|z| z.len() == m.features.rows));
    }

    #[test]
    fn empty_block_epoch_handles() {
        // n not divisible by Pb and smaller than one epoch.
        let c = cfg(Algo::DpMeans, 10, 4, 8);
        let data = Arc::new(dp_clusters(&GenConfig { n: 10, dim: 4, theta: 1.0, seed: 6 }));
        let out = run_with(&c, data, Arc::new(NativeBackend::new())).unwrap();
        assert!(out.model.k() >= 1);
    }

    #[test]
    fn pipelined_end_to_end_all_algorithms() {
        for algo in [Algo::DpMeans, Algo::Ofl, Algo::BpMeans] {
            let c = RunConfig {
                scheduler: SchedulerKind::Pipelined,
                ..cfg(algo, 400, 4, 20)
            };
            let data = Arc::new(load_or_generate(&RunConfig {
                source: if algo == Algo::BpMeans {
                    DataSource::BpFeatures
                } else {
                    DataSource::DpClusters
                },
                ..c.clone()
            })
            .unwrap());
            let out = run_with(&c, data, Arc::new(NativeBackend::new())).unwrap();
            assert!(out.model.k() >= 1, "{algo:?}");
            // Pipelined epochs report their queue depth; at least the
            // non-final epochs of a multi-epoch pass ran two deep.
            let deep = out.summary.epochs.iter().filter(|e| e.queue_depth == 2).count();
            assert!(deep >= 1, "{algo:?}: no overlapped epochs recorded");
        }
    }
}
