//! TCP transport: peers behind real sockets — threads, or whole processes
//! on other machines.
//!
//! Every peer (compute worker or validator shard) sits behind a socket and
//! speaks the [`super::wire`] protocol. A session opens with a versioned
//! [`wire::Hello`] handshake (role, shard assignment, dataset geometry),
//! after which the master interleaves dataset-block frames, snapshot
//! frames and job frames; the peer replies once per job, in the order the
//! jobs arrived. Nothing in the coordinator above the
//! [`super::transport::PlaneIo`] trait knows the difference —
//! `rust/tests/transport_equivalence.rs` proves models stay bit-identical.
//!
//! Peers come in two flavours, one protocol:
//!
//! * **Loopback thread peers** — a topology with no addresses binds one
//!   ephemeral listener per peer and serves [`serve_peer`] from a thread of
//!   this process. This is the default and what CI's `OCCML_TRANSPORT=tcp`
//!   job exercises: the full handshake + dataset-shipping path, in one
//!   process. The listener *persists* across sessions, so a broken
//!   loopback session is re-opened under the same bounded reconnect
//!   policy as a remote worker's (it used to poison the whole plane).
//! * **Addressed remote peers** — a `peers = ["host:port", ...]` topology
//!   connects to standalone `occd worker` processes (the same
//!   [`serve_peer`] loop behind a real `TcpListener`; see `occd worker
//!   --help` and the README runbook). Nothing is shared by `Arc`: the
//!   dataset crosses the wire too.
//!
//! ## Dataset shipping
//!
//! Workers do not share the dataset by `Arc` (that was the PR 2 gap): the
//! master ships [`wire::KIND_DATA`] block frames on demand, tracked by a
//! per-peer [`Coverage`] set. Before a job is written, exactly the missing
//! sub-ranges of [`Job::data_range`] are shipped — so each worker receives
//! precisely the point ranges it computes (its epoch blocks plus its
//! reduction stripe, ~2·n/P per pass), and validator peers — whose
//! `PairCache` jobs carry their proposal rows inline — receive none.
//! Shipped bytes are accounted in `dataset_bytes`, handshake wall-clock in
//! `handshake_time` (see [`super::transport::TransportStats`]).
//!
//! ## Snapshot delta-shipping (the per-epoch wire diet)
//!
//! Epoch snapshots (`C^{t-1}` centers / features) do not ride inside every
//! job frame. Each peer *session* keeps a single-entry snapshot cache —
//! `(id, matrix)` — mirrored master-side in `Peer::snap`, and jobs
//! reference the snapshot by id ([`wire::snapref_job_frame`]). Before a
//! referencing frame is written, `ensure_snapshot` makes the session hold
//! that id:
//!
//! * **nothing** ships when the session already holds it (a speculative
//!   wave against unchanged state, or a resend);
//! * a [`wire::SnapshotDelta`] ships when the held snapshot is a bit-exact
//!   *prefix* — between epochs of a pass the committed state only appends
//!   rows, so the delta is just the accepted rows, `O(ΔK·d)` instead of
//!   `O(K·d)` per peer per epoch. Under depth-K speculation the deltas
//!   simply chain: each in-flight wave's frame re-bases the session from
//!   the previous wave's install, because the peer processes its frames
//!   strictly in order — a single-entry cache is enough for any K;
//! * a full [`wire::KIND_SNAPSHOT`] frame ships otherwise — a cold cache
//!   (first wave, or a replacement session after a reconnect, whose
//!   handshake clears both mirrors) or a rewritten prefix (the
//!   mean-recompute / BP re-estimate pass boundary). Counted in
//!   `full_snapshot_fallbacks`.
//!
//! Reconstruction is bit-exact by construction — both directions move raw
//! f32 bit patterns, and the peer re-bases only against the exact `(id,
//! rows)` the master installed (any mismatch is a typed error surfaced on
//! the next referencing job). Classifications and encodings are memoized
//! per wave ([`SnapMemo`]), so master-side encode effort stays
//! `O(snapshot)`, not `O(P · snapshot)` — the delta-era successor of the
//! PR 3 splice cache, which still serves the reduction waves' shared
//! assignment vectors. `Topology::frugal_wire = false` restores the PR 3
//! embed-everything shape as the A/B baseline.
//!
//! ## Multi-wave pending set
//!
//! A [`TcpPlane`] is **multi-wave**: the wave engine scatters up to
//! `speculation = K` epochs before the first commit retires, so several
//! waves are outstanding per peer at once. Each peer owes one reply per
//! delivered job, in delivery order — tracked by a per-peer `owed` queue —
//! and the PR 4 readiness poll generalizes from "one wave's replies" to a
//! pending *set*: a nonblocking pump drains whatever bytes any peer has,
//! pops complete frames ([`wire::poll_frame`]) and routes each reply to
//! the wave at the front of that peer's owed queue. Waves are retired by
//! [`super::transport::WaveId`] in any order ([`TcpPlane::gather`]), or
//! polled without blocking ([`TcpPlane::try_ready`]); outputs are always
//! slotted by peer id, so determinism is untouched. Idle wall-clock spent
//! waiting on the slowest peers is accounted in `gather_wait_time`.
//!
//! ## The I/O plane: where this plane blocks, and how writes leave
//!
//! Under `io = "reactor"` (the default) the plane owns a
//! [`super::reactor::Reactor`] and **every** blocking moment lands in
//! [`Reactor::wait`](super::reactor::Reactor::wait): each peer socket is
//! switched nonblocking exactly once per session (right after its
//! handshake — the hot path never flips modes again) and registered
//! level-triggered for read readiness, so [`TcpPlane::gather`] and
//! [`TcpPlane::wait_input`] park in `epoll`/`poll(2)` until bytes
//! actually arrive instead of napping on sleep slices. `gather_wait_time`
//! therefore measures *true block time*, and every wait return ticks
//! `reactor_wakeups` (under `io = "poll"`, the legacy A/B baseline, every
//! sleep slice ticks it instead — the plane's 200 µs gather naps here,
//! plus the scheduler's legacy spin slices via
//! [`PlaneIo::note_idle_wait`](super::transport::PlaneIo::note_idle_wait)
//! — and the benches assert the reactor strictly beats that).
//!
//! On the write side nothing calls `write_all` on the hot path. Every
//! outbound frame — dataset block, snapshot, delta, job — is *enqueued*
//! on the peer's pending-write queue and drained by vectored writes
//! (`writev` over up to [`MAX_WRITE_IOVECS`] queued frames per call,
//! counted in `writev_batches`). A partial write leaves the tail queued;
//! the peer's fd gains write-readiness interest until its queue drains,
//! so a tiny send buffer degrades to more batches, never a stall. Frame
//! buffers are pooled: dataset blocks and retired wave frames return
//! their `Vec`s to a per-plane scratch pool, and memoized snapshot
//! frames are shared by `Arc` — steady-state waves stop allocating.
//! Stats for a frame (wire/dataset/delta bytes, snapshot-fallback
//! counts) are applied when its **last byte** reaches the kernel, so a
//! frame abandoned with a dead session and resent through recovery is
//! never double-booked.
//!
//! ## Failure behaviour
//!
//! A peer-side *job* failure (panic, bad geometry, undecodable payload)
//! surfaces as an error reply; the wave is still fully drained before its
//! gather reports the first error and the plane stays usable — same
//! contract as [`super::engine::WorkerPool`].
//!
//! A *dead session* (process killed, connection dropped, desynced stream)
//! poisons only the waves that peer still owes, not the run: the master
//! keeps each scattered frame until its reply arrives, and on a broken
//! stream it makes a bounded number of reconnect attempts (up to
//! `reconnect_attempts`, spaced by a deterministic exponential backoff:
//! [`RECONNECT_BACKOFF_BASE`] doubling to [`RECONNECT_BACKOFF_CAP`]; the
//! mid-wave recovery path parks the backoff in the reactor so other
//! peers' replies keep draining while the timer runs) to the peer's
//! address — a remote `occd worker` replacement, or the persistent
//! loopback listener, which serves a fresh session from the same thread.
//! The replacement session is re-handshaken, re-shipped the dataset ranges
//! and snapshot its retained frames need, and handed every owed frame
//! again, in order — jobs are deterministic, so the waves complete
//! bit-exactly as if nothing happened. If the bound is exhausted, every
//! owed reply becomes a typed error on its wave (never a deadlock — the
//! wave is drained, the gather reports it, and the next scatter tries the
//! address again). `Drop` drains outstanding replies, sends shutdown
//! frames, closes every socket, wakes the persistent listeners and joins
//! the peer threads — infallibly.

use super::engine::{panic_message, run_job_with, Job, JobOutput};
use super::reactor::Reactor;
use super::transport::{SharedStats, Topology, TransportStats, WaveId};
use super::wire::{self, Hello, HelloAck, PeerRole};
use crate::config::{IoKind, StoreKind};
use crate::data::store::{DataView, PeerStore, BLOCK_POINTS};
use crate::data::{DataCell, Dataset};
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::ComputeBackend;
use std::collections::{HashMap, VecDeque};
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[cfg(unix)]
use std::os::unix::io::AsRawFd;

/// First delay of the deterministic exponential reconnect backoff; it
/// doubles per attempt (no jitter — identical schedules across runs) up
/// to [`RECONNECT_BACKOFF_CAP`].
pub const RECONNECT_BACKOFF_BASE: Duration = Duration::from_millis(125);

/// Ceiling of the exponential reconnect backoff.
pub const RECONNECT_BACKOFF_CAP: Duration = Duration::from_millis(1000);

/// Most queued frames one vectored write submits. Each flush call that
/// reaches the kernel counts once in `writev_batches`.
pub const MAX_WRITE_IOVECS: usize = 64;

/// Safety-net cap on any single reactor wait: the lost-wakeup
/// discipline (pump, then wait, then pump again) means a missed edge
/// costs at most one of these slices, never a hang.
const WAIT_CAP: Duration = Duration::from_millis(50);

/// Legacy `io = "poll"` sleep slice (the A/B baseline the reactor is
/// measured against).
const POLL_NAP: Duration = Duration::from_micros(200);

/// Scratch-buffer pool cap per plane: beyond this, drained frame
/// buffers are dropped instead of retained.
const FRAME_POOL_CAP: usize = 64;

/// Delay before reconnect attempt `attempt + 1`: 125 ms, 250, 500,
/// then 1 s flat. The first attempt (index 0) waits nothing.
fn backoff_delay(attempt: usize) -> Duration {
    RECONNECT_BACKOFF_BASE
        .saturating_mul(1u32 << attempt.min(3))
        .min(RECONNECT_BACKOFF_CAP)
}

/// Handshake ack read timeout: a connect can succeed against a listener
/// backlog whose accept loop is gone (a genuinely dead loopback thread),
/// and without a bound the master would block forever on the ack.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(20);

/// Whole-drain deadline for the teardown owed-reply drain
/// ([`TcpPlane::drain_owed`]): one budget shared across *all* peers, so a
/// plane with several wedged sessions still tears down in bounded time
/// (the old shape spent a fresh 10 s read timeout per peer).
pub const DRAIN_DEADLINE: Duration = Duration::from_secs(2);

/// Park a reconnect/connect backoff delay. Under `io = "reactor"` the
/// delay is spent in [`Reactor::wait_until`] — the fd set stays armed, so
/// readiness edges and cross-thread wakeups coalesce into the park
/// instead of being missed behind a hard sleep. Under `io = "poll"`
/// there is no readiness source and the legacy sleep is the park.
fn park_backoff(reactor: &mut Option<Reactor>, delay: Duration) {
    match reactor.as_mut() {
        Some(r) => {
            let _ = r.wait_until(Instant::now() + delay);
        }
        None => std::thread::sleep(delay), // poll-mode: no readiness source
    }
}

/// Points per dataset-block frame: bounds any single frame to
/// `16384 · d · 4` payload bytes (256 MiB at the `dim ≤ 4096` config cap),
/// comfortably under [`wire::MAX_FRAME`].
pub const DATA_BLOCK_POINTS: usize = 16_384;

/// Re-exported from [`crate::data::store`] (where it moved alongside the
/// block store it gates): the disjoint sorted range set tracking which
/// parts of the dataset a peer has been shipped (master side) or has
/// installed (peer side).
pub use crate::data::store::Coverage;

// ---------------------------------------------------------------------------
// Peer side: the serve loop behind `occd worker` and loopback threads
// ---------------------------------------------------------------------------

/// Cumulative times any worker session served by this process came back
/// from its readiness park ([`worker_reactor_wakeups`]).
static WORKER_WAKEUPS: AtomicU64 = AtomicU64::new(0);

/// Times the [`serve_peer`] readiness loops of this process woke from
/// their reactor park (or, with no reactor, their legacy poll slice) —
/// the worker-side counterpart of the master's `reactor_wakeups` stat.
/// Process-wide and monotone; tests diff it around a wave.
pub fn worker_reactor_wakeups() -> u64 {
    WORKER_WAKEUPS.load(Ordering::Relaxed)
}

/// Serve one master session on an accepted connection: a [`wire::Hello`]
/// handshake, then dataset blocks, snapshots and jobs in the master's
/// order until a shutdown frame or EOF. This is the single peer loop
/// behind standalone `occd worker` processes *and* the loopback thread
/// peers — one code path, so every in-process TCP test exercises the real
/// multi-host protocol.
///
/// After the (blocking, [`HANDSHAKE_TIMEOUT`]-bounded) handshake the
/// socket turns nonblocking for the rest of the session and the loop
/// parks in its own [`Reactor`]: frames are popped off an incremental
/// [`wire::poll_frame`] buffer, empty reads park in [`Reactor::wait`]
/// until bytes arrive, and reply writes that hit a full send buffer park
/// under write-readiness interest instead of busy-spinning. Every park
/// return ticks the process-wide [`worker_reactor_wakeups`] counter. If
/// the reactor cannot be built (fd exhaustion), the socket stays blocking
/// and the kernel itself is the park — same protocol, no readiness
/// metering.
///
/// Failure containment: a job that decodes but cannot run (panic, bad
/// geometry), a job whose payload fails decode validation, and a job whose
/// data range was never shipped each produce an error *reply* — the frame
/// boundary is intact, the master counts one reply per delivered job, and
/// the session stays alive. Only a broken stream (EOF, framing lost)
/// terminates the session; that returns `Ok` because it is how masters
/// normally leave.
pub fn serve_peer(stream: TcpStream, backend: Arc<dyn ComputeBackend>) -> Result<()> {
    serve_peer_with(stream, backend, StoreKind::from_env())
}

/// [`serve_peer`] with an explicit peer-side [`StoreKind`] — which
/// structure the session assembles its shipped blocks into: the
/// offset-keyed sparse [`crate::data::store::BlockStore`] (default) or
/// the dense `n × d` matrix baseline. Loopback planes pass their
/// topology's knob; standalone `occd worker` processes resolve it from
/// `--store` / `OCCML_STORE` through the plain wrapper.
pub fn serve_peer_with(
    stream: TcpStream,
    backend: Arc<dyn ComputeBackend>,
    store_kind: StoreKind,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut stream = stream;
    // Handshake: the first frame must be a Hello carrying this peer's shard
    // assignment and the dataset geometry. It is read version-tolerantly so
    // a coordinator built at a different wire version gets a reportable
    // rejection ack instead of a silent hangup — and bounded: a master
    // that connects and then wedges must not pin this thread forever.
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let handshake = wire::read_frame_any_version(&mut stream);
    let _ = stream.set_read_timeout(None);
    let (version, kind, payload) = handshake?;
    if version != wire::VERSION {
        let ack = HelloAck {
            proto: wire::VERSION,
            ok: false,
            message: format!("peer speaks wire version {}, got {version}", wire::VERSION),
        };
        // The rejection ack is the master's only clue why the session died;
        // if it cannot be written, say so in the error instead of dropping
        // the failure on the floor.
        let ack_write = match wire::hello_ack_frame(&ack) {
            Ok(f) => stream.write_all(&f).map_err(|e| e.to_string()),
            Err(e) => Err(e.to_string()),
        };
        let detail = match ack_write {
            Ok(()) => String::new(),
            Err(e) => format!(" (rejection ack not delivered: {e})"),
        };
        return Err(Error::Coordinator(format!(
            "coordinator speaks wire version {version}, this peer speaks {}{detail}",
            wire::VERSION
        )));
    }
    if kind != wire::KIND_HELLO {
        return Err(Error::Coordinator(format!(
            "peer expected a hello frame, got kind {kind}"
        )));
    }
    let hello = match wire::decode_hello(&payload) {
        Ok(h) => h,
        Err(e) => {
            // Tell the master why (version mismatch, corrupt hello) before
            // giving up on the session.
            let ack =
                HelloAck { proto: wire::VERSION, ok: false, message: e.to_string() };
            let ack_write = match wire::hello_ack_frame(&ack) {
                Ok(f) => stream.write_all(&f).map_err(|err| err.to_string()),
                Err(err) => Err(err.to_string()),
            };
            return Err(match ack_write {
                Ok(()) => e,
                Err(w) => Error::Coordinator(format!(
                    "{e} (rejection ack not delivered: {w})"
                )),
            });
        }
    };
    let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
    stream.write_all(&wire::hello_ack_frame(&ack)?)?;

    // Local dataset store, assembled from shipped blocks. Nothing is
    // allocated until the first block arrives: validator peers never
    // receive one and so never pay a byte. Reads are coverage-gated by
    // the store itself — a row no install ever wrote (and its norm) is
    // structurally unreadable on either store variant.
    let mut store = PeerStore::new(store_kind);
    let mut data_err: Option<String> = None;
    // The session's single-entry snapshot cache: the `(id, matrix)` the
    // master last installed, which snapshot-referencing jobs resolve
    // against and delta frames re-base. A failed install is remembered and
    // surfaced on the next job that references a snapshot — the frame
    // boundary stays intact either way.
    let mut snap: Option<(u64, Arc<Matrix>)> = None;
    let mut snap_err: Option<String> = None;
    // Per-center norm cache keyed to the session snapshot: rebuilt whole on
    // a full snapshot frame, extended by the appended rows on a delta, and
    // handed to `Nearest` jobs whose centers resolved against the cached
    // matrix (reference-shipped jobs). Inline-matrix jobs get `None` and
    // the kernel computes center norms per call.
    let mut cnorms = crate::linalg::panel::NormCache::new();
    let empty = Dataset::new(Matrix::zeros(0, 0), None);

    // The session's readiness loop: nonblocking from here on, parked in
    // its own reactor. A failed nonblocking switch or reactor build falls
    // back to the blocking shape (reads park in the kernel instead).
    let mut reactor = Reactor::new().ok();
    if reactor.is_some() && stream.set_nonblocking(true).is_err() {
        reactor = None;
    }
    if let Some(r) = reactor.as_mut() {
        let _ = r.register(stream_fd(&stream));
    }
    let mut inbuf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 64 * 1024];

    loop {
        // Parse-first: pop a buffered frame before touching the socket.
        let next = match wire::poll_frame(&mut inbuf) {
            Ok(Some(f)) => Some(f),
            Ok(None) => None,
            Err(_) => return Ok(()), // framing lost
        };
        let Some((kind, payload)) = next else {
            match (&stream).read(&mut tmp) {
                Ok(0) => return Ok(()), // master gone (EOF)
                Ok(k) => inbuf.extend_from_slice(&tmp[..k]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    match reactor.as_mut() {
                        Some(r) => {
                            let _ = r.wait(WAIT_CAP);
                        }
                        // A blocking socket never reaches here; park one
                        // slice if an OS returns it spuriously anyway.
                        None => std::thread::sleep(POLL_NAP), // poll-mode
                    }
                    WORKER_WAKEUPS.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => return Ok(()), // stream dead
            }
            continue;
        };
        match kind {
            wire::KIND_DATA => {
                if let Err(e) = install_block(&hello, &payload, &mut store) {
                    // The frame boundary is intact; remember the failure and
                    // surface it on the next job that needs the data.
                    data_err = Some(e.to_string());
                }
            }
            wire::KIND_SNAPSHOT => match wire::decode_snapshot(&payload) {
                Ok((id, m)) => {
                    cnorms.rebuild(&m);
                    snap = Some((id, Arc::new(m)));
                    snap_err = None;
                }
                Err(e) => snap_err = Some(e.to_string()),
            },
            wire::KIND_SNAPSHOT_DELTA => {
                let applied = wire::decode_snapshot_delta(&payload).and_then(|d| {
                    let (held, base) = snap.as_ref().ok_or_else(|| {
                        Error::Coordinator(
                            "snapshot delta arrived with no cached base".into(),
                        )
                    })?;
                    Ok((d.id, d.apply(*held, base)?))
                });
                match applied {
                    Ok((id, m)) => {
                        cnorms.extend_to(&m);
                        snap = Some((id, Arc::new(m)));
                        snap_err = None;
                    }
                    Err(e) => snap_err = Some(e.to_string()),
                }
            }
            wire::KIND_JOB => {
                let job = wire::decode_job_snap(&payload, snap.as_ref()).map_err(|e| {
                    // A reference that cannot resolve is most useful with
                    // the install failure that caused it attached.
                    match &snap_err {
                        Some(se) => Error::Coordinator(format!(
                            "{e}; last snapshot frame failed: {se}"
                        )),
                        None => e,
                    }
                });
                let start = Instant::now();
                let output = match job {
                    Ok(Job::Shutdown) => return Ok(()),
                    Ok(job) => run_covered(&job.data_range(), &data_err, &store)
                        .and_then(|view| {
                            let view = view.unwrap_or(DataView::Dense(&empty));
                            // The session norm cache applies exactly when the
                            // job's centers ARE the cached snapshot matrix.
                            let norms: Option<&[f32]> = match (&job, &snap) {
                                (Job::Nearest { centers, .. }, Some((_, held)))
                                    if Arc::ptr_eq(centers, held) =>
                                {
                                    Some(cnorms.norms())
                                }
                                _ => None,
                            };
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                run_job_with(view, &backend, job, norms)
                            }))
                            .unwrap_or_else(|p| Err(Error::Coordinator(panic_message(&*p))))
                        }),
                    Err(e) => Err(e), // decode-invalid job: reply, stay alive
                };
                let busy = start.elapsed();
                let sent = wire::reply_frame(hello.peer_id, busy, &output)
                    .map_err(|_| ())
                    .and_then(|f| {
                        write_session_reply(&stream, &mut reactor, &f).map_err(|_| ())
                    });
                if sent.is_err() {
                    return Ok(()); // master gone
                }
            }
            other => {
                // An unexpected frame kind mid-session means the streams
                // are not speaking the same dialogue; bail out rather than
                // risk a desynchronized reply pairing.
                return Err(Error::Coordinator(format!(
                    "peer got unexpected frame kind {other} mid-session"
                )));
            }
        }
    }
}

/// Write one encoded reply frame on the session's (usually nonblocking)
/// stream: partial writes continue from their offset, and a full send
/// buffer parks under write-readiness interest in the session's reactor
/// instead of busy-spinning. `Err` means the stream is dead.
fn write_session_reply(
    stream: &TcpStream,
    reactor: &mut Option<Reactor>,
    bytes: &[u8],
) -> std::io::Result<()> {
    let mut at = 0;
    let mut armed = false;
    let res = loop {
        if at == bytes.len() {
            break Ok(());
        }
        match (&*stream).write(&bytes[at..]) {
            Ok(0) => {
                break Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "tcp write accepted 0 bytes of a reply",
                ))
            }
            Ok(k) => at += k,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                match reactor.as_mut() {
                    Some(r) => {
                        if !armed {
                            let _ = r.set_write_interest(stream_fd(stream), true);
                            armed = true;
                        }
                        let _ = r.wait(WAIT_CAP);
                    }
                    // A blocking socket never reaches here; park one
                    // slice if an OS returns it spuriously anyway.
                    None => std::thread::sleep(POLL_NAP), // poll-mode
                }
                WORKER_WAKEUPS.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => break Err(e),
        }
    };
    if armed {
        if let Some(r) = reactor.as_mut() {
            let _ = r.set_write_interest(stream_fd(stream), false);
        }
    }
    res
}

/// Check a job's data needs against the peer's store; returns the
/// coverage-gated view to run against (`None` for jobs that read no
/// points).
fn run_covered<'a>(
    need: &Option<Range<usize>>,
    data_err: &Option<String>,
    store: &'a PeerStore,
) -> Result<Option<DataView<'a>>> {
    let Some(range) = need else { return Ok(None) };
    if range.start >= range.end {
        return Ok(None); // an empty block reads no points (tail epochs)
    }
    if let Some(e) = data_err {
        return Err(Error::Coordinator(format!("dataset block error: {e}")));
    }
    store.view(need)
}

/// Decode and geometry-check one dataset-block frame, then install it
/// into the peer's store (dense matrix or sparse block store — the
/// session's knob decides; coverage advances either way).
fn install_block(hello: &Hello, payload: &[u8], store: &mut PeerStore) -> Result<()> {
    let (offset, block) = wire::decode_data_block(payload)?;
    let n = hello.n as usize;
    let d = hello.dim as usize;
    let end = offset
        .checked_add(block.rows)
        .ok_or_else(|| Error::Coordinator("dataset block offset overflow".into()))?;
    if block.cols != d {
        return Err(Error::Coordinator(format!(
            "dataset block {offset}..{end} ({} cols) outside the {n} x {d} geometry",
            block.cols
        )));
    }
    // Streaming ingest (`occd serve`) grows the master's dataset past the
    // `n` this session handshook with, so blocks beyond it are legal: the
    // store grows to cover them. The same plausibility cap as `.occb`
    // loading applies to the *grown* geometry.
    let rows = n.max(end);
    if rows.checked_mul(d).is_none() || rows * d > (1 << 33) {
        return Err(Error::Coordinator(format!("implausible dataset geometry {rows} x {d}")));
    }
    store.install(n, d, offset, &block);
    Ok(())
}

// ---------------------------------------------------------------------------
// Master side
// ---------------------------------------------------------------------------

/// The master's handle on one peer.
struct Peer {
    /// Live session stream, if any.
    stream: Option<TcpStream>,
    /// Address reconnects target: the remote `host:port`, or the
    /// persistent loopback listener this plane spawned for the peer.
    addr: String,
    /// True for loopback thread peers (display only; recovery is uniform).
    loopback: bool,
    /// The handshake this peer's sessions are opened with.
    hello: Hello,
    /// Dataset ranges shipped in the current session.
    sent: Coverage,
    /// The snapshot `(id, matrix)` the current session holds — the master's
    /// mirror of the peer's single-entry snapshot cache, which is what
    /// makes delta shipping sound: a delta is only sent against a base the
    /// master itself installed. Because frames are written (and processed
    /// peer-side) strictly in order, the mirror stays correct with any
    /// number of waves in flight. Cleared with every handshake (a
    /// replacement session starts empty and is re-based from a full
    /// frame).
    snap: Option<(u64, Arc<Matrix>)>,
    /// Pending-write queue: frames enqueued but not yet fully handed to
    /// the kernel, drained front-first by vectored writes. Dies with
    /// the session (recovery resends from the waves' retained frames).
    outq: VecDeque<PendingFrame>,
}

/// The bytes of one queued outbound frame.
enum FrameBytes {
    /// Transient frame (dataset block): its buffer returns to the
    /// plane's scratch pool once drained.
    Owned(Vec<u8>),
    /// Retained or memoized frame (wave job, snapshot, delta): shared
    /// with the wave's resend copy or the scatter memo — zero-copy.
    Shared(Arc<Vec<u8>>),
}

impl FrameBytes {
    fn as_slice(&self) -> &[u8] {
        match self {
            FrameBytes::Owned(b) => b,
            FrameBytes::Shared(b) => b,
        }
    }
}

/// Deferred per-frame accounting, applied when the frame's last byte
/// reaches the kernel. A frame abandoned with its dead session (and
/// resent through recovery on a fresh one) is therefore never
/// double-booked — which keeps the strict `full_snapshot_fallbacks`
/// equalities the tests assert exact.
#[derive(Default)]
struct FrameAcct {
    /// `wire_bytes` share (job and snapshot/delta frames).
    wire: u64,
    /// `total_bytes` share (dataset blocks; handshakes account inline).
    bytes: u64,
    /// Dataset payload bytes.
    dataset: u64,
    /// Snapshot-delta payload bytes.
    delta: u64,
    /// This frame is a full-snapshot re-base.
    full_fallback: bool,
}

impl FrameAcct {
    fn apply(&self, stats: &SharedStats) {
        if self.wire > 0 {
            stats.add_wire(self.wire);
        }
        if self.bytes > 0 {
            stats.add_bytes(self.bytes);
        }
        if self.dataset > 0 {
            stats.add_dataset(self.dataset);
        }
        if self.delta > 0 {
            stats.add_delta(self.delta);
        }
        if self.full_fallback {
            stats.add_full_snapshot_fallback();
        }
    }
}

/// One frame on a peer's pending-write queue.
struct PendingFrame {
    bytes: FrameBytes,
    /// Bytes of this frame already written to the kernel.
    sent: usize,
    acct: FrameAcct,
}

fn enqueue_frame(peer: &mut Peer, bytes: FrameBytes, acct: FrameAcct) {
    peer.outq.push_back(PendingFrame { bytes, sent: 0, acct });
}

/// Return a drained frame buffer to the scratch pool (bounded).
fn recycle(pool: &mut Vec<Vec<u8>>, buf: Vec<u8>) {
    if pool.len() < FRAME_POOL_CAP {
        pool.push(buf);
    }
}

#[cfg(unix)]
fn stream_fd(s: &TcpStream) -> i32 {
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn stream_fd(_s: &TcpStream) -> i32 {
    0
}

/// Drop a peer's session: deregister its fd from the reactor *before*
/// the socket closes (a recycled fd number must never alias a stale
/// registration), close the stream, and discard its pending writes
/// (recovery resends from the waves' retained frames).
fn drop_stream(reactor: &mut Option<Reactor>, peer: &mut Peer) {
    if let (Some(r), Some(s)) = (reactor.as_mut(), &peer.stream) {
        r.deregister(stream_fd(s));
    }
    peer.stream = None;
    peer.outq.clear();
}

/// Track write-readiness interest against queue emptiness. Best-effort:
/// a failed `epoll_ctl` only costs the bounded safety-net timeout.
fn sync_write_interest(reactor: &mut Option<Reactor>, peer: &Peer, on: bool) {
    if let (Some(r), Some(s)) = (reactor.as_mut(), &peer.stream) {
        let _ = r.set_write_interest(stream_fd(s), on);
    }
}

/// Push a peer's pending writes as far as the kernel allows, as
/// vectored batches. `Ok(true)` = queue drained; `Ok(false)` = the
/// kernel refused more (`WouldBlock`) with bytes still queued; `Err` =
/// the session is dead and the caller recovers. Per-frame stats apply
/// as each frame's last byte leaves.
fn flush_peer(shared: &TcpShared, peer: &mut Peer, pool: &mut Vec<Vec<u8>>) -> Result<bool> {
    loop {
        if peer.outq.is_empty() {
            return Ok(true);
        }
        let wrote = {
            let Peer { outq, stream, .. } = &mut *peer;
            let stream = stream
                .as_mut()
                .ok_or_else(|| Error::Coordinator("peer has no live session".into()))?;
            let mut iov: Vec<IoSlice<'_>> = Vec::with_capacity(outq.len().min(MAX_WRITE_IOVECS));
            for f in outq.iter().take(MAX_WRITE_IOVECS) {
                iov.push(IoSlice::new(&f.bytes.as_slice()[f.sent..]));
            }
            stream.write_vectored(&iov)
        };
        match wrote {
            Ok(0) => {
                return Err(Error::Coordinator(
                    "tcp write accepted 0 bytes of a queued frame".into(),
                ))
            }
            Ok(mut n) => {
                shared.stats.add_writev_batch();
                while n > 0 {
                    let front = peer.outq.front_mut().expect("drained bytes came from a frame");
                    let left = front.bytes.as_slice().len() - front.sent;
                    if n < left {
                        front.sent += n;
                        break;
                    }
                    n -= left;
                    let done = peer.outq.pop_front().expect("front exists");
                    done.acct.apply(&shared.stats);
                    if let FrameBytes::Owned(buf) = done.bytes {
                        recycle(pool, buf);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Coordinator(format!("tcp write: {e}"))),
        }
    }
}

#[cfg(test)]
thread_local! {
    /// Blocking-mode switches on this thread — exactly one per session
    /// open. The hot path (pump / flush / gather) must never add one;
    /// `sockets_stay_nonblocking_without_hot_path_mode_flips` asserts it.
    static MODE_FLIPS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

#[cfg(test)]
fn mode_flips() -> u64 {
    MODE_FLIPS.with(|c| c.get())
}

/// One-time I/O setup for a freshly handshaken session: switch the
/// socket nonblocking — permanently; the hot path never toggles modes —
/// and register it with the reactor. Registration is an optimization: a
/// failure degrades to the safety-net timeout, never to wrong results.
fn finish_session_open(reactor: &mut Option<Reactor>, peer: &mut Peer) -> Result<()> {
    let stream = peer.stream.as_ref().expect("handshaken session has a stream");
    #[cfg(test)]
    MODE_FLIPS.with(|c| c.set(c.get() + 1));
    stream
        .set_nonblocking(true)
        .map_err(|e| Error::Coordinator(format!("tcp nonblocking: {e}")))?;
    if let Some(r) = reactor.as_mut() {
        let _ = r.register(stream_fd(stream));
    }
    Ok(())
}

impl Peer {
    fn describe(&self) -> String {
        let pre = if self.loopback { "loopback " } else { "" };
        format!("{pre}{} peer {} ({})", self.hello.role.name(), self.hello.peer_id, self.addr)
    }
}

/// One retained scattered job: the encoded frame (kept for resend after a
/// reconnect; `Arc`-shared with the pending-write queue so enqueueing
/// copies nothing), the dataset range it reads, and the snapshot its
/// frame references (kept so a replacement session can be re-based — by
/// a full frame — before the retained frame is resent).
struct WaveJob {
    frame: Arc<Vec<u8>>,
    need: Option<Range<usize>>,
    snap: Option<(u64, Arc<Matrix>)>,
}

/// One outstanding wave in the plane's pending set.
struct TcpWave {
    seq: WaveId,
    /// Retained per-peer jobs, for recovery resends.
    jobs: Vec<WaveJob>,
    outputs: Vec<Option<JobOutput>>,
    /// Replies (or typed failures) still owed before the wave is drained.
    remaining: usize,
    max_busy: Duration,
    err: Option<Error>,
}

/// How one wave's snapshot relates to a peer's cached base — computed once
/// per `(snapshot, base)` pair per wave and memoized, since every peer of a
/// plane usually shares the same cache state.
#[derive(Clone, Copy, PartialEq, Eq)]
enum SnapRelation {
    /// Bit-identical content: nothing to ship, jobs reference the held id.
    Identical,
    /// The base is a bit-exact prefix: ship only the appended rows.
    Extends,
    /// Prefix rewritten (mean recompute), shrunk, or reshaped: full frame.
    Unrelated,
}

/// Per-scatter memo for snapshot shipping: one classification and one
/// encoding per distinct `(snapshot, base)` pair, spliced to every peer
/// that shares the state — the delta-era successor of the PR 3 splice
/// cache, so master-side encode effort stays `O(snapshot)`, not
/// `O(P · snapshot)`.
#[derive(Default)]
struct SnapMemo {
    /// Wave-assigned snapshot id per distinct `Arc` allocation.
    ids: HashMap<usize, u64>,
    /// `(snapshot ptr, base id)` → relation.
    relations: HashMap<(usize, u64), SnapRelation>,
    /// `(snapshot id)` → encoded full frame, `Arc`-shared with every
    /// pending-write queue that ships it.
    fulls: HashMap<u64, Arc<Vec<u8>>>,
    /// `(snapshot id, base id)` → encoded delta frame, likewise shared.
    deltas: HashMap<(u64, u64), Arc<Vec<u8>>>,
}

/// The snapshot matrix a job embeds, if any: the epoch state that frugal
/// shipping moves as delta frames instead of embedding per job. `PairCache`
/// vectors are deliberately *not* treated as snapshots — a fresh proposal
/// matrix every epoch has no delta to exploit; its wire diet is the row
/// subset built by [`super::transport::ValidatePlane::pair_cache`].
fn job_snapshot(job: &Job) -> Option<&Arc<Matrix>> {
    match job {
        Job::Nearest { centers, .. } => Some(centers),
        Job::BpDescend { features, .. } => Some(features),
        _ => None,
    }
}

/// Classify how `new` relates to the `base` a peer holds, bit-exactly.
fn snap_relation(base: &Matrix, new: &Matrix) -> SnapRelation {
    if base.cols != new.cols && base.rows > 0 && new.rows > 0 {
        return SnapRelation::Unrelated;
    }
    if base.rows > new.rows {
        return SnapRelation::Unrelated;
    }
    // f32 slices compare by bits here: the matrices were built from
    // identical computations, so any difference shows up in the bytes the
    // wire would carry. NaN payloads never arise in committed state, and a
    // NaN != NaN miscompare would only cost an unnecessary full ship — it
    // can never produce a wrong delta.
    if base.data[..] != new.data[..base.rows * base.cols] {
        return SnapRelation::Unrelated;
    }
    if base.rows == new.rows {
        SnapRelation::Identical
    } else {
        SnapRelation::Extends
    }
}

/// Everything a plane's master-side helpers need besides the peer itself:
/// the dataset (for block shipping), the knobs, the snapshot-id source and
/// the cluster-wide accounting. Shared by both planes through an `Arc`, so
/// the compute plane (event loop) and the validation plane (validation
/// thread) account into the same [`SharedStats`].
struct TcpShared {
    /// The dataset behind a swappable cell: static runs set it once;
    /// `occd serve` grows it between mini-epochs (each ship takes one
    /// immutable generation snapshot, so in-flight waves stay bit-stable).
    data: Arc<DataCell>,
    reconnect_attempts: usize,
    /// Snapshot delta-shipping + validator row-subset shipping (default);
    /// `false` restores the PR 3 embed-everything wire shape for A/B runs.
    frugal: bool,
    /// Monotone snapshot-id source (ids are never reused, so a stale
    /// reference can only miss, never alias).
    next_snap_id: AtomicU64,
    /// Which structure peer sessions assemble shipped blocks into —
    /// decides the resident-footprint model the master accounts under
    /// `resident_data_bytes` (and is what loopback planes hand to
    /// [`serve_peer_with`]).
    store: StoreKind,
    stats: Arc<SharedStats>,
}

/// Open one peer's session: write the hello, await the ack (bounded by
/// [`HANDSHAKE_TIMEOUT`] — a backlog connect with no accept loop behind it
/// must fail, not hang), reset the shipped coverage and snapshot mirrors.
/// Returns `(wire bytes, handshake wall-clock)`.
fn do_handshake(peer: &mut Peer) -> Result<(usize, Duration)> {
    let sw = Instant::now();
    let frame = wire::hello_frame(&peer.hello)?;
    let stream = peer
        .stream
        .as_mut()
        .ok_or_else(|| Error::Coordinator("handshake needs a live stream".into()))?;
    stream
        .write_all(&frame)
        .map_err(|e| Error::Coordinator(format!("tcp hello: {e}")))?;
    stream
        .flush()
        .map_err(|e| Error::Coordinator(format!("tcp hello flush: {e}")))?;
    let mut bytes = frame.len();
    // Version-tolerant read: a peer built at a different wire version acks
    // with *its* frame version, and we still want to decode and report it
    // (the ack payload layout is the frozen negotiation anchor).
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let read = wire::read_frame_any_version(stream);
    let _ = stream.set_read_timeout(None);
    let (_version, kind, payload) = read?;
    bytes += wire::HEADER_LEN + payload.len();
    let ack = wire::decode_hello_ack(kind, &payload)?;
    if !ack.ok {
        return Err(Error::Coordinator(format!(
            "{} rejected the session (peer wire version {}): {}",
            peer.describe(),
            ack.proto,
            ack.message
        )));
    }
    if ack.proto != wire::VERSION {
        return Err(Error::Coordinator(format!(
            "{} speaks wire version {}, expected {}",
            peer.describe(),
            ack.proto,
            wire::VERSION
        )));
    }
    peer.sent.clear(); // fresh session: the peer holds no data yet
    peer.snap = None; // ... and no snapshot — the next ship re-bases in full
    Ok((bytes, sw.elapsed()))
}

/// One fresh-session attempt: connect, handshake (on the still-blocking
/// socket), account the cost, then switch the session into its
/// permanent nonblocking + reactor-registered state. The peer's stream
/// is `None` on failure.
fn open_session(shared: &TcpShared, reactor: &mut Option<Reactor>, peer: &mut Peer) -> Result<()> {
    drop_stream(reactor, peer);
    let stream = TcpStream::connect(&peer.addr)
        .map_err(|e| Error::Coordinator(format!("tcp connect {}: {e}", peer.addr)))?;
    stream.set_nodelay(true).ok();
    peer.stream = Some(stream);
    let opened = do_handshake(peer).and_then(|(bytes, took)| {
        shared.stats.add_bytes(bytes as u64);
        shared.stats.add_handshake(took);
        finish_session_open(reactor, peer)
    });
    if let Err(e) = opened {
        peer.stream = None;
        return Err(e);
    }
    Ok(())
}

/// Re-open a dead peer's session under the bounded reconnect policy
/// (deterministic exponential backoff between attempts, parked in the
/// reactor under `io = "reactor"` — never a hard sleep on the
/// coordinator thread).
fn reconnect(shared: &TcpShared, reactor: &mut Option<Reactor>, peer: &mut Peer) -> Result<()> {
    drop_stream(reactor, peer);
    let mut last: Option<Error> = None;
    for attempt in 0..shared.reconnect_attempts {
        if attempt > 0 {
            park_backoff(reactor, backoff_delay(attempt - 1));
        }
        match open_session(shared, reactor, peer) {
            Ok(()) => return Ok(()),
            Err(e) => last = Some(e),
        }
    }
    Err(Error::Coordinator(format!(
        "{} unreachable after {} reconnect attempts: {}",
        peer.describe(),
        shared.reconnect_attempts,
        last.map(|e| e.to_string()).unwrap_or_else(|| "reconnect disabled".into())
    )))
}

/// Queue the sub-ranges of `need` this peer's session has not seen, in
/// bounded block frames encoded straight from the dataset into pooled
/// buffers (no intermediate matrix copy). Shipped-coverage advances at
/// enqueue: frames drain in order, and a dead session's replacement
/// clears the coverage at handshake anyway.
fn ship_missing(
    shared: &TcpShared,
    peer: &mut Peer,
    need: &Range<usize>,
    pool: &mut Vec<Vec<u8>>,
) -> Result<()> {
    // One generation snapshot per ship: `occd serve` may publish a grown
    // generation concurrently, but this frame encodes from exactly one.
    let data = shared.data.get();
    for span in peer.sent.missing(need) {
        let d = data.dim();
        let mut lo = span.start;
        while lo < span.end {
            let hi = (lo + DATA_BLOCK_POINTS).min(span.end);
            let sw = Instant::now();
            let mut buf = pool.pop().unwrap_or_default();
            buf.clear();
            wire::data_rows_frame_into(
                &mut buf,
                lo,
                hi - lo,
                d,
                &data.points.data[lo * d..hi * d],
            )?;
            shared.stats.add_ser(sw.elapsed());
            let acct = FrameAcct {
                bytes: buf.len() as u64,
                dataset: (buf.len() - wire::HEADER_LEN) as u64,
                ..FrameAcct::default()
            };
            enqueue_frame(peer, FrameBytes::Owned(buf), acct);
            lo = hi;
        }
        peer.sent.add(span);
    }
    // Account the peer's resident dataset footprint from the coverage just
    // shipped — the master-side model of what the session's store holds.
    // Dense sessions allocate the full handshook geometry (grown if blocks
    // landed past it); sparse sessions pay only for panel-aligned blocks
    // that coverage touches. Peak across peers, kept as a gauge.
    if !peer.sent.is_empty() {
        let d = data.dim();
        let bytes = match shared.store {
            StoreKind::Dense => (peer.hello.n as usize).max(peer.sent.max_end()) * d * 4,
            StoreKind::Sparse => peer.sent.aligned_blocks(BLOCK_POINTS) * BLOCK_POINTS * d * 4,
        };
        shared.stats.note_resident(bytes as u64);
    }
    Ok(())
}

/// Make the peer's session hold snapshot `id` (= `m`) before a frame
/// referencing it is written. Three outcomes, decided against the
/// master-side mirror of the peer's cache and memoized per wave:
///
/// * the session already holds `id` — nothing to ship (a resend, or a
///   speculative wave whose state did not change);
/// * the held snapshot is a bit-exact *prefix* of `m` — ship a
///   [`wire::SnapshotDelta`] carrying only the appended rows;
/// * anything else (cold cache after a handshake, rewritten prefix) —
///   ship a full [`wire::KIND_SNAPSHOT`] frame, counted in
///   `full_snapshot_fallbacks`.
///
/// The peer reconstructs bit-exactly by construction (raw f32 bit
/// patterns both ways). The mirror (`peer.snap`) advances at *enqueue*:
/// frames drain strictly in order, so the session will hold `id` before
/// any later frame that references it — and every write failure forces
/// a replacement session whose handshake clears the mirror again, so a
/// half-installed cache is never trusted. The install's stats (wire
/// bytes, delta bytes, fallback count) stay deferred until the frame
/// actually drains.
fn ensure_snapshot(
    shared: &TcpShared,
    peer: &mut Peer,
    id: u64,
    m: &Arc<Matrix>,
    memo: &mut SnapMemo,
) -> Result<()> {
    if let Some((held, _)) = &peer.snap {
        if *held == id {
            return Ok(());
        }
    }
    let key = Arc::as_ptr(m) as usize;
    let sw = Instant::now();
    // Delta-eligible base, if the held snapshot is a bit-exact prefix
    // of (or identical to) `m`. Identical content still re-installs
    // under the new id when the job frame references it: a zero-row
    // delta, header-sized on the wire.
    let rebase: Option<(u64, usize)> = match &peer.snap {
        Some((base_id, base)) => {
            let rel = *memo
                .relations
                .entry((key, *base_id))
                .or_insert_with(|| snap_relation(base, m));
            if rel == SnapRelation::Unrelated {
                None
            } else {
                Some((*base_id, base.rows))
            }
        }
        None => None,
    };
    // The memoized frame is `Arc`-shared, not cloned: the bytes encode
    // once per wave and every peer queues the same allocation, so
    // per-wave memcpy stays O(snapshot), not O(P · snapshot).
    let (frame, is_delta): (Arc<Vec<u8>>, bool) = match rebase {
        Some((base_id, base_rows)) => {
            let frame = match memo.deltas.entry((id, base_id)) {
                std::collections::hash_map::Entry::Occupied(e) => e.get().clone(),
                std::collections::hash_map::Entry::Vacant(e) => {
                    let d = m.cols;
                    let tail = Matrix {
                        rows: m.rows - base_rows,
                        cols: d,
                        data: m.data[base_rows * d..].to_vec(),
                    };
                    let delta = wire::SnapshotDelta { id, base_id, base_rows, tail };
                    let mut bytes = Vec::new();
                    wire::snapshot_delta_frame_into(&mut bytes, &delta)?;
                    shared.stats.add_unique(bytes.len() as u64);
                    e.insert(Arc::new(bytes)).clone()
                }
            };
            (frame, true)
        }
        None => {
            let frame = match memo.fulls.entry(id) {
                std::collections::hash_map::Entry::Occupied(e) => e.get().clone(),
                std::collections::hash_map::Entry::Vacant(e) => {
                    let mut bytes = Vec::new();
                    wire::snapshot_frame_into(&mut bytes, id, m)?;
                    shared.stats.add_unique(bytes.len() as u64);
                    e.insert(Arc::new(bytes)).clone()
                }
            };
            (frame, false)
        }
    };
    shared.stats.add_ser(sw.elapsed());
    // Accounting rides the frame and applies when it drains: a broken
    // session's undelivered install is retried (and re-counted) on a
    // fresh session, never double-booked — which keeps the strict
    // `full_snapshot_fallbacks` equalities the tests assert.
    let acct = FrameAcct {
        wire: frame.len() as u64,
        delta: if is_delta { (frame.len() - wire::HEADER_LEN) as u64 } else { 0 },
        full_fallback: !is_delta,
        ..FrameAcct::default()
    };
    enqueue_frame(peer, FrameBytes::Shared(frame), acct);
    peer.snap = Some((id, m.clone()));
    Ok(())
}

/// The snapshot id a peer's job frame should reference: the id its
/// session already holds when the content is bit-identical (no ship at
/// all — the speculative-wave fast path), otherwise this wave's id for
/// the matrix (allocated once per distinct `Arc` per wave).
fn snap_ref_id(shared: &TcpShared, peer: &Peer, m: &Arc<Matrix>, memo: &mut SnapMemo) -> u64 {
    let key = Arc::as_ptr(m) as usize;
    if let Some((held, base)) = &peer.snap {
        let rel = *memo
            .relations
            .entry((key, *held))
            .or_insert_with(|| snap_relation(base, m));
        if rel == SnapRelation::Identical {
            return *held;
        }
    }
    *memo
        .ids
        .entry(key)
        .or_insert_with(|| shared.next_snap_id.fetch_add(1, Ordering::Relaxed))
}

/// Queue a wave job's data needs, snapshot and frame, then push as much
/// as the kernel will take. Anything it refuses stays on the peer's
/// pending-write queue under write-readiness interest, drained by the
/// gather / readiness loops.
fn write_wave_job(
    shared: &TcpShared,
    reactor: &mut Option<Reactor>,
    peer: &mut Peer,
    wj: &WaveJob,
    memo: &mut SnapMemo,
    pool: &mut Vec<Vec<u8>>,
) -> Result<()> {
    if let Some(need) = &wj.need {
        ship_missing(shared, peer, need, pool)?;
    }
    if let Some((id, m)) = &wj.snap {
        ensure_snapshot(shared, peer, *id, m, memo)?;
    }
    enqueue_frame(
        peer,
        FrameBytes::Shared(wj.frame.clone()),
        FrameAcct { wire: wj.frame.len() as u64, ..FrameAcct::default() },
    );
    let drained = flush_peer(shared, peer, pool)?;
    sync_write_interest(reactor, peer, !drained);
    Ok(())
}

/// Deliver one wave job, reconnecting a dead peer (bounded) and retrying
/// the delivery once on a fresh session.
fn deliver(
    shared: &TcpShared,
    reactor: &mut Option<Reactor>,
    peer: &mut Peer,
    wj: &WaveJob,
    memo: &mut SnapMemo,
    pool: &mut Vec<Vec<u8>>,
) -> Result<()> {
    if peer.stream.is_none() {
        reconnect(shared, reactor, peer)?;
    }
    match write_wave_job(shared, reactor, peer, wj, memo, pool) {
        Ok(()) => Ok(()),
        Err(_) => {
            reconnect(shared, reactor, peer)?;
            write_wave_job(shared, reactor, peer, wj, memo, pool)
        }
    }
}

/// Connect with bounded retries — workers may come up slightly after the
/// coordinator, so the initial connect gets `1 + attempts` tries, spaced
/// by the same deterministic exponential backoff reconnects use (and
/// parked the same way: in the plane's reactor when one is armed).
fn connect_with_retry(
    addr: &str,
    attempts: usize,
    reactor: &mut Option<Reactor>,
) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..=attempts {
        if attempt > 0 {
            park_backoff(reactor, backoff_delay(attempt - 1));
        }
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(Error::Coordinator(format!(
        "peer {addr} unreachable after {} connect attempts: {}",
        attempts + 1,
        last.expect("at least one attempt")
    )))
}

/// One TCP peer plane: the master-side endpoint for either the compute
/// workers or the validator shards. Thread-confined (`Send`, not `Sync`);
/// the two planes of a cluster share only the [`TcpShared`] block.
pub struct TcpPlane {
    shared: Arc<TcpShared>,
    peers: Vec<Peer>,
    /// The plane's readiness queue under `io = "reactor"` — every live
    /// session's socket is registered, and every blocking wait on this
    /// plane lands in [`Reactor::wait`]. `None` under `io = "poll"`: the
    /// legacy sleep-slice loops, kept as the A/B baseline.
    reactor: Option<Reactor>,
    /// Recycled frame-encode buffers (bounded by [`FRAME_POOL_CAP`]):
    /// owned frames return their allocation here when fully written, so
    /// steady-state encoding stops allocating per wave.
    pool: Vec<Vec<u8>>,
    /// Incremental reply-parse buffer per peer (bytes drained from the
    /// nonblocking socket, not yet a complete frame).
    bufs: Vec<Vec<u8>>,
    /// Per peer, the scatter-order queue of wave seqs still owing a reply.
    owed: Vec<VecDeque<WaveId>>,
    /// Outstanding waves in scatter order (front = oldest).
    pending: VecDeque<TcpWave>,
    next_seq: WaveId,
    /// Loopback listener threads and the addresses that wake them.
    handles: Vec<JoinHandle<()>>,
    listener_addrs: Vec<String>,
    shutdown: Arc<AtomicBool>,
}

/// Spawn both planes of a TCP cluster over one shared accounting block:
/// per plane, either connect to the listed `host:port` peers (standalone
/// `occd worker` processes) or spawn that many loopback thread peers
/// behind persistent ephemeral listeners.
pub fn spawn_planes(
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    topo: &Topology,
    stats: Arc<SharedStats>,
) -> Result<(TcpPlane, TcpPlane)> {
    spawn_planes_cell(Arc::new(DataCell::new(data)), backend, topo, stats)
}

/// [`spawn_planes`] over a shared, *growable* dataset cell — the
/// `occd serve` entry point: the admission stage keeps a clone of the
/// cell and publishes grown generations between mini-epochs, and every
/// dataset ship snapshots the generation current at encode time.
pub fn spawn_planes_cell(
    data: Arc<DataCell>,
    backend: Arc<dyn ComputeBackend>,
    topo: &Topology,
    stats: Arc<SharedStats>,
) -> Result<(TcpPlane, TcpPlane)> {
    let shared = Arc::new(TcpShared {
        data,
        reconnect_attempts: topo.reconnect_attempts,
        frugal: topo.frugal_wire,
        next_snap_id: AtomicU64::new(1),
        store: topo.store,
        stats,
    });
    let compute = TcpPlane::init(
        &shared,
        &backend,
        PeerRole::Compute,
        topo.procs,
        &topo.compute_peers,
        topo.io,
    )?;
    let validate = TcpPlane::init(
        &shared,
        &backend,
        PeerRole::Validate,
        topo.validators,
        &topo.validator_peers,
        topo.io,
    )?;
    Ok((compute, validate))
}

/// All-loopback convenience spawner (tests, embedders): `procs` compute
/// peers and `validators` validator peers, each behind its own persistent
/// listener, accounting into a private [`SharedStats`] readable through
/// [`TcpPlane::stats`] on either plane.
pub fn spawn_local(
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    procs: usize,
    validators: usize,
) -> Result<(TcpPlane, TcpPlane)> {
    spawn_planes(
        data,
        backend,
        &Topology::local(procs, validators),
        Arc::new(SharedStats::default()),
    )
}

impl TcpPlane {
    /// Build one plane: addressed remote peers when `addrs` is non-empty,
    /// loopback thread peers otherwise. Every peer is handshaken (still in
    /// blocking mode), then switched nonblocking for the life of the
    /// session and — under `io = "reactor"` — registered with the plane's
    /// readiness queue before the plane is handed out.
    fn init(
        shared: &Arc<TcpShared>,
        backend: &Arc<dyn ComputeBackend>,
        role: PeerRole,
        n: usize,
        addrs: &[String],
        io: IoKind,
    ) -> Result<TcpPlane> {
        let mut reactor = match io {
            IoKind::Reactor => Some(Reactor::new().map_err(|e| {
                Error::Coordinator(format!("reactor setup: {e}"))
            })?),
            IoKind::Poll => None,
        };
        let count = if addrs.is_empty() { n } else { addrs.len() };
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        let mut listener_addrs = Vec::new();
        let mut peers = Vec::with_capacity(count);
        // Handshake geometry is the generation current at plane build;
        // streamed growth past it is legal (peer stores grow on demand).
        let geometry = shared.data.get();
        for id in 0..count {
            let hello = Hello {
                proto: wire::VERSION,
                role,
                peer_id: id as u32,
                peers_in_plane: count as u32,
                n: geometry.len() as u64,
                dim: geometry.dim() as u64,
            };
            let (stream, addr, loopback) = if let Some(a) = addrs.get(id) {
                (
                    connect_with_retry(a, shared.reconnect_attempts, &mut reactor)?,
                    a.clone(),
                    false,
                )
            } else {
                // Loopback thread peer: a persistent listener serving one
                // session at a time, so a broken session re-opens under
                // the same bounded reconnect policy as a remote worker's.
                let listener = TcpListener::bind(("127.0.0.1", 0))
                    .map_err(|e| Error::Coordinator(format!("tcp bind: {e}")))?;
                let local = listener
                    .local_addr()
                    .map_err(|e| Error::Coordinator(format!("tcp local_addr: {e}")))?;
                let addr = local.to_string();
                let backend = backend.clone();
                let stop = shutdown.clone();
                let store = shared.store;
                handles.push(std::thread::spawn(move || loop {
                    let Ok((s, _)) = listener.accept() else { return };
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    let _ = serve_peer_with(s, backend.clone(), store);
                }));
                listener_addrs.push(addr.clone());
                let stream = TcpStream::connect(local)
                    .map_err(|e| Error::Coordinator(format!("tcp connect: {e}")))?;
                (stream, addr, true)
            };
            stream.set_nodelay(true).ok();
            let mut peer = Peer {
                stream: Some(stream),
                addr,
                loopback,
                hello,
                sent: Coverage::default(),
                snap: None,
                outq: VecDeque::new(),
            };
            let (bytes, took) = do_handshake(&mut peer)?;
            shared.stats.add_bytes(bytes as u64);
            shared.stats.add_handshake(took);
            finish_session_open(&mut reactor, &mut peer)?;
            peers.push(peer);
        }
        Ok(TcpPlane {
            shared: shared.clone(),
            reactor,
            pool: Vec::new(),
            bufs: vec![Vec::new(); count],
            owed: vec![VecDeque::new(); count],
            pending: VecDeque::new(),
            next_seq: 0,
            peers,
            handles,
            listener_addrs,
            shutdown,
        })
    }

    /// Cumulative transport accounting — cluster-wide (both planes share
    /// the counters).
    pub fn stats(&self) -> TransportStats {
        self.shared.stats.snapshot()
    }

    /// Scatter one job per peer without waiting for results, returning the
    /// wave's id. Several waves may be outstanding; peers process their
    /// frames in order and owe one reply per delivered job.
    ///
    /// A delivery that fails even after the bounded reconnects leaves that
    /// peer's slot a typed error (the wave still drains; the plane stays
    /// usable — the next scatter retries the address) and the scatter
    /// reports the failure.
    pub fn scatter(&mut self, jobs: Vec<Job>) -> Result<WaveId> {
        assert_eq!(jobs.len(), self.peers.len(), "one job per peer");
        // Drain whatever replies are already readable first, so neither
        // direction's socket buffers back up while this wave's frames are
        // written (peers block writing replies nobody reads only if we let
        // the reply direction fill up).
        self.pump_all();
        let seq = self.next_seq;
        self.next_seq += 1;
        let shared = self.shared.clone();
        // Encode the whole wave up front — an encode failure here is clean,
        // nothing has been sent yet. Two shapes:
        //
        // * Snapshot-bearing jobs (Nearest / BpDescend) under frugal
        //   shipping: the matrix leaves the job frame entirely. Each peer's
        //   frame carries a snapshot *reference*; the snapshot itself ships
        //   separately (delta/full/not-at-all, per peer cache state) during
        //   delivery. The reference id per peer is decided here: the held
        //   id when the content is bit-identical to what the session
        //   already holds, a fresh wave id otherwise.
        // * Everything else (reduction waves, pair caches, or any wave with
        //   frugal shipping off): the PR 3 splice path — shared Arc'd
        //   payloads encode once and splice into each frame.
        let needs: Vec<Option<Range<usize>>> = jobs.iter().map(|j| j.data_range()).collect();
        let mut memo = SnapMemo::default();
        let sw = Instant::now();
        let snapshot_wave = shared.frugal && jobs.iter().any(|j| job_snapshot(j).is_some());
        let wave_jobs: Vec<WaveJob> = if snapshot_wave {
            let mut out = Vec::with_capacity(jobs.len());
            let mut unique = 0usize;
            for (i, (job, need)) in jobs.iter().zip(needs).enumerate() {
                let wj = match job_snapshot(job) {
                    Some(m) => {
                        let ref_id = snap_ref_id(&shared, &self.peers[i], m, &mut memo);
                        let mut buf = self.pool.pop().unwrap_or_default();
                        buf.clear();
                        wire::snapref_job_frame_into(&mut buf, job, ref_id)?;
                        unique += buf.len();
                        WaveJob {
                            frame: Arc::new(buf),
                            need,
                            snap: Some((ref_id, m.clone())),
                        }
                    }
                    None => {
                        let mut buf = self.pool.pop().unwrap_or_default();
                        buf.clear();
                        let payload = wire::encode_job(job);
                        wire::frame_into(&mut buf, wire::KIND_JOB, |b| {
                            b.extend_from_slice(&payload)
                        })?;
                        unique += buf.len();
                        WaveJob { frame: Arc::new(buf), need, snap: None }
                    }
                };
                out.push(wj);
            }
            shared.stats.add_unique(unique as u64);
            out
        } else {
            let wave = wire::job_frames_pooled(&jobs, &mut self.pool)?;
            let total: usize = wave.frames.iter().map(|f| f.len()).sum();
            shared.stats.add_unique((total - wave.spliced_payload_bytes) as u64);
            wave.frames
                .into_iter()
                .zip(needs)
                .map(|(frame, need)| WaveJob { frame: Arc::new(frame), need, snap: None })
                .collect()
        };
        shared.stats.add_ser(sw.elapsed());
        let n = self.peers.len();
        let mut wave = TcpWave {
            seq,
            jobs: wave_jobs,
            outputs: (0..n).map(|_| None).collect(),
            remaining: n,
            max_busy: Duration::ZERO,
            err: None,
        };
        let mut first_err: Option<Error> = None;
        for i in 0..n {
            match deliver(
                &shared,
                &mut self.reactor,
                &mut self.peers[i],
                &wave.jobs[i],
                &mut memo,
                &mut self.pool,
            ) {
                Ok(()) => self.owed[i].push_back(seq),
                Err(e) => {
                    // This peer owes no reply for the wave: its slot is a
                    // typed error instead, so the wave still drains and
                    // the plane stays usable.
                    let msg = format!("scatter to {}: {e}", self.peers[i].describe());
                    wave.remaining -= 1;
                    if wave.err.is_none() {
                        wave.err = Some(Error::Coordinator(msg.clone()));
                    }
                    if first_err.is_none() {
                        first_err = Some(Error::Coordinator(msg));
                    }
                    drop_stream(&mut self.reactor, &mut self.peers[i]);
                }
            }
        }
        self.pending.push_back(wave);
        match first_err {
            Some(e) => Err(e),
            None => Ok(seq),
        }
    }

    /// Route one complete reply frame read off peer `i`'s stream: it
    /// belongs to the oldest wave that peer still owes.
    fn route_reply(&mut self, i: usize, kind: u16, payload: Vec<u8>) -> Result<()> {
        let Some(seq) = self.owed[i].pop_front() else {
            return Err(Error::Coordinator(format!(
                "{} sent a frame with no reply owed",
                self.peers[i].describe()
            )));
        };
        self.shared.stats.add_bytes((wire::HEADER_LEN + payload.len()) as u64);
        let sw = Instant::now();
        let reply = wire::decode_reply(kind, &payload);
        self.shared.stats.add_ser(sw.elapsed());
        let n = self.peers.len();
        let wave = self
            .pending
            .iter_mut()
            .find(|w| w.seq == seq)
            .expect("owed seq has a pending wave");
        wave.remaining -= 1;
        match reply {
            Ok(r) => {
                wave.max_busy = wave.max_busy.max(r.busy);
                match r.output {
                    Ok(out) if r.worker == i && i < n => wave.outputs[i] = Some(out),
                    Ok(_) => {
                        if wave.err.is_none() {
                            wave.err = Some(Error::Coordinator(format!(
                                "peer id {} replied on slot {i}",
                                r.worker
                            )));
                        }
                    }
                    Err(e) => {
                        if wave.err.is_none() {
                            wave.err = Some(e);
                        }
                    }
                }
            }
            Err(e) => {
                // Undecodable reply payload: the frame boundary is intact,
                // so the session survives; the wave records the failure.
                if wave.err.is_none() {
                    wave.err = Some(e);
                }
            }
        }
        Ok(())
    }

    /// Nonblocking pump of one peer: drain readable bytes into its buffer
    /// and route every complete frame. The socket is already in
    /// nonblocking mode — sessions are switched exactly once, at open
    /// ([`finish_session_open`]); the hot path never flips modes. `Err`
    /// means the stream is dead or desynced — the caller recovers.
    fn pump_peer(&mut self, i: usize) -> Result<()> {
        loop {
            // Parse first: a previous pump may have buffered complete
            // frames beyond the one it was probing for.
            if let Some((kind, payload)) = wire::poll_frame(&mut self.bufs[i])? {
                self.route_reply(i, kind, payload)?;
                continue;
            }
            let Some(stream) = &self.peers[i].stream else {
                return Err(Error::Coordinator(format!(
                    "{} has no live session",
                    self.peers[i].describe()
                )));
            };
            let mut tmp = [0u8; 64 * 1024];
            let read = (&*stream).read(&mut tmp);
            match read {
                Ok(0) => {
                    return Err(Error::Coordinator("peer closed its stream mid-wave".into()))
                }
                Ok(k) => self.bufs[i].extend_from_slice(&tmp[..k]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(Error::Coordinator(format!("tcp gather read: {e}"))),
            }
        }
    }

    /// One nonblocking sweep over every peer with replies owed; dead
    /// streams take the bounded recovery path inline.
    fn pump_all(&mut self) {
        for i in 0..self.peers.len() {
            if self.owed[i].is_empty() {
                continue;
            }
            if let Err(e) = self.pump_peer(i) {
                self.recover_peer(i, e);
            }
        }
    }

    /// Bytes still queued for write across all peers (0 = fully flushed).
    fn queued_bytes(&self) -> usize {
        self.peers
            .iter()
            .flat_map(|p| p.outq.iter())
            .map(|f| f.bytes.as_slice().len() - f.sent)
            .sum()
    }

    /// Push queued writes on every peer with a live session, keeping
    /// write-readiness interest in sync with queue emptiness. A write
    /// failure takes the bounded recovery path inline — the waves'
    /// retained frames resend on the fresh session.
    fn flush_all(&mut self) {
        for i in 0..self.peers.len() {
            if self.peers[i].outq.is_empty() || self.peers[i].stream.is_none() {
                continue;
            }
            let shared = self.shared.clone();
            match flush_peer(&shared, &mut self.peers[i], &mut self.pool) {
                Ok(drained) => {
                    sync_write_interest(&mut self.reactor, &self.peers[i], !drained)
                }
                Err(e) => self.recover_peer(i, e),
            }
        }
    }

    /// The plane's single park point. Reactor mode blocks in
    /// [`Reactor::wait`] until a registered socket turns ready, the
    /// wakeup fd is signaled, or the capped timeout lapses; poll mode
    /// sleeps one legacy slice. Every return ticks `reactor_wakeups`
    /// once — "times the event loop came back from a wait" — which is
    /// exactly the count the reactor-vs-poll bench gate compares.
    fn wait_io(&mut self, cap: Duration) {
        match self.reactor.as_mut() {
            Some(r) => {
                let _ = r.wait(cap.min(WAIT_CAP));
            }
            None => std::thread::sleep(cap.min(POLL_NAP)), // poll-mode
        }
        self.shared.stats.add_reactor_wakeup();
    }

    /// Park a reconnect backoff for `delay`. Poll mode just sleeps.
    /// Reactor mode spends the delay in [`Reactor::wait`] while pumping
    /// the *other* peers, so their replies keep draining while peer
    /// `dead` is down. A pump error on another peer only drops that
    /// session here — the outer sweep's recovery picks it up.
    fn recovery_pause(&mut self, delay: Duration, dead: usize) {
        if self.reactor.is_none() {
            std::thread::sleep(delay); // poll-mode: no readiness source
            return;
        }
        let deadline = Instant::now() + delay;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return;
            }
            self.wait_io(deadline - now);
            for i in 0..self.peers.len() {
                if i == dead || self.owed[i].is_empty() || self.peers[i].stream.is_none() {
                    continue;
                }
                if self.pump_peer(i).is_err() {
                    drop_stream(&mut self.reactor, &mut self.peers[i]);
                }
            }
        }
    }

    /// Block until this plane (probably) has progress to make: pump and
    /// flush first — if that advanced anything, report immediately —
    /// otherwise park in [`TcpPlane::wait_io`] and sweep once more.
    /// Spurious `Ok(true)` is allowed; the caller re-checks its waves.
    pub fn wait_input(&mut self, timeout: Duration) -> Result<bool> {
        let owed_before: usize = self.owed.iter().map(|q| q.len()).sum();
        let queued_before = self.queued_bytes();
        self.pump_all();
        self.flush_all();
        let progressed = |plane: &TcpPlane| {
            plane.owed.iter().map(|q| q.len()).sum::<usize>() != owed_before
                || plane.queued_bytes() != queued_before
        };
        if progressed(self) {
            return Ok(true);
        }
        self.wait_io(timeout.min(WAIT_CAP));
        self.pump_all();
        self.flush_all();
        Ok(progressed(self))
    }

    /// A cross-thread handle that cuts [`TcpPlane::wait_input`] short
    /// (reactor mode only; poll-mode waits always run out their slice).
    pub fn waker(&self) -> Option<Arc<dyn super::transport::PlaneWaker>> {
        self.reactor
            .as_ref()
            .map(|r| Arc::new(r.wakeup()) as Arc<dyn super::transport::PlaneWaker>)
    }

    /// The recovery path: peer `i`'s session died with replies owed.
    /// Bounded attempts; each opens a fresh session (remote replacement
    /// worker, or the persistent loopback listener), re-ships the retained
    /// frames' data ranges and snapshots (a full re-base — the replacement
    /// session's cache is empty) and resends every owed frame in order;
    /// the replies then arrive through the normal pump. Jobs are
    /// deterministic, so the recovered replies are exactly what the lost
    /// session would have sent. If the budget is exhausted, every owed
    /// reply becomes a typed error on its wave — drained, never deadlocked.
    fn recover_peer(&mut self, i: usize, cause: Error) {
        self.bufs[i].clear();
        let owed: Vec<WaveId> = self.owed[i].iter().copied().collect();
        let shared = self.shared.clone();
        let attempts = shared.reconnect_attempts;
        let mut last = cause;
        'attempt: for attempt in 0..attempts {
            if attempt > 0 {
                self.recovery_pause(backoff_delay(attempt - 1), i);
            }
            if let Err(e) = open_session(&shared, &mut self.reactor, &mut self.peers[i]) {
                last = e;
                continue;
            }
            let mut memo = SnapMemo::default();
            for &seq in &owed {
                let wave = self
                    .pending
                    .iter()
                    .find(|w| w.seq == seq)
                    .expect("owed seq has a pending wave");
                if let Err(e) = write_wave_job(
                    &shared,
                    &mut self.reactor,
                    &mut self.peers[i],
                    &wave.jobs[i],
                    &mut memo,
                    &mut self.pool,
                ) {
                    last = e;
                    continue 'attempt;
                }
            }
            return; // back in the sweep; replies arrive in resend order
        }
        let msg = format!(
            "{} dropped mid-wave, unreachable after {attempts} reconnect attempts: {last}",
            self.peers[i].describe()
        );
        drop_stream(&mut self.reactor, &mut self.peers[i]);
        for seq in owed {
            let wave = self
                .pending
                .iter_mut()
                .find(|w| w.seq == seq)
                .expect("owed seq has a pending wave");
            wave.remaining -= 1;
            if wave.err.is_none() {
                wave.err = Some(Error::Coordinator(msg.clone()));
            }
        }
        self.owed[i].clear();
    }

    fn remaining(&self, wave: WaveId) -> Option<usize> {
        self.pending.iter().find(|w| w.seq == wave).map(|w| w.remaining)
    }

    /// Non-blocking readiness check: true when every reply of `wave` has
    /// arrived (buffered into its slots), so its gather will not block.
    /// Also pushes any queued writes — a probe must never leave frames
    /// parked when the kernel would take them.
    pub fn try_ready(&mut self, wave: WaveId) -> Result<bool> {
        self.pump_all();
        self.flush_all();
        self.remaining(wave)
            .map(|r| r == 0)
            .ok_or_else(|| Error::Coordinator("try_ready on an unknown wave".into()))
    }

    /// Pump-free readiness probe: reports from already-routed replies only
    /// (false for unknown ids), no syscalls. Pair with one
    /// [`TcpPlane::try_ready`] — whose pump routes every readable reply
    /// across all in-flight waves — when polling several waves.
    pub fn ready_hint(&self, wave: WaveId) -> bool {
        self.remaining(wave) == Some(0)
    }

    /// Retire one outstanding wave by id: outputs sorted by peer id plus
    /// the critical-path busy time. Blocks until the wave is fully
    /// drained: each turn pumps replies and flushes queued writes, and
    /// when neither direction moved, parks in [`TcpPlane::wait_io`] —
    /// actual readiness under the reactor, one legacy sleep slice under
    /// `io = "poll"`. The parked time is what `gather_wait_time`
    /// measures: true wall-clock blocked on the slowest peers. Replies
    /// for other in-flight waves arriving meanwhile buffer into their
    /// own slots.
    pub fn gather(&mut self, wave: WaveId) -> Result<(Vec<JobOutput>, Duration)> {
        assert!(
            self.pending.iter().any(|w| w.seq == wave),
            "gather without a scattered wave"
        );
        let mut idle = Duration::ZERO;
        loop {
            if self.remaining(wave).expect("wave registered") == 0 {
                break;
            }
            let owed_before: usize = self.owed.iter().map(|q| q.len()).sum();
            let queued_before = self.queued_bytes();
            self.pump_all();
            self.flush_all();
            let owed_after: usize = self.owed.iter().map(|q| q.len()).sum();
            let done = self.remaining(wave).expect("wave registered") == 0;
            let progressed =
                owed_after != owed_before || self.queued_bytes() != queued_before;
            if !done && !progressed {
                let sw = Instant::now();
                self.wait_io(WAIT_CAP);
                idle += sw.elapsed();
            }
        }
        self.shared.stats.add_gather_wait(idle);
        let at = self.pending.iter().position(|w| w.seq == wave).expect("wave registered");
        let wave = self.pending.remove(at).expect("position valid");
        if let Some(e) = wave.err {
            return Err(e);
        }
        // Reclaim the retired wave's frame buffers: an Arc this plane is
        // the last owner of goes back to the scratch pool, so
        // steady-state waves stop allocating.
        let TcpWave { jobs, outputs, max_busy, .. } = wave;
        for wj in jobs {
            if let Ok(buf) = Arc::try_unwrap(wj.frame) {
                recycle(&mut self.pool, buf);
            }
        }
        Ok((
            outputs.into_iter().map(|o| o.expect("peer replied")).collect(),
            max_busy,
        ))
    }

    /// Scatter one job per peer and gather the replies — the BSP barrier.
    pub fn scatter_gather(&mut self, jobs: Vec<Job>) -> Result<(Vec<JobOutput>, Duration)> {
        let wave = self.scatter(jobs)?;
        self.gather(wave)
    }

    /// Teardown drain: read every owed reply off every live session under
    /// **one** whole-drain `deadline` shared across all peers (the old
    /// shape hard-coded a fresh 10 s read timeout per peer, so P wedged
    /// peers cost P × 10 s). Sessions are restored to blocking mode first
    /// — per-read timeouts are the bound, re-armed with the *remaining*
    /// budget before each read. Failures are typed and returned, never
    /// swallowed: a desynced parse buffer, a wedged peer that eats the
    /// deadline, a mid-drain EOF and an unarmable read timeout each
    /// surface as their own error; `Drop` treats them as best-effort,
    /// tests assert on them directly.
    fn drain_owed(&mut self, deadline: Instant) -> Vec<Error> {
        let mut errs = Vec::new();
        // Teardown only: sessions leave their permanent nonblocking state
        // here, because the read-timeout bound below needs blocking reads.
        for p in self.peers.iter() {
            if let Some(s) = &p.stream {
                let _ = s.set_nonblocking(false);
            }
        }
        for i in 0..self.peers.len() {
            let mut owed = self.owed[i].len();
            if owed == 0 {
                continue;
            }
            let Some(stream) = &self.peers[i].stream else { continue };
            let mut tmp = [0u8; 64 * 1024];
            while owed > 0 {
                // Frames come off the parse buffer first: a pump may have
                // left a partial reply in `bufs`, and reading the raw
                // socket from mid-frame would desync instead of draining.
                match wire::poll_frame(&mut self.bufs[i]) {
                    Ok(Some(_)) => {
                        owed -= 1;
                        continue;
                    }
                    Ok(None) => {}
                    Err(e) => {
                        errs.push(Error::Coordinator(format!(
                            "{} desynced during teardown drain: {e}",
                            self.peers[i].describe()
                        )));
                        break;
                    }
                }
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    errs.push(Error::Coordinator(format!(
                        "teardown drain deadline lapsed with {owed} replies still \
                         owed by {}",
                        self.peers[i].describe()
                    )));
                    break;
                }
                if let Err(e) = stream.set_read_timeout(Some(left)) {
                    errs.push(Error::Coordinator(format!(
                        "{} teardown drain could not arm its read deadline: {e}",
                        self.peers[i].describe()
                    )));
                    break;
                }
                match (&*stream).read(&mut tmp) {
                    Ok(0) => {
                        errs.push(Error::Coordinator(format!(
                            "{} closed with {owed} replies still owed",
                            self.peers[i].describe()
                        )));
                        break;
                    }
                    Ok(k) => self.bufs[i].extend_from_slice(&tmp[..k]),
                    Err(e) => {
                        errs.push(Error::Coordinator(format!(
                            "{} wedged during teardown drain ({owed} replies \
                             still owed): {e}",
                            self.peers[i].describe()
                        )));
                        break;
                    }
                }
            }
            let _ = stream.set_read_timeout(None);
        }
        errs
    }

    /// Sever peer `i`'s current session (tests): the next delivery or pump
    /// takes the reconnect/recovery path against the peer's address.
    #[cfg(test)]
    fn kill_session(&mut self, i: usize) {
        drop_stream(&mut self.reactor, &mut self.peers[i]);
    }

    /// Make every later write on peer `i`'s current session fail hard
    /// (tests): shutting down the local write half turns queued writes
    /// into immediate errors instead of `WouldBlock`, without touching
    /// the read half.
    #[cfg(test)]
    fn break_session_writes(&mut self, i: usize) {
        if let Some(s) = &self.peers[i].stream {
            let _ = s.shutdown(std::net::Shutdown::Write);
        }
    }

    /// Clamp peer `i`'s session send buffer to the kernel minimum
    /// (tests): a snapshot frame then takes many partial vectored writes
    /// to leave, exercising the pending-queue continuation path.
    #[cfg(all(test, target_os = "linux"))]
    fn shrink_sndbuf(&mut self, i: usize) {
        extern "C" {
            fn setsockopt(
                fd: i32,
                level: i32,
                name: i32,
                value: *const std::ffi::c_void,
                len: u32,
            ) -> i32;
        }
        const SOL_SOCKET: i32 = 1;
        const SO_SNDBUF: i32 = 7;
        if let Some(s) = &self.peers[i].stream {
            let val: i32 = 1; // the kernel clamps this up to its floor
            let rc = unsafe {
                setsockopt(
                    stream_fd(s),
                    SOL_SOCKET,
                    SO_SNDBUF,
                    (&val as *const i32).cast(),
                    std::mem::size_of::<i32>() as u32,
                )
            };
            assert_eq!(rc, 0, "setsockopt(SO_SNDBUF) failed");
        }
    }
}

impl super::transport::PlaneIo for TcpPlane {
    fn peers(&self) -> usize {
        self.peers.len()
    }
    fn scatter(&mut self, jobs: Vec<Job>) -> Result<WaveId> {
        TcpPlane::scatter(self, jobs)
    }
    fn try_ready(&mut self, wave: WaveId) -> Result<bool> {
        TcpPlane::try_ready(self, wave)
    }
    fn ready_hint(&self, wave: WaveId) -> bool {
        TcpPlane::ready_hint(self, wave)
    }
    fn gather(&mut self, wave: WaveId) -> Result<(Vec<JobOutput>, Duration)> {
        TcpPlane::gather(self, wave)
    }
    fn wait_input(&mut self, timeout: Duration) -> Result<bool> {
        TcpPlane::wait_input(self, timeout)
    }
    fn waker(&self) -> Option<Arc<dyn super::transport::PlaneWaker>> {
        TcpPlane::waker(self)
    }
    fn note_idle_wait(&self) {
        self.shared.stats.add_reactor_wakeup();
    }
}

impl Drop for TcpPlane {
    fn drop(&mut self) {
        // Stop the persistent listeners from serving replacement sessions
        // before anything else — recovery during teardown makes no sense.
        self.shutdown.store(true, Ordering::SeqCst);
        // Drain outstanding replies under one whole-plane deadline so no
        // peer blocks writing into a socket nobody reads — best-effort
        // here (a wedged or desynced peer's last replies are abandoned;
        // its socket closes below either way). The drain restores
        // blocking mode itself, which the shutdown writes below also
        // rely on.
        let _ = self.drain_owed(Instant::now() + DRAIN_DEADLINE);
        // Shutdown frames are best-effort, but a failed write is recorded
        // by dropping that session immediately: the peer then sees EOF
        // instead of half a frame, and teardown never retries or hangs.
        if let Ok(frame) = wire::job_frame(&Job::Shutdown) {
            for p in self.peers.iter_mut() {
                if let Some(stream) = &mut p.stream {
                    if stream.write_all(&frame).is_err() {
                        p.stream = None;
                    }
                }
            }
        }
        // Close every socket (EOF for any peer that missed its shutdown
        // frame).
        for p in self.peers.iter_mut() {
            p.stream = None;
        }
        // Wake each persistent listener so its accept loop observes the
        // shutdown flag, then join.
        for addr in &self.listener_addrs {
            let _ = TcpStream::connect(addr);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{split_range, split_range_chunked};
    use super::*;
    use crate::data::generators::{dp_clusters, GenConfig};
    use crate::linalg::Matrix;
    use crate::runtime::native::NativeBackend;

    fn data_and_backend(n: usize) -> (Arc<Dataset>, Arc<dyn ComputeBackend>) {
        let data = Arc::new(dp_clusters(&GenConfig { n, dim: 8, theta: 1.0, seed: 7 }));
        (data, Arc::new(NativeBackend::new()))
    }

    fn assert_nearest_bits_equal(a: &[JobOutput], b: &[JobOutput]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            let (JobOutput::Nearest { idx: ia, d2: da }, JobOutput::Nearest { idx: ib, d2: db }) =
                (x, y)
            else {
                panic!("wrong output kind");
            };
            assert_eq!(ia, ib);
            assert_eq!(
                da.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                db.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                "d² diverged across the wire"
            );
        }
    }

    // Coverage unit tests live in `crate::data::store` alongside the type.

    // -- Waves -------------------------------------------------------------

    /// The same wave over TCP and in-proc must return bit-identical outputs
    /// — the whole point of the bit-exact wire format.
    #[test]
    fn tcp_wave_bitidentical_to_inproc() {
        let (data, backend) = data_and_backend(120);
        let (mut compute, _validate) = spawn_local(data.clone(), backend.clone(), 3, 1).unwrap();
        let pool = super::super::engine::WorkerPool::spawn(data.clone(), backend, 3);
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(3));
        centers.push_row(data.point(77));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..120, 3)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let (a, _) = compute.scatter_gather(mk()).unwrap();
        let (b, _) = pool.scatter_gather(mk()).unwrap();
        assert_nearest_bits_equal(&a, &b);
        let stats = compute.stats();
        assert!(stats.wire_bytes > 0, "tcp waves must be accounted");
        assert!(stats.handshake_time > Duration::ZERO, "handshakes must be accounted");
    }

    /// Loopback peers receive the dataset over the wire, on demand, each
    /// range at most once per session.
    #[test]
    fn dataset_blocks_ship_on_demand_and_only_once() {
        let (data, backend) = data_and_backend(100);
        let (mut compute, _validate) = spawn_local(data.clone(), backend, 2, 1).unwrap();
        assert_eq!(compute.stats().dataset_bytes, 0, "nothing shipped before a wave");
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..100, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        compute.scatter_gather(mk()).unwrap();
        let after_first = compute.stats().dataset_bytes;
        assert!(after_first > 0, "compute jobs must ship their point ranges");
        compute.scatter_gather(mk()).unwrap();
        assert_eq!(
            compute.stats().dataset_bytes,
            after_first,
            "already-covered ranges must not be re-shipped"
        );
    }

    /// Validator peers never receive dataset blocks: their jobs carry the
    /// proposal vectors inline.
    #[test]
    fn validator_plane_ships_no_dataset() {
        let (data, backend) = data_and_backend(60);
        let (_compute, mut validate) = spawn_local(data, backend, 1, 2).unwrap();
        let mut vectors = Matrix::zeros(0, 2);
        vectors.push_row(&[0.0, 0.0]);
        vectors.push_row(&[1.0, 0.0]);
        let vectors = Arc::new(vectors);
        let jobs = vec![
            Job::PairCache {
                vectors: vectors.clone(),
                positions: vec![],
                shards: vec![vec![0, 1]],
            },
            Job::PairCache { vectors, positions: vec![], shards: vec![] },
        ];
        validate.scatter_gather(jobs).unwrap();
        assert_eq!(validate.stats().dataset_bytes, 0);
    }

    /// The snapshot wire diet, end to end over real sockets: an unchanged
    /// snapshot ships nothing, an appended snapshot ships only its delta
    /// rows, and a rewritten snapshot falls back to a full frame — with the
    /// returned assignments bit-identical throughout.
    #[test]
    fn snapshot_deltas_ship_only_appended_rows() {
        let (data, backend) = data_and_backend(120);
        let (mut compute, _validate) = spawn_local(data.clone(), backend.clone(), 2, 1).unwrap();
        let mk = |centers: &Arc<Matrix>| -> Vec<Job> {
            split_range(0..120, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let mut m = Matrix::zeros(0, 8);
        m.push_row(data.point(3));
        m.push_row(data.point(40));
        let snap1 = Arc::new(m.clone());

        // Wave 1: cold caches — one full snapshot per peer, no deltas.
        let (out1, _) = compute.scatter_gather(mk(&snap1)).unwrap();
        let s1 = compute.stats();
        assert_eq!(s1.full_snapshot_fallbacks, 2, "one full install per cold peer");
        assert_eq!(s1.delta_bytes, 0);

        // Wave 2: identical content (fresh Arc) — nothing ships at all.
        let snap1b = Arc::new(m.clone());
        let (out2, _) = compute.scatter_gather(mk(&snap1b)).unwrap();
        let s2 = compute.stats();
        assert_eq!(s2.full_snapshot_fallbacks, 2, "no new full installs");
        assert_eq!(s2.delta_bytes, 0, "identical snapshots ship no delta");
        assert_nearest_bits_equal(&out1, &out2);

        // Wave 3: two appended rows — delta bytes ≈ 2 rows, no new fulls.
        m.push_row(data.point(70));
        m.push_row(data.point(99));
        let snap2 = Arc::new(m.clone());
        let (out3, _) = compute.scatter_gather(mk(&snap2)).unwrap();
        let s3 = compute.stats();
        assert_eq!(s3.full_snapshot_fallbacks, 2, "append must not trigger a full ship");
        assert!(s3.delta_bytes > 0, "appended rows must ship as a delta");
        let per_peer = (s3.delta_bytes - s2.delta_bytes) / 2;
        assert!(
            per_peer < 2 * 8 * 4 + 64,
            "delta payload ({per_peer} B/peer) must be ~2 rows, not the full matrix"
        );
        // The delta-reconstructed snapshot computes the exact fresh answer.
        let pool = super::super::engine::WorkerPool::spawn(data.clone(), backend, 2);
        let (reference, _) = pool.scatter_gather(mk(&snap2)).unwrap();
        assert_nearest_bits_equal(&out3, &reference);

        // Wave 4: rewrite a prefix row (the mean-recompute shape) — the
        // delta path must refuse and re-base from a full frame.
        m.row_mut(0)[0] += 1.0;
        let snap3 = Arc::new(m);
        compute.scatter_gather(mk(&snap3)).unwrap();
        let s4 = compute.stats();
        assert_eq!(
            s4.full_snapshot_fallbacks, 4,
            "a rewritten prefix must fall back to full snapshots"
        );
        assert_eq!(s4.delta_bytes, s3.delta_bytes, "no delta for a rewrite");
    }

    /// The multi-wave pending set: several waves scattered before any
    /// gather, retired by id in *reverse* order, with chained snapshot
    /// deltas between the in-flight waves — all bit-identical to an
    /// in-proc pool running the same jobs.
    #[test]
    fn multiple_waves_in_flight_retire_by_id_with_chained_deltas() {
        let (data, backend) = data_and_backend(90);
        let (mut compute, _validate) = spawn_local(data.clone(), backend.clone(), 2, 1).unwrap();
        let mk = |centers: &Arc<Matrix>| -> Vec<Job> {
            split_range(0..90, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let mut m = Matrix::zeros(0, 8);
        m.push_row(data.point(1));
        let snap_a = Arc::new(m.clone());
        m.push_row(data.point(44));
        let snap_b = Arc::new(m.clone());
        m.push_row(data.point(77));
        let snap_c = Arc::new(m.clone());
        // Three waves in flight at once, each against a grown snapshot.
        let wa = compute.scatter(mk(&snap_a)).unwrap();
        let wb = compute.scatter(mk(&snap_b)).unwrap();
        let wc = compute.scatter(mk(&snap_c)).unwrap();
        let stats = compute.stats();
        assert_eq!(
            stats.full_snapshot_fallbacks, 2,
            "only the cold-cache installs ship full; in-flight waves chain deltas"
        );
        assert!(stats.delta_bytes > 0, "waves b and c re-base by delta");
        // Retire youngest-first: replies buffer into their own waves.
        let (oc, _) = compute.gather(wc).unwrap();
        let (ob, _) = compute.gather(wb).unwrap();
        let (oa, _) = compute.gather(wa).unwrap();
        let pool = super::super::engine::WorkerPool::spawn(data.clone(), backend, 2);
        for (outs, snap) in [(&oa, &snap_a), (&ob, &snap_b), (&oc, &snap_c)] {
            let (want, _) = pool.scatter_gather(mk(snap)).unwrap();
            assert_nearest_bits_equal(outs, &want);
        }
    }

    #[test]
    fn tcp_peer_error_drains_wave_and_transport_survives() {
        let (data, backend) = data_and_backend(100);
        let (mut compute, _validate) = spawn_local(data, backend, 2, 1).unwrap();
        let short = Arc::new(vec![0u32; 10]); // fails decode validation peer-side
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: short.clone(), k: 2 })
            .collect();
        let wave = compute.scatter(jobs).unwrap();
        assert!(compute.gather(wave).is_err(), "poisoned wave must error");
        // The peers replied with errors and are still serving: a clean wave
        // works on the same sessions.
        let ok = Arc::new(vec![0u32; 100]);
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: ok.clone(), k: 2 })
            .collect();
        compute.scatter_gather(jobs).unwrap();
        // drop must not hang
    }

    #[test]
    fn tcp_drop_with_outstanding_wave_does_not_hang() {
        let (data, backend) = data_and_backend(60);
        let (mut compute, _validate) = spawn_local(data.clone(), backend, 2, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let jobs: Vec<Job> = split_range(0..60, 2)
            .into_iter()
            .map(|range| Job::Nearest { range, centers: centers.clone() })
            .collect();
        compute.scatter(jobs).unwrap();
        drop(compute); // wave never gathered; drop drains and joins
    }

    /// Out-of-order gather: a straggler peer must not stop an
    /// already-arrived reply from being retired, and the idle wait is
    /// accounted. The slow peer here is a hand-rolled worker that sits on
    /// its job before replying.
    #[test]
    fn gather_retires_replies_out_of_peer_order() {
        let (data, backend) = data_and_backend(60);
        // Peer 0: hand-rolled *slow* worker — handshake, then replies to
        // its job only after a long nap.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let slow_addr = listener.local_addr().unwrap().to_string();
        let slow = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let hello = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            loop {
                let (kind, _) = wire::read_frame(&mut s).unwrap();
                if kind == wire::KIND_JOB {
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(400));
            let out = Ok(JobOutput::PairCache { pairs: vec![] });
            wire::write_reply(&mut s, hello.peer_id, Duration::ZERO, &out).unwrap();
            // Hold the stream until the master is done with the wave.
            let _ = wire::read_frame(&mut s);
        });
        // Peer 1: a real (fast) worker.
        let (fast_addr, fast) = listener_worker(backend.clone(), 1);
        let topo = Topology {
            procs: 2,
            validators: 1,
            compute_peers: vec![],
            validator_peers: vec![slow_addr, fast_addr],
            reconnect_attempts: 1,
            frugal_wire: true,
            io: IoKind::from_env(),
            store: StoreKind::from_env(),
        };
        let (_compute, mut validate) =
            spawn_planes(data, backend, &topo, Arc::new(SharedStats::default())).unwrap();
        let mut vectors = Matrix::zeros(0, 2);
        vectors.push_row(&[0.0, 0.0]);
        vectors.push_row(&[1.0, 1.0]);
        let vectors = Arc::new(vectors);
        let jobs = vec![
            Job::PairCache { vectors: vectors.clone(), positions: vec![], shards: vec![] },
            Job::PairCache { vectors, positions: vec![], shards: vec![vec![0, 1]] },
        ];
        let (outs, _) = validate.scatter_gather(jobs).unwrap();
        // Outputs stay in peer-id order even though peer 1 replied first.
        let JobOutput::PairCache { pairs } = &outs[0] else { panic!("wrong output kind") };
        assert!(pairs.is_empty(), "slow peer's (empty) cache sits at slot 0");
        let JobOutput::PairCache { pairs } = &outs[1] else { panic!("wrong output kind") };
        assert_eq!(pairs.len(), 1, "fast peer's pair sits at slot 1");
        assert!(
            validate.stats().gather_wait_time >= Duration::from_millis(100),
            "waiting on the straggler must be accounted in gather_wait_time"
        );
        drop(validate);
        slow.join().unwrap();
        fast.join().unwrap();
    }

    // -- Addressed peers + reconnect ---------------------------------------

    /// A thread standing in for an `occd worker` process: listens on a real
    /// address and serves sessions with the production peer loop.
    fn listener_worker(
        backend: Arc<dyn ComputeBackend>,
        sessions: usize,
    ) -> (String, JoinHandle<()>) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            for _ in 0..sessions {
                let Ok((s, _)) = listener.accept() else { return };
                let _ = serve_peer(s, backend.clone());
            }
        });
        (addr, handle)
    }

    /// Addressed peers (the `occd worker` path, served here by threads
    /// behind real listeners) produce the same bits as loopback peers.
    #[test]
    fn addressed_peers_serve_waves_like_loopback() {
        let (data, backend) = data_and_backend(90);
        let (a0, h0) = listener_worker(backend.clone(), 1);
        let (a1, h1) = listener_worker(backend.clone(), 1);
        let (av, hv) = listener_worker(backend.clone(), 1);
        let topo = Topology {
            procs: 2,
            validators: 1,
            compute_peers: vec![a0, a1],
            validator_peers: vec![av],
            reconnect_attempts: 2,
            frugal_wire: true,
            io: IoKind::from_env(),
            store: StoreKind::from_env(),
        };
        let (mut compute, validate) =
            spawn_planes(data.clone(), backend.clone(), &topo, Arc::new(SharedStats::default()))
                .unwrap();
        assert_eq!(super::super::transport::PlaneIo::peers(&compute), 2);
        assert_eq!(super::super::transport::PlaneIo::peers(&validate), 1);
        let (mut loop_compute, _loop_validate) =
            spawn_local(data.clone(), backend, 2, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(5));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..90, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let (a, _) = compute.scatter_gather(mk()).unwrap();
        let (b, _) = loop_compute.scatter_gather(mk()).unwrap();
        assert_nearest_bits_equal(&a, &b);
        drop(compute);
        drop(validate);
        drop(loop_compute);
        h0.join().unwrap();
        h1.join().unwrap();
        hv.join().unwrap();
    }

    /// A remote peer that dies mid-wave is recovered through the bounded
    /// reconnect path: the listener serves a first session that reads the
    /// job and drops dead, then a second, healthy session; the master
    /// re-handshakes, re-ships, resends, and the wave completes.
    #[test]
    fn dropped_remote_peer_recovers_via_resend() {
        let (data, backend) = data_and_backend(80);
        // A worker whose first session crashes right after receiving its
        // job (handshake + data blocks are consumed so the master's scatter
        // succeeds), and whose second session is healthy.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let crash_backend = backend.clone();
        let worker = std::thread::spawn(move || {
            // Session 1: handshake, swallow frames until the job arrives,
            // then drop the stream without replying.
            let (mut s, _) = listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let _ = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            loop {
                let (kind, _) = wire::read_frame(&mut s).unwrap();
                if kind == wire::KIND_JOB {
                    break; // crash: drop the stream, reply with nothing
                }
            }
            drop(s);
            // Session 2: a healthy replacement.
            let (s, _) = listener.accept().unwrap();
            let _ = serve_peer(s, crash_backend);
        });
        let topo = Topology {
            procs: 1,
            validators: 1,
            compute_peers: vec![addr],
            validator_peers: vec![],
            reconnect_attempts: 8,
            frugal_wire: true,
            io: IoKind::from_env(),
            store: StoreKind::from_env(),
        };
        let (mut compute, _validate) =
            spawn_planes(data.clone(), backend, &topo, Arc::new(SharedStats::default()))
                .unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let jobs = vec![Job::Nearest { range: 0..80, centers: centers.clone() }];
        let (outs, _) = compute.scatter_gather(jobs).unwrap();
        let JobOutput::Nearest { idx, .. } = &outs[0] else { panic!("wrong output kind") };
        assert_eq!(idx.len(), 80);
        assert!(
            compute.stats().handshake_time > Duration::ZERO,
            "recovery re-handshakes must be accounted"
        );
        assert_eq!(
            compute.stats().full_snapshot_fallbacks,
            2,
            "the replacement session must be re-based from a full snapshot"
        );
        drop(compute);
        worker.join().unwrap();
    }

    /// Satellite (PR 3 leftover): a *loopback* thread peer whose session
    /// breaks no longer poisons the plane — its persistent listener serves
    /// a replacement session through the same bounded reconnect/recovery
    /// policy as a remote worker, and the wave completes bit-identically.
    #[test]
    fn loopback_peer_killed_mid_wave_recovers_bit_identically() {
        let (data, backend) = data_and_backend(80);
        let (mut compute, _validate) = spawn_local(data.clone(), backend.clone(), 2, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..80, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let wave = compute.scatter(mk()).unwrap();
        // Sever peer 0's session with its reply still owed: the gather's
        // pump hits the dead stream and must recover on a fresh session.
        compute.kill_session(0);
        let (outs, _) = compute.gather(wave).unwrap();
        let pool = super::super::engine::WorkerPool::spawn(data.clone(), backend, 2);
        let (want, _) = pool.scatter_gather(mk()).unwrap();
        assert_nearest_bits_equal(&outs, &want);
        // The replacement session re-based from a full snapshot: 2 cold
        // installs + 1 recovery re-base.
        assert_eq!(compute.stats().full_snapshot_fallbacks, 3);
        // And the plane stays fully usable afterwards.
        let (again, _) = compute.scatter_gather(mk()).unwrap();
        assert_nearest_bits_equal(&again, &want);
    }

    /// Satellite counterpart: when recovery is disabled
    /// (`reconnect_attempts = 0`), a dead loopback session surfaces the
    /// typed unreachable error with the wave drained — no deadlock, no
    /// plane poisoning, and the next wave recovers lazily at scatter.
    #[test]
    fn loopback_peer_without_budget_types_out_with_wave_drained() {
        let (data, backend) = data_and_backend(40);
        let topo = Topology { reconnect_attempts: 0, ..Topology::local(2, 1) };
        let (mut compute, _validate) =
            spawn_planes(data.clone(), backend, &topo, Arc::new(SharedStats::default()))
                .unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..40, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let wave = compute.scatter(mk()).unwrap();
        compute.kill_session(0);
        let err = compute.gather(wave).unwrap_err().to_string();
        assert!(
            err.contains("unreachable") || err.contains("reconnect"),
            "typed recovery-exhausted error expected, got: {err}"
        );
        // The wave is drained (gather returned) and the plane recovers on
        // the next scatter, which reconnects the severed peer lazily...
        // with a zero budget the reconnect itself fails fast, typed.
        let err = match compute.scatter(mk()) {
            Err(e) => e.to_string(),
            Ok(wave2) => match compute.gather(wave2) {
                Err(e) => e.to_string(),
                Ok(_) => String::new(),
            },
        };
        assert!(
            err.contains("unreachable") || err.contains("reconnect"),
            "zero-budget reconnects must fail fast, got: {err:?}"
        );
        // drop must not hang
    }

    /// A remote peer that dies and never comes back yields a typed error
    /// with the wave drained — not a poisoned plane, not a deadlock.
    #[test]
    fn dead_remote_peer_types_out_after_bounded_attempts() {
        let (data, backend) = data_and_backend(40);
        let (addr, handle) = listener_worker(backend.clone(), 1);
        let topo = Topology {
            procs: 1,
            validators: 1,
            compute_peers: vec![addr],
            validator_peers: vec![],
            reconnect_attempts: 1,
            frugal_wire: true,
            io: IoKind::from_env(),
            store: StoreKind::from_env(),
        };
        let (mut compute, _validate) =
            spawn_planes(data.clone(), backend, &topo, Arc::new(SharedStats::default()))
                .unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        // First wave works.
        compute
            .scatter_gather(vec![Job::Nearest { range: 0..40, centers: centers.clone() }])
            .unwrap();
        // The worker serves exactly one session; kill it by dropping our
        // stream (reconnect will find nobody listening... the handshake
        // against the dead backlog times out or errors).
        compute.kill_session(0);
        handle.join().unwrap();
        let err = compute
            .scatter(vec![Job::Nearest { range: 0..40, centers: centers.clone() }])
            .unwrap_err()
            .to_string();
        assert!(err.contains("reconnect") || err.contains("unreachable"), "{err}");
        // drop must not hang
    }

    // -- Readiness-reactor I/O plane ---------------------------------------

    /// Satellite: a socket killed mid-write surfaces as a typed session
    /// error and takes the recovery path — never a silent hang or a
    /// dropped wave. The write half of peer 0's session is shut down so
    /// the next delivery's flush fails hard (EPIPE, not `WouldBlock`);
    /// the bounded reconnect must then serve the wave bit-identically on
    /// a replacement session.
    #[test]
    fn socket_killed_mid_write_surfaces_and_recovers() {
        let (data, backend) = data_and_backend(80);
        let (mut compute, _validate) = spawn_local(data.clone(), backend.clone(), 2, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..80, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let handshakes_before = compute.stats().handshake_time;
        compute.break_session_writes(0);
        let (outs, _) = compute.scatter_gather(mk()).unwrap();
        let pool = super::super::engine::WorkerPool::spawn(data.clone(), backend, 2);
        let (want, _) = pool.scatter_gather(mk()).unwrap();
        assert_nearest_bits_equal(&outs, &want);
        assert!(
            compute.stats().handshake_time > handshakes_before,
            "the broken write half must force a re-handshake, not a silent retry"
        );
        // The plane stays fully usable afterwards.
        let (again, _) = compute.scatter_gather(mk()).unwrap();
        assert_nearest_bits_equal(&again, &want);
    }

    /// A frame bigger than the socket's send buffer leaves through many
    /// partial vectored writes: the unwritten tail parks on the peer's
    /// pending-write queue and continues from `sent` on later flushes,
    /// with bit-identical results. Linux-only: relies on clamping
    /// SO_SNDBUF to the kernel floor.
    #[cfg(target_os = "linux")]
    #[test]
    fn partial_writes_continue_under_tiny_sndbuf() {
        let (data, backend) = data_and_backend(64);
        let (mut compute, _validate) = spawn_local(data.clone(), backend.clone(), 1, 1).unwrap();
        // A snapshot far larger than the clamped send buffer (~4.6 KB):
        // 2048 rows × 8 f32 ≈ 64 KB of payload.
        let mut centers = Matrix::zeros(0, 8);
        for i in 0..2048 {
            centers.push_row(data.point(i % 64));
        }
        let centers = Arc::new(centers);
        let mk = || vec![Job::Nearest { range: 0..64, centers: centers.clone() }];
        compute.shrink_sndbuf(0);
        let batches_before = compute.stats().writev_batches;
        let (outs, _) = compute.scatter_gather(mk()).unwrap();
        assert!(
            compute.stats().writev_batches > batches_before + 1,
            "a 64 KB snapshot through a minimum send buffer must take \
             several vectored writes, got {}",
            compute.stats().writev_batches - batches_before
        );
        let pool = super::super::engine::WorkerPool::spawn(data.clone(), backend, 1);
        let (want, _) = pool.scatter_gather(mk()).unwrap();
        assert_nearest_bits_equal(&outs, &want);
    }

    /// Satellite: sessions are switched to nonblocking exactly once, at
    /// open — the pump/flush/gather hot path never toggles modes. The
    /// counter is thread-local and every session here opens on this
    /// thread, so the count is race-free under the parallel test runner.
    #[test]
    fn sockets_stay_nonblocking_without_hot_path_mode_flips() {
        let (data, backend) = data_and_backend(60);
        let (mut compute, _validate) = spawn_local(data.clone(), backend, 2, 1).unwrap();
        let flips_after_open = mode_flips();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..60, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        for _ in 0..3 {
            compute.scatter_gather(mk()).unwrap();
        }
        assert_eq!(
            mode_flips(),
            flips_after_open,
            "three waves of scatter/pump/flush/gather must not flip a \
             socket's blocking mode"
        );
    }

    /// Bugfix regression: a reconnect backoff must park in the reactor,
    /// not a hard `thread::sleep` — another peer's reply that arrives
    /// while the backoff timer runs is routed *during* the park, not
    /// after it. Peer 0's worker dies mid-wave and rejects two reconnect
    /// attempts (forcing two backoff pauses); peer 1 replies ~80 ms in,
    /// squarely inside the first pause. After `recover_peer` returns —
    /// and before anything else pumps — peer 1's reply must already be
    /// retired into the wave.
    #[test]
    fn reconnect_backoff_routes_other_peers_replies_mid_park() {
        let (data, backend) = data_and_backend(40);
        // Peer 0: session 1 handshakes, reads its job, drops dead. The
        // next two connects are accepted and hung up pre-handshake (each
        // reconnect attempt fails fast, so recovery parks its backoff in
        // between); the fourth session is a healthy replacement.
        let flaky_listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let flaky_addr = flaky_listener.local_addr().unwrap().to_string();
        let flaky_backend = backend.clone();
        let flaky = std::thread::spawn(move || {
            let (mut s, _) = flaky_listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let _ = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            loop {
                let (kind, _) = wire::read_frame(&mut s).unwrap();
                if kind == wire::KIND_JOB {
                    break;
                }
            }
            drop(s);
            for _ in 0..2 {
                let (s, _) = flaky_listener.accept().unwrap();
                drop(s);
            }
            let (s, _) = flaky_listener.accept().unwrap();
            let _ = serve_peer(s, flaky_backend);
        });
        // Peer 1: healthy, but replies only after a nap that lands inside
        // peer 0's first backoff pause.
        let slow_listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let slow_addr = slow_listener.local_addr().unwrap().to_string();
        let slow = std::thread::spawn(move || {
            let (mut s, _) = slow_listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let hello = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            loop {
                let (kind, _) = wire::read_frame(&mut s).unwrap();
                if kind == wire::KIND_JOB {
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(80));
            let out = Ok(JobOutput::PairCache { pairs: vec![] });
            wire::write_reply(&mut s, hello.peer_id, Duration::ZERO, &out).unwrap();
            let _ = wire::read_frame(&mut s); // hold until teardown
        });
        let topo = Topology {
            procs: 1,
            validators: 2,
            compute_peers: vec![],
            validator_peers: vec![flaky_addr, slow_addr],
            reconnect_attempts: 3,
            frugal_wire: true,
            io: IoKind::from_env(),
            store: StoreKind::from_env(),
        };
        let (_compute, mut validate) =
            spawn_planes(data, backend, &topo, Arc::new(SharedStats::default())).unwrap();
        let mut vectors = Matrix::zeros(0, 2);
        vectors.push_row(&[0.0, 0.0]);
        let vectors = Arc::new(vectors);
        let mk_jobs = || -> Vec<Job> {
            (0..2)
                .map(|_| Job::PairCache {
                    vectors: vectors.clone(),
                    positions: vec![],
                    shards: vec![],
                })
                .collect()
        };
        let wave = validate.scatter(mk_jobs()).unwrap();
        validate.kill_session(0);
        validate.recover_peer(0, Error::Coordinator("test kill".into()));
        assert_eq!(
            validate.remaining(wave),
            Some(1),
            "peer 1's reply must retire during peer 0's backoff park — \
             only peer 0's resent reply may still be outstanding"
        );
        validate.gather(wave).unwrap();
        drop(validate);
        flaky.join().unwrap();
        slow.join().unwrap();
    }

    /// Bugfix regression: the teardown owed-reply drain runs under ONE
    /// whole-drain deadline (the old shape spent a fresh 10 s read
    /// timeout per peer) and surfaces typed errors instead of swallowing
    /// them. Peer 0 desyncs its stream with garbage bytes; peer 1 wedges
    /// silently and eats the remaining budget.
    #[test]
    fn teardown_drain_bounds_wedged_peers_under_one_deadline() {
        let (data, backend) = data_and_backend(40);
        let desync_listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let desync_addr = desync_listener.local_addr().unwrap().to_string();
        let desync = std::thread::spawn(move || {
            let (mut s, _) = desync_listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let _ = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            let _ = wire::read_frame(&mut s); // the job
            s.write_all(&[0xAB; 16]).unwrap(); // not a frame: desync
            let _ = wire::read_frame(&mut s); // hold until teardown
        });
        let wedged_listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let wedged_addr = wedged_listener.local_addr().unwrap().to_string();
        let wedged = std::thread::spawn(move || {
            let (mut s, _) = wedged_listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let _ = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            let _ = wire::read_frame(&mut s); // the job — never replied to
            let _ = wire::read_frame(&mut s); // hold until teardown
        });
        let topo = Topology {
            procs: 1,
            validators: 2,
            compute_peers: vec![],
            validator_peers: vec![desync_addr, wedged_addr],
            reconnect_attempts: 1,
            frugal_wire: true,
            io: IoKind::from_env(),
            store: StoreKind::from_env(),
        };
        let (_compute, mut validate) =
            spawn_planes(data, backend, &topo, Arc::new(SharedStats::default())).unwrap();
        let mut vectors = Matrix::zeros(0, 2);
        vectors.push_row(&[0.0, 0.0]);
        let vectors = Arc::new(vectors);
        let jobs: Vec<Job> = (0..2)
            .map(|_| Job::PairCache {
                vectors: vectors.clone(),
                positions: vec![],
                shards: vec![],
            })
            .collect();
        validate.scatter(jobs).unwrap();
        let sw = Instant::now();
        let errs = validate.drain_owed(Instant::now() + Duration::from_millis(300));
        let took = sw.elapsed();
        assert!(
            took < Duration::from_secs(3),
            "one 300 ms whole-drain deadline must bound BOTH peers, took {took:?}"
        );
        assert_eq!(errs.len(), 2, "both failures must surface typed: {errs:?}");
        let text = errs.iter().map(|e| e.to_string()).collect::<Vec<_>>().join(" / ");
        assert!(text.contains("desynced"), "typed desync error expected: {text}");
        assert!(
            text.contains("wedged") || text.contains("deadline lapsed"),
            "typed wedged/deadline error expected: {text}"
        );
        // Keep the Drop below from re-draining the same dead sessions.
        validate.owed[0].clear();
        validate.owed[1].clear();
        drop(validate);
        desync.join().unwrap();
        wedged.join().unwrap();
    }

    /// Satellite: worker sessions park in their own reactor and meter
    /// every park return through the process-wide wakeup counter.
    #[test]
    fn worker_sessions_meter_their_reactor_wakeups() {
        let (data, backend) = data_and_backend(40);
        let before = worker_reactor_wakeups();
        let (mut compute, _validate) = spawn_local(data.clone(), backend, 1, 1).unwrap();
        // The idle sessions are parked waiting for their first job; give
        // them at least one full WAIT_CAP slice to wake through.
        std::thread::sleep(Duration::from_millis(120));
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        compute
            .scatter_gather(vec![Job::Nearest { range: 0..40, centers }])
            .unwrap();
        assert!(
            worker_reactor_wakeups() > before,
            "worker readiness loops must tick the wakeup counter"
        );
    }
}
