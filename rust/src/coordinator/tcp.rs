//! Loopback TCP transport: peers behind real sockets.
//!
//! Each peer (compute worker or validator shard) is a thread sitting behind
//! its own `TcpListener` on `127.0.0.1:0`; the master connects one
//! `TcpStream` per peer and speaks the [`super::wire`] protocol in
//! lockstep: one job frame out, one reply frame back, per wave. Nothing in
//! the coordinator above the [`Transport`] trait knows the difference —
//! `rust/tests/transport_equivalence.rs` proves models stay bit-identical.
//!
//! Loopback peers still share the *dataset* by `Arc` (it is process-local
//! state, not a message); jobs, snapshots and replies all cross the socket
//! as bytes. That makes this transport an honest single-host rehearsal for
//! multi-host runs: the remaining work for true remote peers is process
//! bootstrap and dataset distribution (see ROADMAP), not message-plane
//! changes.
//!
//! ## Accounting
//!
//! The master counts every frame byte written or read (`wire_bytes`) and
//! the wall-clock spent encoding jobs and decoding replies (`ser_time`);
//! [`Transport::stats`] exposes the running totals and the schedulers
//! record per-epoch deltas into [`crate::metrics::EpochRecord`].
//!
//! ## Failure behaviour
//!
//! Mirrors [`super::engine::WorkerPool`]: a peer that panics inside a job
//! replies with an error frame (the panic is caught peer-side), a wave with
//! failures is drained completely before `gather` reports the first error,
//! and `Drop` drains any outstanding wave, sends shutdown frames, closes
//! the sockets and joins every peer thread — infallibly.

use super::engine::{panic_message, run_job, Job, JobOutput};
use super::transport::{Plane, Transport, TransportStats};
use super::wire;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::runtime::ComputeBackend;
use std::cell::Cell;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One plane's master-side endpoints.
struct PlaneEndpoints {
    streams: Vec<TcpStream>,
    /// Waves scattered but not yet gathered (0 or 1).
    in_flight: Cell<usize>,
    /// Set when a scatter failed partway: some peers own a job whose reply
    /// can no longer be paired with a wave (and their streams may hold
    /// unread frames), so further scatters on this plane error out instead
    /// of silently misattributing stale replies.
    poisoned: Cell<bool>,
}

/// The loopback TCP transport.
pub struct Tcp {
    planes: [PlaneEndpoints; 2],
    handles: Vec<JoinHandle<()>>,
    wire_bytes: Cell<u64>,
    ser_time: Cell<Duration>,
}

impl Tcp {
    /// Spawn `procs` compute peers and `validators` validator peers, each
    /// behind its own loopback socket, and connect to all of them.
    pub fn spawn(
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        procs: usize,
        validators: usize,
    ) -> Result<Tcp> {
        let mut handles = Vec::with_capacity(procs + validators);
        let compute = spawn_plane(&data, &backend, procs, &mut handles)?;
        let validate = spawn_plane(&data, &backend, validators, &mut handles)?;
        Ok(Tcp {
            planes: [compute, validate],
            handles,
            wire_bytes: Cell::new(0),
            ser_time: Cell::new(Duration::ZERO),
        })
    }

    fn add_bytes(&self, n: usize) {
        self.wire_bytes.set(self.wire_bytes.get() + n as u64);
    }

    fn add_ser(&self, d: Duration) {
        self.ser_time.set(self.ser_time.get() + d);
    }
}

fn spawn_plane(
    data: &Arc<Dataset>,
    backend: &Arc<dyn ComputeBackend>,
    n: usize,
    handles: &mut Vec<JoinHandle<()>>,
) -> Result<PlaneEndpoints> {
    let mut streams = Vec::with_capacity(n);
    for id in 0..n {
        let listener = TcpListener::bind(("127.0.0.1", 0))
            .map_err(|e| Error::Coordinator(format!("tcp bind: {e}")))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Coordinator(format!("tcp local_addr: {e}")))?;
        let data = data.clone();
        let backend = backend.clone();
        handles.push(std::thread::spawn(move || peer_loop(id, data, backend, listener)));
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Coordinator(format!("tcp connect: {e}")))?;
        stream.set_nodelay(true).ok();
        streams.push(stream);
    }
    Ok(PlaneEndpoints { streams, in_flight: Cell::new(0), poisoned: Cell::new(false) })
}

/// Best-effort, bounded drain of one queued reply per stream — shutdown
/// hygiene so no peer blocks writing into a socket nobody reads. A wedged
/// peer costs at most the timeout; closing the sockets afterwards unblocks
/// it regardless.
fn drain_replies(streams: &[TcpStream]) {
    for stream in streams {
        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
        let _ = wire::read_frame(&mut &*stream);
        let _ = stream.set_read_timeout(None);
    }
}

/// One peer: accept the master's connection, then serve jobs in lockstep
/// until a shutdown frame or a closed/corrupt socket.
///
/// Failure containment mirrors the in-proc worker loop: a job that decodes
/// but cannot run (panic, bad geometry) — *and* a frame whose payload fails
/// decode validation — each produce an error *reply*, because the master
/// counts one reply per peer per wave and the frame boundary is intact
/// either way. Only a broken stream (EOF, short header/payload — we can no
/// longer find the next frame) terminates the peer.
fn peer_loop(
    id: usize,
    data: Arc<Dataset>,
    backend: Arc<dyn ComputeBackend>,
    listener: TcpListener,
) {
    let Ok((stream, _)) = listener.accept() else { return };
    stream.set_nodelay(true).ok();
    let mut stream = stream;
    loop {
        let Ok((kind, payload)) = wire::read_frame(&mut stream) else {
            return; // stream closed or framing lost
        };
        let job = if kind == wire::KIND_JOB {
            wire::decode_job(&payload)
        } else {
            Err(Error::Coordinator(format!("peer expected a job frame, got kind {kind}")))
        };
        let start = Instant::now();
        let output = match job {
            Ok(Job::Shutdown) => return,
            Ok(job) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_job(&data, &backend, job)
            }))
            .unwrap_or_else(|p| Err(Error::Coordinator(panic_message(&*p)))),
            Err(e) => Err(e), // decode-invalid job: reply, stay alive
        };
        let busy = start.elapsed();
        if wire::write_reply(&mut stream, id as u32, busy, &output).is_err() {
            return; // master gone
        }
    }
}

impl Transport for Tcp {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn peers(&self, plane: Plane) -> usize {
        self.planes[plane.idx()].streams.len()
    }

    fn scatter(&self, plane: Plane, jobs: Vec<Job>) -> Result<()> {
        let ep = &self.planes[plane.idx()];
        assert_eq!(jobs.len(), ep.streams.len(), "one job per peer");
        assert_eq!(ep.in_flight.get(), 0, "scatter with a wave still outstanding");
        if ep.poisoned.get() {
            return Err(Error::Coordinator(
                "transport plane poisoned by an earlier failed scatter".into(),
            ));
        }
        for (stream, job) in ep.streams.iter().zip(jobs) {
            let sw = Instant::now();
            let frame = match wire::job_frame(&job) {
                Ok(f) => f,
                Err(e) => {
                    // Peers that already received a job will reply, but
                    // those replies belong to no wave — poison the plane
                    // rather than risk pairing them with a later gather.
                    // (A peer-side *job* failure is different: the wave
                    // completes, `gather` reports it, the plane stays
                    // usable.)
                    ep.poisoned.set(true);
                    return Err(e);
                }
            };
            self.add_ser(sw.elapsed());
            self.add_bytes(frame.len());
            if let Err(e) = (&mut &*stream).write_all(&frame) {
                ep.poisoned.set(true);
                return Err(Error::Coordinator(format!("tcp scatter: {e}")));
            }
        }
        ep.in_flight.set(1);
        Ok(())
    }

    fn gather(&self, plane: Plane) -> Result<(Vec<JobOutput>, Duration)> {
        let ep = &self.planes[plane.idx()];
        assert_eq!(ep.in_flight.get(), 1, "gather without a scattered wave");
        let n = ep.streams.len();
        let mut outputs: Vec<Option<JobOutput>> = (0..n).map(|_| None).collect();
        let mut max_busy = Duration::ZERO;
        let mut first_err: Option<Error> = None;
        for stream in &ep.streams {
            match wire::read_frame(&mut &*stream) {
                Ok((kind, payload)) => {
                    self.add_bytes(wire::HEADER_LEN + payload.len());
                    let sw = Instant::now();
                    let reply = wire::decode_reply(kind, &payload);
                    self.add_ser(sw.elapsed());
                    match reply {
                        Ok(reply) => {
                            max_busy = max_busy.max(reply.busy);
                            match reply.output {
                                Ok(out) if reply.worker < n => {
                                    outputs[reply.worker] = Some(out);
                                }
                                Ok(_) => {
                                    first_err = first_err.or_else(|| {
                                        Some(Error::Coordinator(format!(
                                            "peer id {} out of range",
                                            reply.worker
                                        )))
                                    });
                                }
                                Err(e) => first_err = first_err.or(Some(e)),
                            }
                        }
                        Err(e) => first_err = first_err.or(Some(e)),
                    }
                }
                Err(e) => {
                    // Frame-level read failure: the stream is dead or
                    // desynchronized, so a retry wave on this plane could
                    // block forever or mispair replies — poison it.
                    // (A decode failure above leaves the stream framed and
                    // synced; the plane stays usable, like a job error.)
                    ep.poisoned.set(true);
                    first_err = first_err.or(Some(e));
                }
            }
        }
        ep.in_flight.set(0);
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok((
            outputs.into_iter().map(|o| o.expect("peer replied")).collect(),
            max_busy,
        ))
    }

    fn stats(&self) -> TransportStats {
        TransportStats { wire_bytes: self.wire_bytes.get(), ser_time: self.ser_time.get() }
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        for ep in &self.planes {
            // Drain an outstanding (successfully scattered, never
            // gathered) wave so no peer blocks writing a reply into a
            // socket nobody reads. A poisoned plane is skipped — its
            // streams may be desynced; closing them below is the only
            // safe move.
            if ep.in_flight.get() > 0 && !ep.poisoned.get() {
                drain_replies(&ep.streams);
            }
            // Shutdown frames are best-effort: a dead peer's socket just
            // errors, and closing the stream below unblocks it anyway.
            if let Ok(frame) = wire::job_frame(&Job::Shutdown) {
                for stream in &ep.streams {
                    let _ = (&mut &*stream).write_all(&frame);
                }
            }
        }
        // Close every socket (EOF for any peer that missed its shutdown
        // frame), then join.
        for ep in &mut self.planes {
            ep.streams.clear();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{split_range, split_range_chunked};
    use super::super::transport::{Cluster, Plane, Transport};
    use super::*;
    use crate::config::TransportKind;
    use crate::data::generators::{dp_clusters, GenConfig};
    use crate::linalg::Matrix;
    use crate::runtime::native::NativeBackend;

    fn data_and_backend(n: usize) -> (Arc<Dataset>, Arc<dyn ComputeBackend>) {
        let data = Arc::new(dp_clusters(&GenConfig { n, dim: 8, theta: 1.0, seed: 7 }));
        (data, Arc::new(NativeBackend::new()))
    }

    /// The same wave over TCP and in-proc must return bit-identical outputs
    /// — the whole point of the bit-exact wire format.
    #[test]
    fn tcp_wave_bitidentical_to_inproc() {
        let (data, backend) = data_and_backend(120);
        let tcp = Cluster::spawn(TransportKind::Tcp, data.clone(), backend.clone(), 3, 1)
            .unwrap();
        let inproc =
            Cluster::spawn(TransportKind::InProc, data.clone(), backend, 3, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(3));
        centers.push_row(data.point(77));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..120, 3)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let (a, _) = tcp.scatter_gather(mk()).unwrap();
        let (b, _) = inproc.scatter_gather(mk()).unwrap();
        for (x, y) in a.iter().zip(&b) {
            let (JobOutput::Nearest { idx: ia, d2: da }, JobOutput::Nearest { idx: ib, d2: db }) =
                (x, y)
            else {
                panic!("wrong output kind");
            };
            assert_eq!(ia, ib);
            let da: Vec<u32> = da.iter().map(|f| f.to_bits()).collect();
            let db: Vec<u32> = db.iter().map(|f| f.to_bits()).collect();
            assert_eq!(da, db, "d² diverged across the wire");
        }
        let stats = tcp.stats();
        assert!(stats.wire_bytes > 0, "tcp waves must be accounted");
    }

    #[test]
    fn tcp_peer_error_drains_wave_and_transport_survives() {
        let (data, backend) = data_and_backend(100);
        let tcp = Tcp::spawn(data, backend, 2, 1).unwrap();
        let short = Arc::new(vec![0u32; 10]); // panics inside the peer
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: short.clone(), k: 2 })
            .collect();
        tcp.scatter(Plane::Compute, jobs).unwrap();
        assert!(tcp.gather(Plane::Compute).is_err(), "poisoned wave must error");
        // The peers caught the panic and are still serving: a clean wave
        // works on the same connections.
        let ok = Arc::new(vec![0u32; 100]);
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: ok.clone(), k: 2 })
            .collect();
        tcp.scatter(Plane::Compute, jobs).unwrap();
        tcp.gather(Plane::Compute).unwrap();
        drop(tcp); // must not hang
    }

    #[test]
    fn tcp_drop_with_outstanding_wave_does_not_hang() {
        let (data, backend) = data_and_backend(60);
        let tcp = Tcp::spawn(data.clone(), backend, 2, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let jobs: Vec<Job> = split_range(0..60, 2)
            .into_iter()
            .map(|range| Job::Nearest { range, centers: centers.clone() })
            .collect();
        tcp.scatter(Plane::Compute, jobs).unwrap();
        drop(tcp); // wave never gathered; drop drains and joins
    }
}
