//! TCP transport: peers behind real sockets — threads, or whole processes
//! on other machines.
//!
//! Every peer (compute worker or validator shard) sits behind a socket and
//! speaks the [`super::wire`] protocol. A session opens with a versioned
//! [`wire::Hello`] handshake (role, shard assignment, dataset geometry),
//! after which the master interleaves dataset-block frames and job frames:
//! one job out, one reply back, per wave. Nothing in the coordinator above
//! the [`Transport`] trait knows the difference —
//! `rust/tests/transport_equivalence.rs` proves models stay bit-identical.
//!
//! Peers come in two flavours, one protocol:
//!
//! * **Loopback thread peers** — `Tcp::spawn` with no addresses binds one
//!   ephemeral listener per peer and serves [`serve_peer`] from a thread of
//!   this process. This is the default and what CI's `OCCML_TRANSPORT=tcp`
//!   job exercises: the full handshake + dataset-shipping path, in one
//!   process.
//! * **Addressed remote peers** — a `peers = ["host:port", ...]` topology
//!   connects to standalone `occd worker` processes (the same
//!   [`serve_peer`] loop behind a real `TcpListener`; see `occd worker
//!   --help` and the README runbook). Nothing is shared by `Arc`: the
//!   dataset crosses the wire too.
//!
//! ## Dataset shipping
//!
//! Workers do not share the dataset by `Arc` (that was the PR 2 gap): the
//! master ships [`wire::KIND_DATA`] block frames on demand, tracked by a
//! per-peer [`Coverage`] set. Before a job is written, exactly the missing
//! sub-ranges of [`Job::data_range`] are shipped — so each worker receives
//! precisely the point ranges it computes (its epoch blocks plus its
//! reduction stripe, ~2·n/P per pass), and validator peers — whose
//! `PairCache` jobs carry their proposal rows inline — receive none.
//! Shipped bytes are accounted in [`TransportStats::dataset_bytes`],
//! handshake wall-clock in [`TransportStats::handshake_time`].
//!
//! ## Snapshot delta-shipping (the per-epoch wire diet)
//!
//! Epoch snapshots (`C^{t-1}` centers / features) no longer ride inside
//! every job frame. Each peer *session* keeps a single-entry snapshot
//! cache — `(id, matrix)` — mirrored master-side in `Peer::snap`, and jobs
//! reference the snapshot by id ([`wire::snapref_job_frame`]). Before a
//! referencing frame is written, `ensure_snapshot` makes the session hold
//! that id:
//!
//! * **nothing** ships when the session already holds it (a speculative
//!   wave against unchanged state, or a resend);
//! * a [`wire::SnapshotDelta`] ships when the held snapshot is a bit-exact
//!   *prefix* — between epochs of a pass the committed state only appends
//!   rows, so the delta is just the accepted rows, `O(ΔK·d)` instead of
//!   `O(K·d)` per peer per epoch;
//! * a full [`wire::KIND_SNAPSHOT`] frame ships otherwise — a cold cache
//!   (first wave, or a replacement peer after a reconnect, whose handshake
//!   clears both mirrors) or a rewritten prefix (the mean-recompute /
//!   BP re-estimate pass boundary). Counted in
//!   [`TransportStats::full_snapshot_fallbacks`].
//!
//! Reconstruction is bit-exact by construction — both directions move raw
//! f32 bit patterns, and the peer re-bases only against the exact `(id,
//! rows)` the master installed (any mismatch is a typed error surfaced on
//! the next referencing job). Classifications and encodings are memoized
//! per wave ([`SnapMemo`]), so master-side encode effort stays
//! `O(snapshot)`, not `O(P · snapshot)` — the delta-era successor of the
//! PR 3 splice cache, which still serves the reduction waves' shared
//! assignment vectors. `Topology::frugal_wire = false` restores the PR 3
//! embed-everything shape as the A/B baseline.
//!
//! ## Out-of-order gather
//!
//! `gather` no longer reads replies in fixed peer order: every live socket
//! goes nonblocking and a small poll loop ([`wire::poll_frame`] over
//! per-peer buffers) retires replies as they *arrive*, so one straggler no
//! longer serializes the whole wave behind it. Outputs are still slotted
//! by peer id — determinism is untouched. Idle time waiting on the slowest
//! peers is accounted in [`TransportStats::gather_wait_time`];
//! reconnect/poison semantics are unchanged (failed peers drop out of the
//! sweep and take the same bounded recovery path afterwards).
//!
//! ## Failure behaviour
//!
//! A peer-side *job* failure (panic, bad geometry, undecodable payload)
//! surfaces as an error reply; the wave is drained completely before
//! `gather` reports the first error and the transport stays usable — same
//! contract as [`super::engine::WorkerPool`].
//!
//! A *dead peer* (process killed, connection dropped) poisons only its
//! wave, not the run: the master keeps each scattered frame until its reply
//! arrives, and on a broken stream it makes a bounded number of reconnect
//! attempts (`reconnect_attempts`, [`RECONNECT_DELAY`] apart) to the peer's
//! address. A replacement worker on the same address is re-handshaken,
//! re-shipped the dataset ranges the retained job needs, and handed the
//! frame again — jobs are deterministic, so the wave completes bit-exactly
//! as if nothing happened. If the bound is exhausted, `gather` returns a
//! typed error with the rest of the wave drained (never a deadlock — the
//! regression class of the PR 2 gather fix), and the next scatter will try
//! the address again. Loopback thread peers cannot be re-sessioned; losing
//! one poisons the plane, as before. `Drop` drains any outstanding wave,
//! sends shutdown frames, closes every socket and joins the peer threads —
//! infallibly.

use super::engine::{panic_message, run_job, Job, JobOutput, JobReply};
use super::transport::{Plane, Topology, Transport, TransportStats};
use super::wire::{self, Hello, HelloAck, PeerRole};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::ComputeBackend;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Delay between reconnect attempts to a dropped remote peer.
pub const RECONNECT_DELAY: Duration = Duration::from_millis(250);

/// Points per dataset-block frame: bounds any single frame to
/// `16384 · d · 4` payload bytes (256 MiB at the `dim ≤ 4096` config cap),
/// comfortably under [`wire::MAX_FRAME`].
pub const DATA_BLOCK_POINTS: usize = 16_384;

// ---------------------------------------------------------------------------
// Coverage: which point ranges a peer holds
// ---------------------------------------------------------------------------

/// A set of disjoint, sorted point ranges — which parts of the dataset a
/// peer has been shipped (master side) or has installed (peer side).
#[derive(Debug, Clone, Default)]
pub struct Coverage {
    spans: Vec<Range<usize>>,
}

impl Coverage {
    /// Add a range, merging with overlapping or adjacent spans.
    pub fn add(&mut self, r: Range<usize>) {
        if r.start >= r.end {
            return;
        }
        self.spans.push(r);
        self.spans.sort_by_key(|s| s.start);
        let mut merged: Vec<Range<usize>> = Vec::with_capacity(self.spans.len());
        for s in self.spans.drain(..) {
            match merged.last_mut() {
                Some(last) if s.start <= last.end => last.end = last.end.max(s.end),
                _ => merged.push(s),
            }
        }
        self.spans = merged;
    }

    /// True if every point of `r` is covered.
    pub fn covers(&self, r: &Range<usize>) -> bool {
        r.start >= r.end || self.spans.iter().any(|s| s.start <= r.start && r.end <= s.end)
    }

    /// The sub-ranges of `r` not yet covered, in order.
    pub fn missing(&self, r: &Range<usize>) -> Vec<Range<usize>> {
        let mut out = Vec::new();
        let mut at = r.start;
        for s in &self.spans {
            if at >= r.end {
                break;
            }
            if s.end <= at {
                continue;
            }
            if s.start >= r.end {
                break;
            }
            if s.start > at {
                out.push(at..s.start.min(r.end));
            }
            at = at.max(s.end);
        }
        if at < r.end {
            out.push(at..r.end);
        }
        out
    }

    /// Forget everything (a fresh peer session holds nothing).
    pub fn clear(&mut self) {
        self.spans.clear();
    }
}

// ---------------------------------------------------------------------------
// Peer side: the serve loop behind `occd worker` and loopback threads
// ---------------------------------------------------------------------------

/// Serve one master session on an accepted connection: a [`wire::Hello`]
/// handshake, then dataset blocks and jobs in the master's order until a
/// shutdown frame or EOF. This is the single peer loop behind standalone
/// `occd worker` processes *and* the loopback thread peers [`Tcp::spawn`]
/// creates — one code path, so every in-process TCP test exercises the real
/// multi-host protocol.
///
/// Failure containment: a job that decodes but cannot run (panic, bad
/// geometry), a job whose payload fails decode validation, and a job whose
/// data range was never shipped each produce an error *reply* — the frame
/// boundary is intact, the master counts one reply per peer per wave, and
/// the session stays alive. Only a broken stream (EOF, framing lost)
/// terminates the session; that returns `Ok` because it is how masters
/// normally leave.
pub fn serve_peer(stream: TcpStream, backend: Arc<dyn ComputeBackend>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut stream = stream;
    // Handshake: the first frame must be a Hello carrying this peer's shard
    // assignment and the dataset geometry. It is read version-tolerantly so
    // a coordinator built at a different wire version gets a reportable
    // rejection ack instead of a silent hangup.
    let (version, kind, payload) = wire::read_frame_any_version(&mut stream)?;
    if version != wire::VERSION {
        let ack = HelloAck {
            proto: wire::VERSION,
            ok: false,
            message: format!("peer speaks wire version {}, got {version}", wire::VERSION),
        };
        if let Ok(f) = wire::hello_ack_frame(&ack) {
            let _ = stream.write_all(&f);
        }
        return Err(Error::Coordinator(format!(
            "coordinator speaks wire version {version}, this peer speaks {}",
            wire::VERSION
        )));
    }
    if kind != wire::KIND_HELLO {
        return Err(Error::Coordinator(format!(
            "peer expected a hello frame, got kind {kind}"
        )));
    }
    let hello = match wire::decode_hello(&payload) {
        Ok(h) => h,
        Err(e) => {
            // Tell the master why (version mismatch, corrupt hello) before
            // giving up on the session.
            let ack =
                HelloAck { proto: wire::VERSION, ok: false, message: e.to_string() };
            if let Ok(f) = wire::hello_ack_frame(&ack) {
                let _ = stream.write_all(&f);
            }
            return Err(e);
        }
    };
    let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
    stream.write_all(&wire::hello_ack_frame(&ack)?)?;

    // Local dataset store, assembled from shipped blocks. Allocated lazily
    // on the first block: validator peers never receive one and so never
    // pay for an n × d matrix.
    let mut store: Option<Dataset> = None;
    let mut covered = Coverage::default();
    let mut data_err: Option<String> = None;
    // The session's single-entry snapshot cache: the `(id, matrix)` the
    // master last installed, which snapshot-referencing jobs resolve
    // against and delta frames re-base. A failed install is remembered and
    // surfaced on the next job that references a snapshot — the frame
    // boundary stays intact either way.
    let mut snap: Option<(u64, Arc<Matrix>)> = None;
    let mut snap_err: Option<String> = None;
    let empty = Dataset { points: Matrix::zeros(0, 0), labels: None };

    loop {
        let Ok((kind, payload)) = wire::read_frame(&mut stream) else {
            return Ok(()); // master gone (EOF) or framing lost
        };
        match kind {
            wire::KIND_DATA => {
                if let Err(e) = install_block(&hello, &payload, &mut store, &mut covered) {
                    // The frame boundary is intact; remember the failure and
                    // surface it on the next job that needs the data.
                    data_err = Some(e.to_string());
                }
            }
            wire::KIND_SNAPSHOT => match wire::decode_snapshot(&payload) {
                Ok((id, m)) => {
                    snap = Some((id, Arc::new(m)));
                    snap_err = None;
                }
                Err(e) => snap_err = Some(e.to_string()),
            },
            wire::KIND_SNAPSHOT_DELTA => {
                let applied = wire::decode_snapshot_delta(&payload).and_then(|d| {
                    let (held, base) = snap.as_ref().ok_or_else(|| {
                        Error::Coordinator(
                            "snapshot delta arrived with no cached base".into(),
                        )
                    })?;
                    Ok((d.id, d.apply(*held, base)?))
                });
                match applied {
                    Ok((id, m)) => {
                        snap = Some((id, Arc::new(m)));
                        snap_err = None;
                    }
                    Err(e) => snap_err = Some(e.to_string()),
                }
            }
            wire::KIND_JOB => {
                let job = wire::decode_job_snap(&payload, snap.as_ref()).map_err(|e| {
                    // A reference that cannot resolve is most useful with
                    // the install failure that caused it attached.
                    match &snap_err {
                        Some(se) => Error::Coordinator(format!(
                            "{e}; last snapshot frame failed: {se}"
                        )),
                        None => e,
                    }
                });
                let start = Instant::now();
                let output = match job {
                    Ok(Job::Shutdown) => return Ok(()),
                    Ok(job) => run_covered(&job.data_range(), &data_err, &store, &covered)
                        .and_then(|data| {
                            let data = data.unwrap_or(&empty);
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                run_job(data, &backend, job)
                            }))
                            .unwrap_or_else(|p| Err(Error::Coordinator(panic_message(&*p))))
                        }),
                    Err(e) => Err(e), // decode-invalid job: reply, stay alive
                };
                let busy = start.elapsed();
                if wire::write_reply(&mut stream, hello.peer_id, busy, &output).is_err() {
                    return Ok(()); // master gone
                }
            }
            other => {
                // An unexpected frame kind mid-session means the streams
                // are not speaking the same dialogue; bail out rather than
                // risk a desynchronized reply pairing.
                return Err(Error::Coordinator(format!(
                    "peer got unexpected frame kind {other} mid-session"
                )));
            }
        }
    }
}

/// Check a job's data needs against the peer's store; returns the dataset
/// to run against (`None` for jobs that read no points).
fn run_covered<'a>(
    need: &Option<Range<usize>>,
    data_err: &Option<String>,
    store: &'a Option<Dataset>,
    covered: &Coverage,
) -> Result<Option<&'a Dataset>> {
    let Some(range) = need else { return Ok(None) };
    if range.start >= range.end {
        return Ok(None); // an empty block reads no points (tail epochs)
    }
    if let Some(e) = data_err {
        return Err(Error::Coordinator(format!("dataset block error: {e}")));
    }
    match store {
        Some(ds) if covered.covers(range) => Ok(Some(ds)),
        _ => Err(Error::Coordinator(format!(
            "job range {}..{} not covered by shipped dataset blocks",
            range.start, range.end
        ))),
    }
}

/// Install one dataset-block frame into the peer's store.
fn install_block(
    hello: &Hello,
    payload: &[u8],
    store: &mut Option<Dataset>,
    covered: &mut Coverage,
) -> Result<()> {
    let (offset, block) = wire::decode_data_block(payload)?;
    let n = hello.n as usize;
    let d = hello.dim as usize;
    let end = offset
        .checked_add(block.rows)
        .ok_or_else(|| Error::Coordinator("dataset block offset overflow".into()))?;
    if block.cols != d || end > n {
        return Err(Error::Coordinator(format!(
            "dataset block {offset}..{end} ({} cols) outside the {n} x {d} geometry",
            block.cols
        )));
    }
    // Same plausibility cap as `.occb` loading: refuse to allocate a store
    // for a nonsensical geometry.
    if n.checked_mul(d).is_none() || n * d > (1 << 33) {
        return Err(Error::Coordinator(format!("implausible dataset geometry {n} x {d}")));
    }
    // Dense full-size store, filled sparsely: global point indices stay
    // valid for the shared job executor at the cost of allocating n × d
    // zeros even though only ~2·n/P rows ever arrive. Fine for RAM-sized
    // data; an offset-keyed block store is the ROADMAP item for datasets
    // that only fit sharded.
    let ds = store.get_or_insert_with(|| Dataset {
        points: Matrix::zeros(n, d),
        labels: None,
    });
    ds.points.data[offset * d..end * d].copy_from_slice(&block.data);
    covered.add(offset..end);
    Ok(())
}

// ---------------------------------------------------------------------------
// Master side
// ---------------------------------------------------------------------------

/// The master's handle on one peer.
struct Peer {
    /// Live session stream, if any.
    stream: Option<TcpStream>,
    /// Remote address for reconnects; `None` marks a loopback thread peer,
    /// which cannot be re-sessioned.
    addr: Option<String>,
    /// The handshake this peer's sessions are opened with.
    hello: Hello,
    /// Dataset ranges shipped in the current session.
    sent: Coverage,
    /// The snapshot `(id, matrix)` the current session holds — the master's
    /// mirror of the peer's single-entry snapshot cache, which is what
    /// makes delta shipping sound: a delta is only sent against a base the
    /// master itself installed. Cleared with every handshake (a replacement
    /// peer starts empty and is re-based from a full frame).
    snap: Option<(u64, Arc<Matrix>)>,
}

impl Peer {
    fn describe(&self) -> String {
        match &self.addr {
            Some(a) => format!("{} peer {} ({a})", self.hello.role.name(), self.hello.peer_id),
            None => format!("loopback {} peer {}", self.hello.role.name(), self.hello.peer_id),
        }
    }
}

/// One retained scattered job: the encoded frame (kept for resend after a
/// reconnect), the dataset range it reads, and the snapshot its frame
/// references (kept so a replacement session can be re-based — by a full
/// frame — before the retained frame is resent).
struct WaveJob {
    frame: Vec<u8>,
    need: Option<Range<usize>>,
    snap: Option<(u64, Arc<Matrix>)>,
}

/// One plane's master-side state.
struct PlaneEndpoints {
    peers: RefCell<Vec<Peer>>,
    /// The outstanding wave's retained jobs (empty between waves).
    wave: RefCell<Vec<WaveJob>>,
    /// Waves scattered but not yet gathered (0 or 1).
    in_flight: Cell<usize>,
    /// Set when a loopback thread peer's stream broke: its replies can no
    /// longer be trusted to pair with any wave and it cannot be
    /// re-sessioned, so further scatters on the plane error out.
    poisoned: Cell<bool>,
}

impl PlaneEndpoints {
    fn new() -> PlaneEndpoints {
        PlaneEndpoints {
            peers: RefCell::new(Vec::new()),
            wave: RefCell::new(Vec::new()),
            in_flight: Cell::new(0),
            poisoned: Cell::new(false),
        }
    }
}

/// Handshake + wire accounting accumulated before the `Tcp` value exists.
#[derive(Default)]
struct SpawnAccounting {
    wire_bytes: u64,
    handshake_time: Duration,
}

/// How one wave's snapshot relates to a peer's cached base — computed once
/// per `(snapshot, base)` pair per wave and memoized, since every peer of a
/// plane usually shares the same cache state.
#[derive(Clone, Copy, PartialEq, Eq)]
enum SnapRelation {
    /// Bit-identical content: nothing to ship, jobs reference the held id.
    Identical,
    /// The base is a bit-exact prefix: ship only the appended rows.
    Extends,
    /// Prefix rewritten (mean recompute), shrunk, or reshaped: full frame.
    Unrelated,
}

/// Per-scatter memo for snapshot shipping: one classification and one
/// encoding per distinct `(snapshot, base)` pair, spliced to every peer
/// that shares the state — the delta-era successor of the PR 3 splice
/// cache, so master-side encode effort stays `O(snapshot)`, not
/// `O(P · snapshot)`.
#[derive(Default)]
struct SnapMemo {
    /// Wave-assigned snapshot id per distinct `Arc` allocation.
    ids: HashMap<usize, u64>,
    /// `(snapshot ptr, base id)` → relation.
    relations: HashMap<(usize, u64), SnapRelation>,
    /// `(snapshot id)` → encoded full frame.
    fulls: HashMap<u64, Vec<u8>>,
    /// `(snapshot id, base id)` → encoded delta frame.
    deltas: HashMap<(u64, u64), Vec<u8>>,
}

/// The snapshot matrix a job embeds, if any: the epoch state that frugal
/// shipping moves as delta frames instead of embedding per job. `PairCache`
/// vectors are deliberately *not* treated as snapshots — a fresh proposal
/// matrix every epoch has no delta to exploit; its wire diet is the row
/// subset built by [`super::transport::Cluster::pair_cache`].
fn job_snapshot(job: &Job) -> Option<&Arc<Matrix>> {
    match job {
        Job::Nearest { centers, .. } => Some(centers),
        Job::BpDescend { features, .. } => Some(features),
        _ => None,
    }
}

/// Classify how `new` relates to the `base` a peer holds, bit-exactly.
fn snap_relation(base: &Matrix, new: &Matrix) -> SnapRelation {
    if base.cols != new.cols && base.rows > 0 && new.rows > 0 {
        return SnapRelation::Unrelated;
    }
    if base.rows > new.rows {
        return SnapRelation::Unrelated;
    }
    // f32 slices compare by bits here: the matrices were built from
    // identical computations, so any difference shows up in the bytes the
    // wire would carry. NaN payloads never arise in committed state, and a
    // NaN != NaN miscompare would only cost an unnecessary full ship — it
    // can never produce a wrong delta.
    if base.data[..] != new.data[..base.rows * base.cols] {
        return SnapRelation::Unrelated;
    }
    if base.rows == new.rows {
        SnapRelation::Identical
    } else {
        SnapRelation::Extends
    }
}

/// The TCP transport.
pub struct Tcp {
    planes: [PlaneEndpoints; 2],
    handles: Vec<JoinHandle<()>>,
    data: Arc<Dataset>,
    reconnect_attempts: usize,
    /// Snapshot delta-shipping + validator row-subset shipping (default);
    /// `false` restores the PR 3 embed-everything wire shape for A/B runs.
    frugal: bool,
    /// Monotone snapshot-id source (ids are never reused, so a stale
    /// reference can only miss, never alias).
    next_snap_id: Cell<u64>,
    wire_bytes: Cell<u64>,
    unique_bytes: Cell<u64>,
    ser_time: Cell<Duration>,
    dataset_bytes: Cell<u64>,
    delta_bytes: Cell<u64>,
    full_snapshot_fallbacks: Cell<u64>,
    handshake_time: Cell<Duration>,
    gather_wait: Cell<Duration>,
}

impl Tcp {
    /// Spawn `procs` compute peers and `validators` validator peers as
    /// loopback threads, each behind its own ephemeral socket.
    pub fn spawn(
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        procs: usize,
        validators: usize,
    ) -> Result<Tcp> {
        Tcp::spawn_topology(data, backend, &Topology::local(procs, validators))
    }

    /// Spawn the transport a topology describes: per plane, either connect
    /// to the listed `host:port` peers (standalone `occd worker`
    /// processes) or spawn that many loopback thread peers.
    pub fn spawn_topology(
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        topo: &Topology,
    ) -> Result<Tcp> {
        let mut handles = Vec::new();
        let mut acct = SpawnAccounting::default();
        let compute = init_plane(
            &data,
            &backend,
            PeerRole::Compute,
            topo.procs,
            &topo.compute_peers,
            topo.reconnect_attempts,
            &mut handles,
            &mut acct,
        )?;
        let validate = init_plane(
            &data,
            &backend,
            PeerRole::Validate,
            topo.validators,
            &topo.validator_peers,
            topo.reconnect_attempts,
            &mut handles,
            &mut acct,
        )?;
        Ok(Tcp {
            planes: [compute, validate],
            handles,
            data,
            reconnect_attempts: topo.reconnect_attempts,
            frugal: topo.frugal_wire,
            next_snap_id: Cell::new(1),
            wire_bytes: Cell::new(acct.wire_bytes),
            unique_bytes: Cell::new(acct.wire_bytes), // handshakes encode once
            ser_time: Cell::new(Duration::ZERO),
            dataset_bytes: Cell::new(0),
            delta_bytes: Cell::new(0),
            full_snapshot_fallbacks: Cell::new(0),
            handshake_time: Cell::new(acct.handshake_time),
            gather_wait: Cell::new(Duration::ZERO),
        })
    }

    /// Account bytes that crossed the wire *and* passed the encoder once.
    fn add_bytes(&self, n: usize) {
        self.add_wire(n);
        self.add_unique(n);
    }

    /// Account bytes that crossed the wire (unconditionally).
    fn add_wire(&self, n: usize) {
        self.wire_bytes.set(self.wire_bytes.get() + n as u64);
    }

    /// Account bytes that passed the encoder exactly once (splice/delta
    /// reuse across peers writes the same bytes again without re-encoding —
    /// those copies count in `wire_bytes` only).
    fn add_unique(&self, n: usize) {
        self.unique_bytes.set(self.unique_bytes.get() + n as u64);
    }

    fn add_ser(&self, d: Duration) {
        self.ser_time.set(self.ser_time.get() + d);
    }

    /// One fresh-session attempt to a remote peer: connect, handshake
    /// (which resets the shipped-coverage tracking — a replacement worker
    /// starts empty), account the cost. The peer's stream is `None` on
    /// failure.
    fn open_session(&self, peer: &mut Peer) -> Result<()> {
        peer.stream = None;
        let addr = peer.addr.clone().expect("open_session is remote-only");
        let stream = TcpStream::connect(&addr)
            .map_err(|e| Error::Coordinator(format!("tcp connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        peer.stream = Some(stream);
        match do_handshake(peer) {
            Ok((bytes, took)) => {
                self.add_bytes(bytes);
                self.handshake_time.set(self.handshake_time.get() + took);
                Ok(())
            }
            Err(e) => {
                peer.stream = None;
                Err(e)
            }
        }
    }

    /// Re-open a dead remote peer's session under the bounded reconnect
    /// policy.
    fn reconnect(&self, peer: &mut Peer) -> Result<()> {
        if peer.addr.is_none() {
            return Err(Error::Coordinator(format!(
                "{} died and loopback thread peers cannot be re-sessioned",
                peer.describe()
            )));
        }
        peer.stream = None;
        let mut last: Option<Error> = None;
        for attempt in 0..self.reconnect_attempts {
            if attempt > 0 {
                std::thread::sleep(RECONNECT_DELAY);
            }
            match self.open_session(peer) {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
        }
        Err(Error::Coordinator(format!(
            "{} unreachable after {} reconnect attempts: {}",
            peer.describe(),
            self.reconnect_attempts,
            last.map(|e| e.to_string()).unwrap_or_else(|| "reconnect disabled".into())
        )))
    }

    /// Ship the sub-ranges of `need` this peer's session has not seen, in
    /// bounded block frames.
    fn ship_missing(&self, peer: &mut Peer, need: &Range<usize>) -> Result<()> {
        for span in peer.sent.missing(need) {
            let d = self.data.dim();
            let mut lo = span.start;
            while lo < span.end {
                let hi = (lo + DATA_BLOCK_POINTS).min(span.end);
                let sw = Instant::now();
                let block = Matrix {
                    rows: hi - lo,
                    cols: d,
                    data: self.data.points.data[lo * d..hi * d].to_vec(),
                };
                let frame = wire::data_frame(lo, &block)?;
                self.add_ser(sw.elapsed());
                self.add_bytes(frame.len());
                self.dataset_bytes
                    .set(self.dataset_bytes.get() + (frame.len() - wire::HEADER_LEN) as u64);
                let stream = peer
                    .stream
                    .as_mut()
                    .ok_or_else(|| Error::Coordinator("peer has no live session".into()))?;
                stream
                    .write_all(&frame)
                    .map_err(|e| Error::Coordinator(format!("tcp data ship: {e}")))?;
                lo = hi;
            }
            peer.sent.add(span);
        }
        Ok(())
    }

    /// Make the peer's session hold snapshot `id` (= `m`) before a frame
    /// referencing it is written. Three outcomes, decided against the
    /// master-side mirror of the peer's cache and memoized per wave:
    ///
    /// * the session already holds `id` — nothing to ship (a resend, or a
    ///   speculative wave whose state did not change);
    /// * the held snapshot is a bit-exact *prefix* of `m` — ship a
    ///   [`wire::SnapshotDelta`] carrying only the appended rows;
    /// * anything else (cold cache after a handshake, rewritten prefix) —
    ///   ship a full [`wire::KIND_SNAPSHOT`] frame, counted in
    ///   [`TransportStats::full_snapshot_fallbacks`].
    ///
    /// The peer reconstructs bit-exactly by construction (raw f32 bit
    /// patterns both ways), and `peer.snap` is only advanced after the
    /// write succeeded — a broken write leaves the mirror cleared, so the
    /// next ship re-bases in full instead of trusting a half-installed
    /// cache.
    fn ensure_snapshot(
        &self,
        peer: &mut Peer,
        id: u64,
        m: &Arc<Matrix>,
        memo: &mut SnapMemo,
    ) -> Result<()> {
        if let Some((held, _)) = &peer.snap {
            if *held == id {
                return Ok(());
            }
        }
        let key = Arc::as_ptr(m) as usize;
        let sw = Instant::now();
        // Delta-eligible base, if the held snapshot is a bit-exact prefix
        // of (or identical to) `m`. Identical content still re-installs
        // under the new id when the job frame references it: a zero-row
        // delta, header-sized on the wire.
        let rebase: Option<(u64, usize)> = match &peer.snap {
            Some((base_id, base)) => {
                let rel = *memo
                    .relations
                    .entry((key, *base_id))
                    .or_insert_with(|| snap_relation(base, m));
                if rel == SnapRelation::Unrelated {
                    None
                } else {
                    Some((*base_id, base.rows))
                }
            }
            None => None,
        };
        // The memoized frame is *borrowed*, not cloned: the bytes encode
        // once per wave and every peer writes the same buffer, so per-wave
        // memcpy stays O(snapshot), not O(P · snapshot).
        let (frame, is_delta): (&[u8], bool) = match rebase {
            Some((base_id, base_rows)) => {
                let frame = match memo.deltas.entry((id, base_id)) {
                    std::collections::hash_map::Entry::Occupied(e) => &*e.into_mut(),
                    std::collections::hash_map::Entry::Vacant(e) => {
                        let d = m.cols;
                        let tail = Matrix {
                            rows: m.rows - base_rows,
                            cols: d,
                            data: m.data[base_rows * d..].to_vec(),
                        };
                        let delta = wire::SnapshotDelta { id, base_id, base_rows, tail };
                        let bytes = wire::snapshot_delta_frame(&delta)?;
                        self.add_unique(bytes.len());
                        &*e.insert(bytes)
                    }
                };
                (frame, true)
            }
            None => {
                let frame = match memo.fulls.entry(id) {
                    std::collections::hash_map::Entry::Occupied(e) => &*e.into_mut(),
                    std::collections::hash_map::Entry::Vacant(e) => {
                        let bytes = wire::snapshot_frame(id, m)?;
                        self.add_unique(bytes.len());
                        &*e.insert(bytes)
                    }
                };
                (frame, false)
            }
        };
        self.add_ser(sw.elapsed());
        peer.snap = None; // cleared until the write proves out
        let stream = peer
            .stream
            .as_mut()
            .ok_or_else(|| Error::Coordinator("peer has no live session".into()))?;
        stream
            .write_all(&frame)
            .map_err(|e| Error::Coordinator(format!("tcp snapshot ship: {e}")))?;
        // Accounted only after the write succeeded: a broken write is
        // retried on a fresh session by `deliver`, and counting the failed
        // attempt would double-book the install (and break the strict
        // `full_snapshot_fallbacks` equalities the tests assert).
        self.add_wire(frame.len());
        if is_delta {
            self.delta_bytes
                .set(self.delta_bytes.get() + (frame.len() - wire::HEADER_LEN) as u64);
        } else {
            self.full_snapshot_fallbacks.set(self.full_snapshot_fallbacks.get() + 1);
        }
        peer.snap = Some((id, m.clone()));
        Ok(())
    }

    /// The snapshot id a peer's job frame should reference: the id its
    /// session already holds when the content is bit-identical (no ship at
    /// all — the speculative-wave fast path), otherwise this wave's id for
    /// the matrix (allocated once per distinct `Arc` per wave).
    fn snap_ref_id(&self, peer: &Peer, m: &Arc<Matrix>, memo: &mut SnapMemo) -> u64 {
        let key = Arc::as_ptr(m) as usize;
        if let Some((held, base)) = &peer.snap {
            let rel = *memo
                .relations
                .entry((key, *held))
                .or_insert_with(|| snap_relation(base, m));
            if rel == SnapRelation::Identical {
                return *held;
            }
        }
        *memo.ids.entry(key).or_insert_with(|| {
            let id = self.next_snap_id.get();
            self.next_snap_id.set(id + 1);
            id
        })
    }

    /// Ship a wave job's data needs and snapshot, then write its frame.
    fn write_wave_job(&self, peer: &mut Peer, wj: &WaveJob, memo: &mut SnapMemo) -> Result<()> {
        if let Some(need) = &wj.need {
            self.ship_missing(peer, need)?;
        }
        if let Some((id, m)) = &wj.snap {
            self.ensure_snapshot(peer, *id, m, memo)?;
        }
        let stream = peer
            .stream
            .as_mut()
            .ok_or_else(|| Error::Coordinator("peer has no live session".into()))?;
        stream
            .write_all(&wj.frame)
            .map_err(|e| Error::Coordinator(format!("tcp scatter: {e}")))?;
        // Post-write, like the snapshot accounting above: a failed write is
        // retried on a fresh session by `deliver`, and pre-write accounting
        // would double-book the frame.
        self.add_wire(wj.frame.len());
        Ok(())
    }

    /// Deliver one wave job, reconnecting a dead remote peer (bounded) and
    /// retrying the delivery once on a fresh session.
    fn deliver(&self, peer: &mut Peer, wj: &WaveJob, memo: &mut SnapMemo) -> Result<()> {
        if peer.stream.is_none() {
            self.reconnect(peer)?;
        }
        match self.write_wave_job(peer, wj, memo) {
            Ok(()) => Ok(()),
            Err(_) if peer.addr.is_some() => {
                self.reconnect(peer)?;
                self.write_wave_job(peer, wj, memo)
            }
            Err(e) => Err(e),
        }
    }

    /// Read one reply frame off a peer's stream.
    fn read_reply(&self, peer: &Peer) -> Result<JobReply> {
        let Some(stream) = &peer.stream else {
            return Err(Error::Coordinator(format!(
                "{} has no live session",
                peer.describe()
            )));
        };
        let (kind, payload) = wire::read_frame(&mut &*stream)?;
        self.add_bytes(wire::HEADER_LEN + payload.len());
        let sw = Instant::now();
        let reply = wire::decode_reply(kind, &payload);
        self.add_ser(sw.elapsed());
        reply
    }

    /// The gather-side recovery path: the peer's stream died mid-wave.
    /// Bounded reconnect attempts; each successful session is re-shipped
    /// the retained job's data ranges and snapshot (a full re-base — the
    /// replacement's cache is empty), resent the frame, and read for the
    /// reply. Jobs are deterministic, so the recovered reply is exactly
    /// what the lost peer would have sent.
    fn recover_and_resend(&self, peer: &mut Peer, wj: &WaveJob) -> Result<JobReply> {
        let mut last: Option<Error> = None;
        for attempt in 0..self.reconnect_attempts {
            if attempt > 0 {
                std::thread::sleep(RECONNECT_DELAY);
            }
            let mut memo = SnapMemo::default();
            let res = self.open_session(peer).and_then(|()| {
                self.write_wave_job(peer, wj, &mut memo)?;
                self.read_reply(peer)
            });
            match res {
                Ok(r) => return Ok(r),
                Err(e) => {
                    peer.stream = None;
                    last = Some(e);
                }
            }
        }
        Err(Error::Coordinator(format!(
            "{} dropped mid-wave and stayed unreachable after {} reconnect attempts: {}",
            peer.describe(),
            self.reconnect_attempts,
            last.map(|e| e.to_string()).unwrap_or_else(|| "reconnect disabled".into())
        )))
    }

    /// Retire replies for jobs already delivered when a scatter failed
    /// partway, so the wave is fully drained and the plane stays usable. A
    /// peer whose reply cannot be drained loses its session (remote) or
    /// poisons the plane (loopback thread peer).
    fn abort_scatter(&self, ep: &PlaneEndpoints, peers: &mut [Peer], delivered: usize) {
        for p in peers[..delivered].iter_mut() {
            if !drain_one(p) {
                match p.addr {
                    Some(_) => p.stream = None,
                    None => ep.poisoned.set(true),
                }
            }
        }
        ep.wave.borrow_mut().clear();
    }
}

/// Best-effort, bounded drain of one queued reply — shutdown/abort hygiene
/// so no peer blocks writing into a socket nobody reads. Returns false if
/// the reply could not be read within the timeout.
fn drain_one(peer: &Peer) -> bool {
    let Some(stream) = &peer.stream else { return true };
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let ok = wire::read_frame(&mut &*stream).is_ok();
    let _ = stream.set_read_timeout(None);
    ok
}

/// Open one peer's session: write the hello, await the ack, reset the
/// shipped coverage. Returns `(wire bytes, handshake wall-clock)`.
fn do_handshake(peer: &mut Peer) -> Result<(usize, Duration)> {
    let sw = Instant::now();
    let frame = wire::hello_frame(&peer.hello)?;
    let stream = peer
        .stream
        .as_mut()
        .ok_or_else(|| Error::Coordinator("handshake needs a live stream".into()))?;
    stream
        .write_all(&frame)
        .map_err(|e| Error::Coordinator(format!("tcp hello: {e}")))?;
    stream.flush().ok();
    let mut bytes = frame.len();
    // Version-tolerant read: a peer built at a different wire version acks
    // with *its* frame version, and we still want to decode and report it
    // (the ack payload layout is the frozen negotiation anchor).
    let (_version, kind, payload) = wire::read_frame_any_version(stream)?;
    bytes += wire::HEADER_LEN + payload.len();
    let ack = wire::decode_hello_ack(kind, &payload)?;
    if !ack.ok {
        return Err(Error::Coordinator(format!(
            "{} rejected the session (peer wire version {}): {}",
            peer.describe(),
            ack.proto,
            ack.message
        )));
    }
    if ack.proto != wire::VERSION {
        return Err(Error::Coordinator(format!(
            "{} speaks wire version {}, expected {}",
            peer.describe(),
            ack.proto,
            wire::VERSION
        )));
    }
    peer.sent.clear(); // fresh session: the peer holds no data yet
    peer.snap = None; // ... and no snapshot — the next ship re-bases in full
    Ok((bytes, sw.elapsed()))
}

/// Connect with bounded retries — workers may come up slightly after the
/// coordinator, so the initial connect gets `1 + attempts` tries.
fn connect_with_retry(addr: &str, attempts: usize) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..=attempts {
        if attempt > 0 {
            std::thread::sleep(RECONNECT_DELAY);
        }
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(Error::Coordinator(format!(
        "peer {addr} unreachable after {} connect attempts: {}",
        attempts + 1,
        last.expect("at least one attempt")
    )))
}

/// Build one plane: addressed remote peers when `addrs` is non-empty,
/// loopback thread peers otherwise. Every peer is handshaken before the
/// transport is handed out.
#[allow(clippy::too_many_arguments)]
fn init_plane(
    data: &Arc<Dataset>,
    backend: &Arc<dyn ComputeBackend>,
    role: PeerRole,
    n: usize,
    addrs: &[String],
    reconnect_attempts: usize,
    handles: &mut Vec<JoinHandle<()>>,
    acct: &mut SpawnAccounting,
) -> Result<PlaneEndpoints> {
    let count = if addrs.is_empty() { n } else { addrs.len() };
    let mut peers = Vec::with_capacity(count);
    for id in 0..count {
        let hello = Hello {
            proto: wire::VERSION,
            role,
            peer_id: id as u32,
            peers_in_plane: count as u32,
            n: data.len() as u64,
            dim: data.dim() as u64,
        };
        let (stream, addr) = if let Some(a) = addrs.get(id) {
            (connect_with_retry(a, reconnect_attempts)?, Some(a.clone()))
        } else {
            let listener = TcpListener::bind(("127.0.0.1", 0))
                .map_err(|e| Error::Coordinator(format!("tcp bind: {e}")))?;
            let local = listener
                .local_addr()
                .map_err(|e| Error::Coordinator(format!("tcp local_addr: {e}")))?;
            let backend = backend.clone();
            handles.push(std::thread::spawn(move || {
                if let Ok((s, _)) = listener.accept() {
                    let _ = serve_peer(s, backend);
                }
            }));
            let stream = TcpStream::connect(local)
                .map_err(|e| Error::Coordinator(format!("tcp connect: {e}")))?;
            (stream, None)
        };
        stream.set_nodelay(true).ok();
        let mut peer = Peer {
            stream: Some(stream),
            addr,
            hello,
            sent: Coverage::default(),
            snap: None,
        };
        let (bytes, took) = do_handshake(&mut peer)?;
        acct.wire_bytes += bytes as u64;
        acct.handshake_time += took;
        peers.push(peer);
    }
    let ep = PlaneEndpoints::new();
    *ep.peers.borrow_mut() = peers;
    Ok(ep)
}

impl Transport for Tcp {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn peers(&self, plane: Plane) -> usize {
        self.planes[plane.idx()].peers.borrow().len()
    }

    fn scatter(&self, plane: Plane, jobs: Vec<Job>) -> Result<()> {
        let ep = &self.planes[plane.idx()];
        let mut peers = ep.peers.borrow_mut();
        assert_eq!(jobs.len(), peers.len(), "one job per peer");
        assert_eq!(ep.in_flight.get(), 0, "scatter with a wave still outstanding");
        if ep.poisoned.get() {
            return Err(Error::Coordinator(
                "transport plane poisoned by a lost loopback peer".into(),
            ));
        }
        // Encode the whole wave up front — an encode failure here is clean,
        // nothing has been sent yet. Two shapes:
        //
        // * Snapshot-bearing jobs (Nearest / BpDescend) under frugal
        //   shipping: the matrix leaves the job frame entirely. Each peer's
        //   frame carries a snapshot *reference*; the snapshot itself ships
        //   separately (delta/full/not-at-all, per peer cache state) during
        //   delivery. The reference id per peer is decided here: the held
        //   id when the content is bit-identical to what the session
        //   already holds, a fresh wave id otherwise.
        // * Everything else (reduction waves, pair caches, or any wave with
        //   frugal shipping off): the PR 3 splice path — shared Arc'd
        //   payloads encode once and splice into each frame.
        let needs: Vec<Option<Range<usize>>> = jobs.iter().map(|j| j.data_range()).collect();
        let mut memo = SnapMemo::default();
        let sw = Instant::now();
        let snapshot_wave =
            self.frugal && jobs.iter().any(|j| job_snapshot(j).is_some());
        let wave_jobs: Vec<WaveJob> = if snapshot_wave {
            let mut out = Vec::with_capacity(jobs.len());
            let mut unique = 0usize;
            for (job, need) in jobs.iter().zip(needs) {
                let wj = match job_snapshot(job) {
                    Some(m) => {
                        let ref_id = self.snap_ref_id(&peers[out.len()], m, &mut memo);
                        let frame = wire::snapref_job_frame(job, ref_id)?;
                        unique += frame.len();
                        WaveJob { frame, need, snap: Some((ref_id, m.clone())) }
                    }
                    None => {
                        let frame = wire::job_frame(job)?;
                        unique += frame.len();
                        WaveJob { frame, need, snap: None }
                    }
                };
                out.push(wj);
            }
            self.add_unique(unique);
            out
        } else {
            let wave = wire::job_frames(&jobs)?;
            let total: usize = wave.frames.iter().map(|f| f.len()).sum();
            self.add_unique(total - wave.spliced_payload_bytes);
            wave.frames
                .into_iter()
                .zip(needs)
                .map(|(frame, need)| WaveJob { frame, need, snap: None })
                .collect()
        };
        self.add_ser(sw.elapsed());
        *ep.wave.borrow_mut() = wave_jobs;
        let wave_ref = ep.wave.borrow();
        for i in 0..peers.len() {
            if let Err(e) = self.deliver(&mut peers[i], &wave_ref[i], &mut memo) {
                drop(wave_ref);
                self.abort_scatter(ep, &mut peers, i);
                return Err(e);
            }
        }
        drop(wave_ref);
        // Frames are retained only where a resend is possible: loopback
        // thread peers cannot be re-sessioned, so holding extra frame
        // copies for them would buy nothing.
        for (wj, peer) in ep.wave.borrow_mut().iter_mut().zip(peers.iter()) {
            if peer.addr.is_none() {
                wj.frame = Vec::new();
                wj.snap = None;
            }
        }
        ep.in_flight.set(1);
        Ok(())
    }

    fn gather(&self, plane: Plane) -> Result<(Vec<JobOutput>, Duration)> {
        let ep = &self.planes[plane.idx()];
        assert_eq!(ep.in_flight.get(), 1, "gather without a scattered wave");
        let mut peers = ep.peers.borrow_mut();
        let wave = ep.wave.borrow();
        let n = peers.len();
        let mut outputs: Vec<Option<JobOutput>> = (0..n).map(|_| None).collect();
        let mut max_busy = Duration::ZERO;
        let mut first_err: Option<Error> = None;
        let mut take = |reply: JobReply,
                        outputs: &mut Vec<Option<JobOutput>>,
                        first_err: &mut Option<Error>| {
            max_busy = max_busy.max(reply.busy);
            match reply.output {
                Ok(out) if reply.worker < n => outputs[reply.worker] = Some(out),
                Ok(_) => {
                    if first_err.is_none() {
                        *first_err = Some(Error::Coordinator(format!(
                            "peer id {} out of range",
                            reply.worker
                        )));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        *first_err = Some(e);
                    }
                }
            }
        };
        // Readiness-polled sweep: every live socket goes nonblocking and
        // replies retire in *arrival* order, so one straggler no longer
        // serializes the whole wave behind the fixed peer order.
        // Determinism is untouched — outputs are slotted by peer id, and
        // the jobs themselves are pure. Peers whose stream breaks (or
        // arrives desynced) drop out of the sweep and are recovered —
        // sequentially, with the same bounded reconnect/resend policy as
        // before — once every healthy reply is in.
        let mut pending: Vec<usize> = Vec::with_capacity(n);
        let mut dead: Vec<(usize, Error)> = Vec::new();
        for (i, peer) in peers.iter().enumerate() {
            match &peer.stream {
                Some(s) if s.set_nonblocking(true).is_ok() => pending.push(i),
                Some(_) => dead.push((
                    i,
                    Error::Coordinator(format!(
                        "{} socket rejected nonblocking mode",
                        peer.describe()
                    )),
                )),
                None => dead.push((
                    i,
                    Error::Coordinator(format!("{} has no live session", peer.describe())),
                )),
            }
        }
        let mut bufs: Vec<Vec<u8>> = (0..n).map(|_| Vec::new()).collect();
        let mut idle = Duration::ZERO;
        while !pending.is_empty() {
            let mut progressed = false;
            pending.retain(|&i| {
                let peer = &peers[i];
                let stream = peer.stream.as_ref().expect("pending peer has a stream");
                match pump_reply(stream, &mut bufs[i]) {
                    Ok(Some((kind, payload))) => {
                        progressed = true;
                        let _ = stream.set_nonblocking(false);
                        if !bufs[i].is_empty() {
                            // More bytes after the one reply this wave owes:
                            // the streams are desynced — recover on a fresh
                            // session rather than guess at reply pairing.
                            dead.push((
                                i,
                                Error::Coordinator(format!(
                                    "{} sent bytes beyond its reply frame",
                                    peer.describe()
                                )),
                            ));
                            return false;
                        }
                        self.add_bytes(wire::HEADER_LEN + payload.len());
                        let sw = Instant::now();
                        let reply = wire::decode_reply(kind, &payload);
                        self.add_ser(sw.elapsed());
                        match reply {
                            Ok(reply) => take(reply, &mut outputs, &mut first_err),
                            Err(e) => dead.push((i, e)),
                        }
                        false
                    }
                    Ok(None) => true,
                    Err(e) => {
                        progressed = true;
                        let _ = stream.set_nonblocking(false);
                        dead.push((i, e));
                        false
                    }
                }
            });
            if !pending.is_empty() && !progressed {
                // Nothing readable anywhere: yield briefly instead of
                // spinning. The sleep slices are what gather_wait_time
                // measures — wall-clock spent waiting on the slowest peers.
                let sw = Instant::now();
                std::thread::sleep(Duration::from_micros(200));
                idle += sw.elapsed();
            }
        }
        self.gather_wait.set(self.gather_wait.get() + idle);
        // Recovery pass for the peers that dropped out of the sweep.
        for (i, err) in dead {
            if peers[i].addr.is_some() {
                // The frame was retained at scatter, so a replacement
                // worker on the same address can be re-handshaken,
                // re-based, re-shipped, and handed the job again — the
                // wave completes as if nothing happened.
                match self.recover_and_resend(&mut peers[i], &wave[i]) {
                    Ok(reply) => take(reply, &mut outputs, &mut first_err),
                    Err(e) => {
                        peers[i].stream = None;
                        first_err = first_err.or(Some(e));
                    }
                }
            } else {
                // A loopback thread peer's stream broke: it cannot be
                // re-sessioned, so the plane is poisoned.
                ep.poisoned.set(true);
                first_err = first_err.or(Some(err));
            }
        }
        ep.in_flight.set(0);
        drop(wave);
        ep.wave.borrow_mut().clear();
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok((
            outputs.into_iter().map(|o| o.expect("peer replied")).collect(),
            max_busy,
        ))
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            wire_bytes: self.wire_bytes.get(),
            unique_payload_bytes: self.unique_bytes.get(),
            ser_time: self.ser_time.get(),
            dataset_bytes: self.dataset_bytes.get(),
            delta_bytes: self.delta_bytes.get(),
            full_snapshot_fallbacks: self.full_snapshot_fallbacks.get(),
            handshake_time: self.handshake_time.get(),
            gather_wait_time: self.gather_wait.get(),
        }
    }
}

/// Nonblocking read step for the gather sweep: drain whatever bytes the
/// socket has into `buf` and try to pop one complete frame off it
/// ([`wire::poll_frame`]). `Ok(None)` means "not ready yet"; a typed error
/// means the stream is dead (EOF) or desynced (bad header).
fn pump_reply(mut stream: &TcpStream, buf: &mut Vec<u8>) -> Result<Option<(u16, Vec<u8>)>> {
    let mut tmp = [0u8; 64 * 1024];
    loop {
        // Parse first: a previous sweep may have buffered a complete frame.
        if let Some(frame) = wire::poll_frame(buf)? {
            return Ok(Some(frame));
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                return Err(Error::Coordinator(
                    "peer closed its stream mid-wave".into(),
                ))
            }
            Ok(k) => buf.extend_from_slice(&tmp[..k]),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(None),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Coordinator(format!("tcp gather read: {e}"))),
        }
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        for ep in &self.planes {
            let mut peers = ep.peers.borrow_mut();
            // Drain an outstanding (successfully scattered, never gathered)
            // wave so no peer blocks writing a reply into a socket nobody
            // reads. A poisoned plane is skipped — its streams may be
            // desynced; closing them below is the only safe move.
            if ep.in_flight.get() > 0 && !ep.poisoned.get() {
                for p in peers.iter() {
                    let _ = drain_one(p);
                }
            }
            // Shutdown frames are best-effort: a dead peer's socket just
            // errors, and closing the stream below unblocks it anyway.
            if let Ok(frame) = wire::job_frame(&Job::Shutdown) {
                for p in peers.iter_mut() {
                    if let Some(stream) = &mut p.stream {
                        let _ = stream.write_all(&frame);
                    }
                }
            }
            // Close every socket (EOF for any peer that missed its
            // shutdown frame).
            for p in peers.iter_mut() {
                p.stream = None;
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{split_range, split_range_chunked};
    use super::super::transport::{Cluster, Plane, Transport};
    use super::*;
    use crate::config::TransportKind;
    use crate::data::generators::{dp_clusters, GenConfig};
    use crate::linalg::Matrix;
    use crate::runtime::native::NativeBackend;

    fn data_and_backend(n: usize) -> (Arc<Dataset>, Arc<dyn ComputeBackend>) {
        let data = Arc::new(dp_clusters(&GenConfig { n, dim: 8, theta: 1.0, seed: 7 }));
        (data, Arc::new(NativeBackend::new()))
    }

    // -- Coverage ----------------------------------------------------------

    #[test]
    fn coverage_add_merges_and_covers() {
        let mut c = Coverage::default();
        assert!(c.covers(&(5..5)), "empty range is always covered");
        c.add(10..20);
        c.add(30..40);
        c.add(18..30); // bridges the two spans
        assert!(c.covers(&(10..40)));
        assert!(!c.covers(&(9..12)));
        assert!(!c.covers(&(35..41)));
        c.add(0..0); // empty add is a no-op
        assert!(!c.covers(&(0..1)));
    }

    #[test]
    fn coverage_missing_returns_exact_gaps() {
        let mut c = Coverage::default();
        c.add(10..20);
        c.add(30..40);
        assert_eq!(c.missing(&(0..50)), vec![0..10, 20..30, 40..50]);
        assert_eq!(c.missing(&(12..18)), Vec::<Range<usize>>::new());
        assert_eq!(c.missing(&(15..35)), vec![20..30]);
        assert_eq!(c.missing(&(40..40)), Vec::<Range<usize>>::new());
        c.clear();
        assert_eq!(c.missing(&(1..3)), vec![1..3]);
    }

    // -- Waves -------------------------------------------------------------

    /// The same wave over TCP and in-proc must return bit-identical outputs
    /// — the whole point of the bit-exact wire format.
    #[test]
    fn tcp_wave_bitidentical_to_inproc() {
        let (data, backend) = data_and_backend(120);
        let tcp = Cluster::spawn(TransportKind::Tcp, data.clone(), backend.clone(), 3, 1)
            .unwrap();
        let inproc =
            Cluster::spawn(TransportKind::InProc, data.clone(), backend, 3, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(3));
        centers.push_row(data.point(77));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..120, 3)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let (a, _) = tcp.scatter_gather(mk()).unwrap();
        let (b, _) = inproc.scatter_gather(mk()).unwrap();
        for (x, y) in a.iter().zip(&b) {
            let (JobOutput::Nearest { idx: ia, d2: da }, JobOutput::Nearest { idx: ib, d2: db }) =
                (x, y)
            else {
                panic!("wrong output kind");
            };
            assert_eq!(ia, ib);
            let da: Vec<u32> = da.iter().map(|f| f.to_bits()).collect();
            let db: Vec<u32> = db.iter().map(|f| f.to_bits()).collect();
            assert_eq!(da, db, "d² diverged across the wire");
        }
        let stats = tcp.stats();
        assert!(stats.wire_bytes > 0, "tcp waves must be accounted");
        assert!(stats.handshake_time > Duration::ZERO, "handshakes must be accounted");
    }

    /// Loopback peers receive the dataset over the wire, on demand, each
    /// range at most once per session.
    #[test]
    fn dataset_blocks_ship_on_demand_and_only_once() {
        let (data, backend) = data_and_backend(100);
        let tcp = Tcp::spawn(data.clone(), backend, 2, 1).unwrap();
        assert_eq!(tcp.stats().dataset_bytes, 0, "nothing shipped before a wave");
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..100, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        tcp.scatter(Plane::Compute, mk()).unwrap();
        tcp.gather(Plane::Compute).unwrap();
        let after_first = tcp.stats().dataset_bytes;
        assert!(after_first > 0, "compute jobs must ship their point ranges");
        tcp.scatter(Plane::Compute, mk()).unwrap();
        tcp.gather(Plane::Compute).unwrap();
        assert_eq!(
            tcp.stats().dataset_bytes,
            after_first,
            "already-covered ranges must not be re-shipped"
        );
    }

    /// Validator peers never receive dataset blocks: their jobs carry the
    /// proposal vectors inline.
    #[test]
    fn validator_plane_ships_no_dataset() {
        let (data, backend) = data_and_backend(60);
        let tcp = Tcp::spawn(data, backend, 1, 2).unwrap();
        let mut vectors = Matrix::zeros(0, 2);
        vectors.push_row(&[0.0, 0.0]);
        vectors.push_row(&[1.0, 0.0]);
        let vectors = Arc::new(vectors);
        let jobs = vec![
            Job::PairCache {
                vectors: vectors.clone(),
                positions: vec![],
                shards: vec![vec![0, 1]],
            },
            Job::PairCache { vectors, positions: vec![], shards: vec![] },
        ];
        tcp.scatter(Plane::Validate, jobs).unwrap();
        tcp.gather(Plane::Validate).unwrap();
        assert_eq!(tcp.stats().dataset_bytes, 0);
    }

    /// The snapshot wire diet, end to end over real sockets: an unchanged
    /// snapshot ships nothing, an appended snapshot ships only its delta
    /// rows, and a rewritten snapshot falls back to a full frame — with the
    /// returned assignments bit-identical throughout.
    #[test]
    fn snapshot_deltas_ship_only_appended_rows() {
        let (data, backend) = data_and_backend(120);
        let tcp = Tcp::spawn(data.clone(), backend, 2, 1).unwrap();
        let mk = |centers: &Arc<Matrix>| -> Vec<Job> {
            split_range(0..120, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        let mut m = Matrix::zeros(0, 8);
        m.push_row(data.point(3));
        m.push_row(data.point(40));
        let snap1 = Arc::new(m.clone());

        // Wave 1: cold caches — one full snapshot per peer, no deltas.
        tcp.scatter(Plane::Compute, mk(&snap1)).unwrap();
        let (out1, _) = tcp.gather(Plane::Compute).unwrap();
        let s1 = tcp.stats();
        assert_eq!(s1.full_snapshot_fallbacks, 2, "one full install per cold peer");
        assert_eq!(s1.delta_bytes, 0);

        // Wave 2: identical content (fresh Arc) — nothing ships at all.
        let snap1b = Arc::new(m.clone());
        tcp.scatter(Plane::Compute, mk(&snap1b)).unwrap();
        let (out2, _) = tcp.gather(Plane::Compute).unwrap();
        let s2 = tcp.stats();
        assert_eq!(s2.full_snapshot_fallbacks, 2, "no new full installs");
        assert_eq!(s2.delta_bytes, 0, "identical snapshots ship no delta");
        for (a, b) in out1.iter().zip(&out2) {
            let (JobOutput::Nearest { idx: ia, d2: da }, JobOutput::Nearest { idx: ib, d2: db }) =
                (a, b)
            else {
                panic!("wrong output kind");
            };
            assert_eq!(ia, ib);
            assert_eq!(
                da.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                db.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
            );
        }

        // Wave 3: two appended rows — delta bytes ≈ 2 rows, no new fulls.
        m.push_row(data.point(70));
        m.push_row(data.point(99));
        let snap2 = Arc::new(m.clone());
        tcp.scatter(Plane::Compute, mk(&snap2)).unwrap();
        let (out3, _) = tcp.gather(Plane::Compute).unwrap();
        let s3 = tcp.stats();
        assert_eq!(s3.full_snapshot_fallbacks, 2, "append must not trigger a full ship");
        assert!(s3.delta_bytes > 0, "appended rows must ship as a delta");
        let per_peer = (s3.delta_bytes - s2.delta_bytes) / 2;
        assert!(
            per_peer < 2 * 8 * 4 + 64,
            "delta payload ({per_peer} B/peer) must be ~2 rows, not the full matrix"
        );
        // The delta-reconstructed snapshot computes the exact fresh answer.
        let inproc = Cluster::spawn(
            TransportKind::InProc,
            data.clone(),
            Arc::new(NativeBackend::new()),
            2,
            1,
        )
        .unwrap();
        let (reference, _) = inproc.scatter_gather(mk(&snap2)).unwrap();
        for (a, b) in out3.iter().zip(&reference) {
            let (JobOutput::Nearest { idx: ia, d2: da }, JobOutput::Nearest { idx: ib, d2: db }) =
                (a, b)
            else {
                panic!("wrong output kind");
            };
            assert_eq!(ia, ib);
            assert_eq!(
                da.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                db.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
            );
        }

        // Wave 4: rewrite a prefix row (the mean-recompute shape) — the
        // delta path must refuse and re-base from a full frame.
        m.row_mut(0)[0] += 1.0;
        let snap3 = Arc::new(m);
        tcp.scatter(Plane::Compute, mk(&snap3)).unwrap();
        tcp.gather(Plane::Compute).unwrap();
        let s4 = tcp.stats();
        assert_eq!(
            s4.full_snapshot_fallbacks, 4,
            "a rewritten prefix must fall back to full snapshots"
        );
        assert_eq!(s4.delta_bytes, s3.delta_bytes, "no delta for a rewrite");
    }

    /// Out-of-order gather: a straggler peer must not stop an
    /// already-arrived reply from being retired, and the idle wait is
    /// accounted. The slow peer here is a hand-rolled worker that sits on
    /// its job before replying.
    #[test]
    fn gather_retires_replies_out_of_peer_order() {
        let (data, backend) = data_and_backend(60);
        // Peer 0: hand-rolled *slow* worker — handshake, then replies to
        // its job only after a long nap.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let slow_addr = listener.local_addr().unwrap().to_string();
        let slow = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let hello = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            loop {
                let (kind, _) = wire::read_frame(&mut s).unwrap();
                if kind == wire::KIND_JOB {
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(400));
            let out = Ok(JobOutput::PairCache { pairs: vec![] });
            wire::write_reply(&mut s, hello.peer_id, Duration::ZERO, &out).unwrap();
            // Hold the stream until the master is done with the wave.
            let _ = wire::read_frame(&mut s);
        });
        // Peer 1: a real (fast) worker.
        let (fast_addr, fast) = listener_worker(backend.clone(), 1);
        let topo = Topology {
            procs: 2,
            validators: 1,
            compute_peers: vec![],
            validator_peers: vec![slow_addr, fast_addr],
            reconnect_attempts: 1,
            frugal_wire: true,
        };
        let tcp = Tcp::spawn_topology(data, backend, &topo).unwrap();
        let mut vectors = Matrix::zeros(0, 2);
        vectors.push_row(&[0.0, 0.0]);
        vectors.push_row(&[1.0, 1.0]);
        let vectors = Arc::new(vectors);
        let jobs = vec![
            Job::PairCache { vectors: vectors.clone(), positions: vec![], shards: vec![] },
            Job::PairCache { vectors, positions: vec![], shards: vec![vec![0, 1]] },
        ];
        tcp.scatter(Plane::Validate, jobs).unwrap();
        let (outs, _) = tcp.gather(Plane::Validate).unwrap();
        // Outputs stay in peer-id order even though peer 1 replied first.
        let JobOutput::PairCache { pairs } = &outs[0] else { panic!("wrong output kind") };
        assert!(pairs.is_empty(), "slow peer's (empty) cache sits at slot 0");
        let JobOutput::PairCache { pairs } = &outs[1] else { panic!("wrong output kind") };
        assert_eq!(pairs.len(), 1, "fast peer's pair sits at slot 1");
        assert!(
            tcp.stats().gather_wait_time >= Duration::from_millis(100),
            "waiting on the straggler must be accounted in gather_wait_time"
        );
        drop(tcp);
        slow.join().unwrap();
        fast.join().unwrap();
    }

    #[test]
    fn tcp_peer_error_drains_wave_and_transport_survives() {
        let (data, backend) = data_and_backend(100);
        let tcp = Tcp::spawn(data, backend, 2, 1).unwrap();
        let short = Arc::new(vec![0u32; 10]); // fails decode validation peer-side
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: short.clone(), k: 2 })
            .collect();
        tcp.scatter(Plane::Compute, jobs).unwrap();
        assert!(tcp.gather(Plane::Compute).is_err(), "poisoned wave must error");
        // The peers replied with errors and are still serving: a clean wave
        // works on the same sessions.
        let ok = Arc::new(vec![0u32; 100]);
        let jobs: Vec<Job> = split_range_chunked(0..100, 2)
            .into_iter()
            .map(|range| Job::SuffStats { range, assignments: ok.clone(), k: 2 })
            .collect();
        tcp.scatter(Plane::Compute, jobs).unwrap();
        tcp.gather(Plane::Compute).unwrap();
        drop(tcp); // must not hang
    }

    #[test]
    fn tcp_drop_with_outstanding_wave_does_not_hang() {
        let (data, backend) = data_and_backend(60);
        let tcp = Tcp::spawn(data.clone(), backend, 2, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let jobs: Vec<Job> = split_range(0..60, 2)
            .into_iter()
            .map(|range| Job::Nearest { range, centers: centers.clone() })
            .collect();
        tcp.scatter(Plane::Compute, jobs).unwrap();
        drop(tcp); // wave never gathered; drop drains and joins
    }

    // -- Addressed peers + reconnect ---------------------------------------

    /// A thread standing in for an `occd worker` process: listens on a real
    /// address and serves sessions with the production peer loop.
    fn listener_worker(
        backend: Arc<dyn ComputeBackend>,
        sessions: usize,
    ) -> (String, JoinHandle<()>) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            for _ in 0..sessions {
                let Ok((s, _)) = listener.accept() else { return };
                let _ = serve_peer(s, backend.clone());
            }
        });
        (addr, handle)
    }

    /// Addressed peers (the `occd worker` path, served here by threads
    /// behind real listeners) produce the same bits as loopback peers.
    #[test]
    fn addressed_peers_serve_waves_like_loopback() {
        let (data, backend) = data_and_backend(90);
        let (a0, h0) = listener_worker(backend.clone(), 1);
        let (a1, h1) = listener_worker(backend.clone(), 1);
        let (av, hv) = listener_worker(backend.clone(), 1);
        let topo = Topology {
            procs: 2,
            validators: 1,
            compute_peers: vec![a0, a1],
            validator_peers: vec![av],
            reconnect_attempts: 2,
            frugal_wire: true,
        };
        let tcp = Tcp::spawn_topology(data.clone(), backend.clone(), &topo).unwrap();
        assert_eq!(tcp.peers(Plane::Compute), 2);
        assert_eq!(tcp.peers(Plane::Validate), 1);
        let loopback = Tcp::spawn(data.clone(), backend, 2, 1).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(5));
        let centers = Arc::new(centers);
        let mk = || -> Vec<Job> {
            split_range(0..90, 2)
                .into_iter()
                .map(|range| Job::Nearest { range, centers: centers.clone() })
                .collect()
        };
        tcp.scatter(Plane::Compute, mk()).unwrap();
        let (a, _) = tcp.gather(Plane::Compute).unwrap();
        loopback.scatter(Plane::Compute, mk()).unwrap();
        let (b, _) = loopback.gather(Plane::Compute).unwrap();
        for (x, y) in a.iter().zip(&b) {
            let (JobOutput::Nearest { idx: ia, d2: da }, JobOutput::Nearest { idx: ib, d2: db }) =
                (x, y)
            else {
                panic!("wrong output kind");
            };
            assert_eq!(ia, ib);
            assert_eq!(
                da.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                db.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
            );
        }
        drop(tcp);
        drop(loopback);
        h0.join().unwrap();
        h1.join().unwrap();
        hv.join().unwrap();
    }

    /// A remote peer that dies mid-wave is recovered through the bounded
    /// reconnect path: the listener serves a first session that reads the
    /// job and drops dead, then a second, healthy session; the master
    /// re-handshakes, re-ships, resends, and the wave completes.
    #[test]
    fn dropped_remote_peer_recovers_via_resend() {
        let (data, backend) = data_and_backend(80);
        // A worker whose first session crashes right after receiving its
        // job (handshake + data blocks are consumed so the master's scatter
        // succeeds), and whose second session is healthy.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let crash_backend = backend.clone();
        let worker = std::thread::spawn(move || {
            // Session 1: handshake, swallow frames until the job arrives,
            // then drop the stream without replying.
            let (mut s, _) = listener.accept().unwrap();
            let (kind, payload) = wire::read_frame(&mut s).unwrap();
            assert_eq!(kind, wire::KIND_HELLO);
            let _ = wire::decode_hello(&payload).unwrap();
            let ack = HelloAck { proto: wire::VERSION, ok: true, message: String::new() };
            s.write_all(&wire::hello_ack_frame(&ack).unwrap()).unwrap();
            loop {
                let (kind, _) = wire::read_frame(&mut s).unwrap();
                if kind == wire::KIND_JOB {
                    break; // crash: drop the stream, reply with nothing
                }
            }
            drop(s);
            // Session 2: a healthy replacement.
            let (s, _) = listener.accept().unwrap();
            let _ = serve_peer(s, crash_backend);
        });
        let topo = Topology {
            procs: 1,
            validators: 1,
            compute_peers: vec![addr],
            validator_peers: vec![],
            reconnect_attempts: 8,
            frugal_wire: true,
        };
        let tcp = Tcp::spawn_topology(data.clone(), backend, &topo).unwrap();
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        let jobs = vec![Job::Nearest { range: 0..80, centers: centers.clone() }];
        tcp.scatter(Plane::Compute, jobs).unwrap();
        let (outs, _) = tcp.gather(Plane::Compute).unwrap();
        let JobOutput::Nearest { idx, .. } = &outs[0] else { panic!("wrong output kind") };
        assert_eq!(idx.len(), 80);
        assert!(
            tcp.stats().handshake_time > Duration::ZERO,
            "recovery re-handshakes must be accounted"
        );
        assert_eq!(
            tcp.stats().full_snapshot_fallbacks,
            2,
            "the replacement session must be re-based from a full snapshot"
        );
        drop(tcp);
        worker.join().unwrap();
    }

    /// A remote peer that dies and never comes back yields a typed error
    /// with the wave drained — not a poisoned plane, not a deadlock.
    #[test]
    fn dead_remote_peer_types_out_after_bounded_attempts() {
        let (data, backend) = data_and_backend(40);
        let (addr, handle) = listener_worker(backend.clone(), 1);
        let topo = Topology {
            procs: 1,
            validators: 1,
            compute_peers: vec![addr],
            validator_peers: vec![],
            reconnect_attempts: 1,
            frugal_wire: true,
        };
        let tcp = Tcp::spawn_topology(data.clone(), backend, &topo).unwrap();
        // Kill the worker: drop the transport's only session server by
        // sending a shutdown-shaped job... instead, simply send a job after
        // the listener thread exits its single session.
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        let centers = Arc::new(centers);
        // First wave works.
        tcp.scatter(
            Plane::Compute,
            vec![Job::Nearest { range: 0..40, centers: centers.clone() }],
        )
        .unwrap();
        tcp.gather(Plane::Compute).unwrap();
        // The worker serves exactly one session; kill it by dropping our
        // stream (reconnect will find nobody listening).
        tcp.planes[Plane::Compute.idx()].peers.borrow_mut()[0].stream = None;
        handle.join().unwrap();
        let err = tcp
            .scatter(
                Plane::Compute,
                vec![Job::Nearest { range: 0..40, centers: centers.clone() }],
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("reconnect") || err.contains("unreachable"), "{err}");
        drop(tcp); // must not hang
    }
}
