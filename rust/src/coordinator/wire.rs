//! The cluster's wire format: explicit, versioned, length-prefixed frames
//! carrying [`Job`]s, [`JobOutput`]s and replies between the master and TCP
//! peers.
//!
//! Hand-rolled and zero-dependency by design (no serde offline). The
//! format is little-endian throughout:
//!
//! ```text
//! frame   := magic:u32  version:u16  kind:u16  len:u32  payload[len]
//! magic   := 0x4D43434F ("OCCM" in LE byte order)
//! kind    := 1 job | 2 reply-ok | 3 reply-err | 4 hello | 5 hello-ack
//!          | 6 dataset-block | 7 snapshot | 8 snapshot-delta
//!          | 9 ingest | 10 ingest-ack | 11 query
//! ```
//!
//! * **f32 values travel as their IEEE-754 bit patterns** (`to_bits` /
//!   `from_bits`): NaN payloads, signed zeros and subnormals round-trip
//!   exactly, which is what keeps TCP runs bit-identical to in-proc runs
//!   (`rust/tests/transport_equivalence.rs`).
//! * **Every length is validated before allocation** — a truncated,
//!   oversized or corrupt frame produces a typed error, never a panic or an
//!   unbounded allocation (`rust/tests/wire_format.rs`).
//! * The version field is checked on receive; bumping [`VERSION`] is the
//!   upgrade path when the `Job` schema changes. The [`Hello`] handshake
//!   additionally carries the version in its payload, so a mismatched peer
//!   is rejected with a typed error before any work is exchanged.
//!
//! Snapshots (`C^{t-1}` center/feature matrices) are *not* embedded in the
//! jobs that reference them (that was the PR 2–3 shape): they travel as
//! their own versioned frames, and jobs reference them by id:
//!
//! * [`KIND_SNAPSHOT`] installs a full snapshot `{id, matrix}` into the
//!   peer session's single-entry snapshot cache.
//! * [`KIND_SNAPSHOT_DELTA`] re-bases the cache: `{id, base_id, base_rows,
//!   tail}` reconstructs the new snapshot as the first `base_rows` rows of
//!   the cached `base_id` entry plus the tail rows — bit-exactly, since
//!   both sides move f32 bit patterns. Between epochs of one pass the
//!   committed state only *appends* rows (DP/OFL validation, BP
//!   acceptances), so the per-epoch snapshot traffic shrinks from the full
//!   `O(K·d)` matrix to just the accepted rows; a mean-recompute rewrite, a
//!   cache miss or a replacement peer falls back to a full
//!   [`KIND_SNAPSHOT`] frame.
//! * Snapshot-referencing job encodings ([`snapref_job_frame`]) carry the
//!   id instead of the matrix; [`decode_job_snap`] resolves it against the
//!   peer's cache and rejects a mismatch with a typed error.
//!
//! The dataset is shipped as explicit [`KIND_DATA`] block frames: a peer
//! opens a session with a [`Hello`]/[`HelloAck`] exchange that fixes its
//! shard assignment and the dataset geometry, then receives exactly the
//! point ranges its jobs read (see [`super::tcp`]).
//!
//! ## Streaming ingest (`occd serve`)
//!
//! Three client-facing kinds serve the front-end gateway of the streaming
//! ingest service (see [`super::serve`]); they flow on *client* sessions,
//! never on worker sessions:
//!
//! * [`KIND_INGEST`] — client → gateway: `{seq: u64, points: Matrix}`, a
//!   chunk of points offered for admission. An **empty matrix (0 rows)
//!   marks end-of-stream**: the gateway seals any pending mini-epoch,
//!   closes admission, and acknowledges the EOS frame only once the model
//!   is final.
//! * [`KIND_INGEST_ACK`] — gateway → client: `{seq: u64, status: u8,
//!   detail: u64, message: str}` echoing the chunk's `seq`. Status is
//!   typed ([`IngestStatus`]): `Accepted` (detail = points admitted so
//!   far), `Throttled` (the bounded admission queue is full — detail =
//!   the configured bound; the chunk was **not** admitted, re-send it), or
//!   `Rejected` (malformed payload; detail = 0, message says why — the
//!   session survives, framing was intact).
//! * [`KIND_QUERY`] — client → gateway: empty payload; the gateway replies
//!   with a [`KIND_SNAPSHOT`] frame carrying the current model matrix
//!   (id = committed batches; a 0-row matrix while no model is final).
//!
//! ## Shared-payload splicing
//!
//! The P jobs of one wave may embed the same `Arc`'d payload (the
//! reduction waves' assignment vector). [`job_frames`] encodes each shared
//! payload *once* per wave and splices the cached bytes into every frame,
//! instead of re-encoding it P times; the produced frames are
//! byte-identical to per-job [`job_frame`] encoding, and
//! [`WaveFrames::spliced_payload_bytes`] reports how much encoder work the
//! splice avoided (asserted in `rust/tests/wire_format.rs`).

use super::engine::{Job, JobOutput, JobReply};
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::ops::Range;
use std::sync::Arc;
use std::time::Duration;

/// Frame magic: "OCCM" read back from little-endian bytes.
pub const MAGIC: u32 = u32::from_le_bytes(*b"OCCM");
/// Wire-format version. v2 added the snapshot / snapshot-delta frame
/// kinds, snapshot-referencing job encodings, and the `PairCache`
/// row-subset position map.
pub const VERSION: u16 = 2;
/// Frame header length in bytes (magic + version + kind + len).
pub const HEADER_LEN: usize = 12;
/// Maximum frame payload: 1 GiB. Anything larger is a protocol error.
pub const MAX_FRAME: u32 = 1 << 30;

/// Frame kind: a job flowing master → peer.
pub const KIND_JOB: u16 = 1;
/// Frame kind: a successful reply flowing peer → master.
pub const KIND_REPLY_OK: u16 = 2;
/// Frame kind: an error reply flowing peer → master.
pub const KIND_REPLY_ERR: u16 = 3;
/// Frame kind: the master → peer handshake opening a session.
pub const KIND_HELLO: u16 = 4;
/// Frame kind: the peer's handshake acknowledgement.
pub const KIND_HELLO_ACK: u16 = 5;
/// Frame kind: a dataset block flowing master → peer.
pub const KIND_DATA: u16 = 6;
/// Frame kind: a full snapshot install flowing master → peer.
pub const KIND_SNAPSHOT: u16 = 7;
/// Frame kind: a snapshot delta (re-base) flowing master → peer.
pub const KIND_SNAPSHOT_DELTA: u16 = 8;
/// Frame kind: a chunk of points offered for admission, client → gateway
/// (`occd serve`). An empty matrix marks end-of-stream.
pub const KIND_INGEST: u16 = 9;
/// Frame kind: the gateway's typed admission acknowledgement for one
/// ingest chunk, gateway → client.
pub const KIND_INGEST_ACK: u16 = 10;
/// Frame kind: a live model query, client → gateway; answered with a
/// [`KIND_SNAPSHOT`] frame.
pub const KIND_QUERY: u16 = 11;

fn wire_err(msg: impl Into<String>) -> Error {
    Error::Data(format!("wire: {}", msg.into()))
}

// ---------------------------------------------------------------------------
// Primitive encoding
// ---------------------------------------------------------------------------

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}
fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_usize(b: &mut Vec<u8>, v: usize) {
    put_u64(b, v as u64);
}
fn put_f32(b: &mut Vec<u8>, v: f32) {
    put_u32(b, v.to_bits());
}
fn put_range(b: &mut Vec<u8>, r: &Range<usize>) {
    put_usize(b, r.start);
    put_usize(b, r.end);
}
fn put_u32_slice(b: &mut Vec<u8>, v: &[u32]) {
    put_usize(b, v.len());
    for &x in v {
        put_u32(b, x);
    }
}
fn put_f32_slice(b: &mut Vec<u8>, v: &[f32]) {
    put_usize(b, v.len());
    for &x in v {
        put_f32(b, x);
    }
}
fn put_u64_slice(b: &mut Vec<u8>, v: &[u64]) {
    put_usize(b, v.len());
    for &x in v {
        put_u64(b, x);
    }
}
fn put_bool_slice(b: &mut Vec<u8>, v: &[bool]) {
    put_usize(b, v.len());
    for &x in v {
        put_u8(b, u8::from(x));
    }
}
fn put_matrix(b: &mut Vec<u8>, m: &Matrix) {
    put_usize(b, m.rows);
    put_usize(b, m.cols);
    for &x in &m.data {
        put_f32(b, x);
    }
}
fn put_str(b: &mut Vec<u8>, s: &str) {
    put_usize(b, s.len());
    b.extend_from_slice(s.as_bytes());
}

/// Bounds-checked payload reader. Every accessor fails with a "truncated"
/// error instead of panicking when the payload runs short.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Read from `buf`.
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn ensure(&self, n: usize) -> Result<()> {
        if self.buf.len() - self.pos < n {
            Err(wire_err(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )))
        } else {
            Ok(())
        }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        self.ensure(n)?;
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Next u8.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    /// Next little-endian u16.
    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("2 bytes")))
    }
    /// Next little-endian u32.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
    /// Next little-endian u64.
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
    /// Next u64, converted to usize.
    pub fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| wire_err("length does not fit usize"))
    }
    /// Next f32, bit-exact.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    /// A length that must still be coverable by the remaining payload when
    /// each element takes `elem_bytes` — rejects corrupt lengths before any
    /// allocation happens.
    fn len_of(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.usize()?;
        let need = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| wire_err("length overflow"))?;
        self.ensure(need)?;
        Ok(n)
    }
    /// The payload must be fully consumed.
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            Err(wire_err(format!("{} trailing bytes", self.buf.len() - self.pos)))
        } else {
            Ok(())
        }
    }
}

fn get_range(r: &mut Reader) -> Result<Range<usize>> {
    let start = r.usize()?;
    let end = r.usize()?;
    if start > end {
        return Err(wire_err(format!("inverted range {start}..{end}")));
    }
    Ok(start..end)
}
fn get_u32_vec(r: &mut Reader) -> Result<Vec<u32>> {
    let n = r.len_of(4)?;
    (0..n).map(|_| r.u32()).collect()
}
fn get_f32_vec(r: &mut Reader) -> Result<Vec<f32>> {
    let n = r.len_of(4)?;
    (0..n).map(|_| r.f32()).collect()
}
fn get_u64_vec(r: &mut Reader) -> Result<Vec<u64>> {
    let n = r.len_of(8)?;
    (0..n).map(|_| r.u64()).collect()
}
fn get_bool_vec(r: &mut Reader) -> Result<Vec<bool>> {
    let n = r.len_of(1)?;
    (0..n)
        .map(|_| match r.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(wire_err(format!("invalid bool byte {other}"))),
        })
        .collect()
}
fn get_matrix(r: &mut Reader) -> Result<Matrix> {
    let rows = r.usize()?;
    let cols = r.usize()?;
    let n = rows.checked_mul(cols).ok_or_else(|| wire_err("matrix size overflow"))?;
    let bytes = n.checked_mul(4).ok_or_else(|| wire_err("matrix size overflow"))?;
    r.ensure(bytes)?;
    let mut data = Vec::with_capacity(n);
    for _ in 0..n {
        data.push(r.f32()?);
    }
    Ok(Matrix { rows, cols, data })
}
fn get_str(r: &mut Reader) -> Result<String> {
    let n = r.len_of(1)?;
    String::from_utf8(r.take(n)?.to_vec()).map_err(|_| wire_err("invalid utf-8 string"))
}

// ---------------------------------------------------------------------------
// Job encoding
// ---------------------------------------------------------------------------

const JOB_NEAREST: u8 = 0;
const JOB_SUFFSTATS: u8 = 1;
const JOB_BP_DESCEND: u8 = 2;
const JOB_BP_STATS: u8 = 3;
const JOB_PAIR_CACHE: u8 = 4;
const JOB_SHUTDOWN: u8 = 5;
/// A `Nearest` job whose centers live in the peer's snapshot cache,
/// referenced by id instead of being embedded.
const JOB_NEAREST_SNAP: u8 = 6;
/// A `BpDescend` job whose features live in the peer's snapshot cache.
const JOB_BP_DESCEND_SNAP: u8 = 7;

/// Per-wave cache of encoded shared payloads, keyed by the `Arc`
/// allocation's address. Payloads the wave's jobs share by `Arc` (the
/// epoch snapshot, the reduction's assignment vector) are encoded once and
/// spliced — byte-for-byte — into every later frame that embeds them.
#[derive(Default)]
struct SpliceCache {
    parts: HashMap<usize, Vec<u8>>,
    spliced: usize,
}

impl SpliceCache {
    /// Append the encoding of a shared payload to `b`: run `encode` on a
    /// cache miss, splice the cached bytes on a hit.
    fn splice(&mut self, b: &mut Vec<u8>, key: usize, encode: impl FnOnce(&mut Vec<u8>)) {
        if let Some(cached) = self.parts.get(&key) {
            self.spliced += cached.len();
            b.extend_from_slice(cached);
            return;
        }
        let start = b.len();
        encode(b);
        self.parts.insert(key, b[start..].to_vec());
    }
}

fn encode_job_into(job: &Job, cache: &mut SpliceCache) -> Vec<u8> {
    let mut b = Vec::new();
    encode_job_to(&mut b, job, cache);
    b
}

/// Append a job payload to `b` (no frame header) — the in-place twin of
/// [`encode_job`] for reusable scratch buffers.
fn encode_job_to(b: &mut Vec<u8>, job: &Job, cache: &mut SpliceCache) {
    match job {
        Job::Nearest { range, centers } => {
            put_u8(b, JOB_NEAREST);
            put_range(b, range);
            cache.splice(b, Arc::as_ptr(centers) as usize, |b| put_matrix(b, centers));
        }
        Job::SuffStats { range, assignments, k } => {
            put_u8(b, JOB_SUFFSTATS);
            put_range(b, range);
            cache.splice(b, Arc::as_ptr(assignments) as usize, |b| {
                put_u32_slice(b, assignments.as_slice())
            });
            put_usize(b, *k);
        }
        Job::BpDescend { range, features, sweeps } => {
            put_u8(b, JOB_BP_DESCEND);
            put_range(b, range);
            cache.splice(b, Arc::as_ptr(features) as usize, |b| put_matrix(b, features));
            put_usize(b, *sweeps);
        }
        Job::BpStats { range, z, k } => {
            put_u8(b, JOB_BP_STATS);
            put_range(b, range);
            cache.splice(b, Arc::as_ptr(z) as usize, |b| {
                put_usize(b, z.len());
                for row in z.iter() {
                    put_bool_slice(b, row);
                }
            });
            put_usize(b, *k);
        }
        Job::PairCache { vectors, positions, shards } => {
            put_u8(b, JOB_PAIR_CACHE);
            cache.splice(b, Arc::as_ptr(vectors) as usize, |b| put_matrix(b, vectors));
            put_u32_slice(b, positions);
            put_usize(b, shards.len());
            for shard in shards {
                put_u32_slice(b, shard);
            }
        }
        Job::Shutdown => {
            put_u8(b, JOB_SHUTDOWN);
        }
    }
}

/// Serialize a job payload (no frame header).
pub fn encode_job(job: &Job) -> Vec<u8> {
    encode_job_into(job, &mut SpliceCache::default())
}

/// One wave's encoded job frames plus encoder-effort accounting.
pub struct WaveFrames {
    /// One complete frame per job, in job order — byte-identical to what
    /// per-job [`job_frame`] calls would produce.
    pub frames: Vec<Vec<u8>>,
    /// Payload bytes that were actually run through the encoder.
    pub fresh_payload_bytes: usize,
    /// Payload bytes spliced from the wave's shared-payload cache instead
    /// of being re-encoded (a pure memcpy).
    pub spliced_payload_bytes: usize,
}

/// Encode one wave of jobs with shared-payload splicing: payloads the jobs
/// share by `Arc` (snapshots, assignment vectors) are encoded once and
/// spliced into each later frame.
pub fn job_frames(jobs: &[Job]) -> Result<WaveFrames> {
    job_frames_pooled(jobs, &mut Vec::new())
}

/// [`job_frames`] drawing its frame buffers from `pool` instead of the
/// allocator: each returned frame reuses a pooled `Vec`'s capacity
/// (cleared, never shrunk). The TCP plane returns drained frames to the
/// pool, so steady-state waves stop allocating. Byte-identical output.
pub fn job_frames_pooled(jobs: &[Job], pool: &mut Vec<Vec<u8>>) -> Result<WaveFrames> {
    let mut cache = SpliceCache::default();
    let mut frames = Vec::with_capacity(jobs.len());
    let mut payload_total = 0usize;
    for job in jobs {
        let mut buf = pool.pop().unwrap_or_default();
        buf.clear();
        payload_total += frame_into(&mut buf, KIND_JOB, |b| encode_job_to(b, job, &mut cache))?;
        frames.push(buf);
    }
    Ok(WaveFrames {
        frames,
        fresh_payload_bytes: payload_total - cache.spliced,
        spliced_payload_bytes: cache.spliced,
    })
}

/// Serialize a snapshot-referencing job payload (no frame header): the
/// `Nearest` / `BpDescend` matrix is replaced by `snap_id`, which the peer
/// resolves against its session snapshot cache. Errors for job kinds that
/// carry no snapshot.
pub fn encode_snapref_job(job: &Job, snap_id: u64) -> Result<Vec<u8>> {
    let mut b = Vec::new();
    match job {
        Job::Nearest { range, .. } => {
            put_u8(&mut b, JOB_NEAREST_SNAP);
            put_range(&mut b, range);
            put_u64(&mut b, snap_id);
        }
        Job::BpDescend { range, sweeps, .. } => {
            put_u8(&mut b, JOB_BP_DESCEND_SNAP);
            put_range(&mut b, range);
            put_u64(&mut b, snap_id);
            put_usize(&mut b, *sweeps);
        }
        _ => return Err(wire_err("job kind carries no snapshot to reference")),
    }
    Ok(b)
}

/// A complete snapshot-referencing job frame, ready to write.
pub fn snapref_job_frame(job: &Job, snap_id: u64) -> Result<Vec<u8>> {
    frame(KIND_JOB, encode_snapref_job(job, snap_id)?)
}

/// Resolve a snapshot reference against the peer's single-entry cache.
fn resolve_snap(snap: Option<&(u64, Arc<Matrix>)>, id: u64) -> Result<Arc<Matrix>> {
    match snap {
        Some((held, m)) if *held == id => Ok(m.clone()),
        Some((held, _)) => Err(wire_err(format!(
            "job references snapshot id {id}, peer holds id {held}"
        ))),
        None => Err(wire_err(format!(
            "job references snapshot id {id}, peer holds no snapshot"
        ))),
    }
}

/// Deserialize a job payload, validating internal invariants (range
/// orientation, index bounds) so a corrupt frame cannot poison a peer.
/// Snapshot-referencing encodings resolve against `snap`, the peer
/// session's cached `(id, matrix)` entry; a missing or mismatched id is a
/// typed error.
pub fn decode_job_snap(payload: &[u8], snap: Option<&(u64, Arc<Matrix>)>) -> Result<Job> {
    let mut r = Reader::new(payload);
    let job = match r.u8()? {
        JOB_NEAREST => {
            let range = get_range(&mut r)?;
            let centers = Arc::new(get_matrix(&mut r)?);
            Job::Nearest { range, centers }
        }
        JOB_SUFFSTATS => {
            let range = get_range(&mut r)?;
            let assignments = get_u32_vec(&mut r)?;
            let k = r.usize()?;
            if assignments.len() < range.end {
                return Err(wire_err(format!(
                    "suffstats assignments cover {} points, range ends at {}",
                    assignments.len(),
                    range.end
                )));
            }
            Job::SuffStats { range, assignments: Arc::new(assignments), k }
        }
        JOB_BP_DESCEND => {
            let range = get_range(&mut r)?;
            let features = Arc::new(get_matrix(&mut r)?);
            let sweeps = r.usize()?;
            Job::BpDescend { range, features, sweeps }
        }
        JOB_BP_STATS => {
            let range = get_range(&mut r)?;
            let rows = r.len_of(8)?;
            let mut z = Vec::with_capacity(rows);
            for _ in 0..rows {
                z.push(get_bool_vec(&mut r)?);
            }
            let k = r.usize()?;
            if z.len() < range.end {
                return Err(wire_err(format!(
                    "bp-stats z covers {} points, range ends at {}",
                    z.len(),
                    range.end
                )));
            }
            Job::BpStats { range, z: Arc::new(z), k }
        }
        JOB_PAIR_CACHE => {
            let vectors = get_matrix(&mut r)?;
            let positions = get_u32_vec(&mut r)?;
            let nshards = r.len_of(8)?;
            let mut shards = Vec::with_capacity(nshards);
            for _ in 0..nshards {
                shards.push(get_u32_vec(&mut r)?);
            }
            // Same geometry rules as the executor, single-sourced so a
            // corrupt frame is rejected here with the exact invariants the
            // job would be run under.
            super::engine::check_pair_cache_geometry(vectors.rows, &positions, &shards)?;
            Job::PairCache { vectors: Arc::new(vectors), positions, shards }
        }
        JOB_SHUTDOWN => Job::Shutdown,
        JOB_NEAREST_SNAP => {
            let range = get_range(&mut r)?;
            let centers = resolve_snap(snap, r.u64()?)?;
            Job::Nearest { range, centers }
        }
        JOB_BP_DESCEND_SNAP => {
            let range = get_range(&mut r)?;
            let features = resolve_snap(snap, r.u64()?)?;
            let sweeps = r.usize()?;
            Job::BpDescend { range, features, sweeps }
        }
        other => return Err(wire_err(format!("unknown job tag {other}"))),
    };
    r.finish()?;
    Ok(job)
}

/// Deserialize a job payload that embeds all its payloads inline (no
/// snapshot cache available — a snapshot-referencing encoding is rejected).
pub fn decode_job(payload: &[u8]) -> Result<Job> {
    decode_job_snap(payload, None)
}

// ---------------------------------------------------------------------------
// Session handshake and dataset distribution
// ---------------------------------------------------------------------------

/// Which plane a peer serves — carried in the [`Hello`] handshake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerRole {
    /// Epoch-compute worker (owns point blocks).
    Compute,
    /// Validator shard (owns conflict-key bucket ranges per wave).
    Validate,
}

impl PeerRole {
    fn code(self) -> u8 {
        match self {
            PeerRole::Compute => 0,
            PeerRole::Validate => 1,
        }
    }
    fn from_code(c: u8) -> Result<PeerRole> {
        match c {
            0 => Ok(PeerRole::Compute),
            1 => Ok(PeerRole::Validate),
            other => Err(wire_err(format!("unknown peer role {other}"))),
        }
    }
    /// Role name (logs / errors).
    pub fn name(self) -> &'static str {
        match self {
            PeerRole::Compute => "compute",
            PeerRole::Validate => "validate",
        }
    }
}

/// The master → peer session handshake: protocol version, the peer's shard
/// assignment (role + id within a plane of `peers_in_plane`), and the
/// dataset geometry so the peer can size its local store before any
/// [`KIND_DATA`] block arrives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    /// Sender's wire-format version. Receivers reject a mismatch with a
    /// typed error instead of guessing at the schema — the frame header
    /// carries the version too, but the handshake makes the rejection
    /// explicit and reportable before any work is exchanged.
    pub proto: u16,
    /// Plane the peer is being enrolled into.
    pub role: PeerRole,
    /// Peer id within its plane; replies are attributed by this id.
    pub peer_id: u32,
    /// Plane size — the shard assignment is (`peer_id`, of this many).
    pub peers_in_plane: u32,
    /// Dataset points (rows of the global point matrix).
    pub n: u64,
    /// Dataset dimensionality.
    pub dim: u64,
}

/// Serialize a handshake payload (no frame header).
pub fn encode_hello(h: &Hello) -> Vec<u8> {
    let mut b = Vec::new();
    put_u16(&mut b, h.proto);
    put_u8(&mut b, h.role.code());
    put_u32(&mut b, h.peer_id);
    put_u32(&mut b, h.peers_in_plane);
    put_u64(&mut b, h.n);
    put_u64(&mut b, h.dim);
    b
}

/// Deserialize a handshake payload, rejecting a protocol-version mismatch
/// with a typed error.
pub fn decode_hello(payload: &[u8]) -> Result<Hello> {
    let mut r = Reader::new(payload);
    let proto = r.u16()?;
    if proto != VERSION {
        return Err(wire_err(format!(
            "hello protocol version {proto}, expected {VERSION}"
        )));
    }
    let role = PeerRole::from_code(r.u8()?)?;
    let peer_id = r.u32()?;
    let peers_in_plane = r.u32()?;
    let n = r.u64()?;
    let dim = r.u64()?;
    r.finish()?;
    Ok(Hello { proto, role, peer_id, peers_in_plane, n, dim })
}

/// A complete handshake frame, ready to write.
pub fn hello_frame(h: &Hello) -> Result<Vec<u8>> {
    frame(KIND_HELLO, encode_hello(h))
}

/// The peer's answer to a [`Hello`]: its own protocol version, whether it
/// accepted the session, and a reason when it did not.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HelloAck {
    /// The peer's wire-format version.
    pub proto: u16,
    /// True if the peer accepted the session.
    pub ok: bool,
    /// Rejection reason (empty on acceptance).
    pub message: String,
}

/// Serialize an acknowledgement payload (no frame header).
pub fn encode_hello_ack(a: &HelloAck) -> Vec<u8> {
    let mut b = Vec::new();
    put_u16(&mut b, a.proto);
    put_u8(&mut b, u8::from(a.ok));
    put_str(&mut b, &a.message);
    b
}

/// Deserialize an acknowledgement. Unlike [`decode_hello`] this does *not*
/// reject a foreign version: the master needs the peer's version to report
/// a useful mismatch error.
pub fn decode_hello_ack(kind: u16, payload: &[u8]) -> Result<HelloAck> {
    if kind != KIND_HELLO_ACK {
        return Err(wire_err(format!("expected a hello-ack frame, got kind {kind}")));
    }
    let mut r = Reader::new(payload);
    let proto = r.u16()?;
    let ok = match r.u8()? {
        0 => false,
        1 => true,
        other => return Err(wire_err(format!("invalid hello-ack flag {other}"))),
    };
    let message = get_str(&mut r)?;
    r.finish()?;
    Ok(HelloAck { proto, ok, message })
}

/// A complete acknowledgement frame, ready to write.
pub fn hello_ack_frame(a: &HelloAck) -> Result<Vec<u8>> {
    frame(KIND_HELLO_ACK, encode_hello_ack(a))
}

/// Serialize a dataset block: `block.rows` points starting at global point
/// index `offset` (no frame header).
pub fn encode_data_block(offset: usize, block: &Matrix) -> Vec<u8> {
    let mut b = Vec::new();
    put_usize(&mut b, offset);
    put_matrix(&mut b, block);
    b
}

/// A complete dataset-block frame, ready to write.
pub fn data_frame(offset: usize, block: &Matrix) -> Result<Vec<u8>> {
    frame(KIND_DATA, encode_data_block(offset, block))
}

/// Deserialize a dataset block into `(offset, points)`.
pub fn decode_data_block(payload: &[u8]) -> Result<(usize, Matrix)> {
    let mut r = Reader::new(payload);
    let offset = r.usize()?;
    let block = get_matrix(&mut r)?;
    r.finish()?;
    Ok((offset, block))
}

// ---------------------------------------------------------------------------
// Snapshot distribution: full installs and delta re-bases
// ---------------------------------------------------------------------------

/// Serialize a full snapshot install (no frame header): the peer replaces
/// its single-entry snapshot cache with `(id, matrix)`.
pub fn encode_snapshot(id: u64, m: &Matrix) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, id);
    put_matrix(&mut b, m);
    b
}

/// A complete full-snapshot frame, ready to write.
pub fn snapshot_frame(id: u64, m: &Matrix) -> Result<Vec<u8>> {
    frame(KIND_SNAPSHOT, encode_snapshot(id, m))
}

/// Deserialize a full snapshot install into `(id, matrix)`.
pub fn decode_snapshot(payload: &[u8]) -> Result<(u64, Matrix)> {
    let mut r = Reader::new(payload);
    let id = r.u64()?;
    let m = get_matrix(&mut r)?;
    r.finish()?;
    Ok((id, m))
}

/// A snapshot delta: the new snapshot `id` equals the first `base_rows`
/// rows of the peer's cached snapshot `base_id` followed by the `tail`
/// rows. Because both sides move raw f32 bit patterns, the reconstruction
/// is bit-exact by construction ([`SnapshotDelta::apply`], property-checked
/// in `rust/tests/wire_format.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotDelta {
    /// Id the reconstructed snapshot is installed under.
    pub id: u64,
    /// Cache entry the delta re-bases (must match the peer's held id).
    pub base_id: u64,
    /// Prefix rows reused from the base (must equal the base's row count —
    /// the committed state only ever *appends* between epochs; a rewrite
    /// ships a full snapshot instead).
    pub base_rows: usize,
    /// Appended rows (`0` rows = the state did not grow).
    pub tail: Matrix,
}

impl SnapshotDelta {
    /// Reconstruct the full snapshot from the peer's cached base entry.
    /// Every mismatch (wrong base id, wrong geometry) is a typed error —
    /// the master only sends a delta when it knows the peer's cache state,
    /// so a mismatch means the session must re-base from a full frame.
    pub fn apply(&self, held_id: u64, base: &Matrix) -> Result<Matrix> {
        if held_id != self.base_id {
            return Err(wire_err(format!(
                "snapshot delta re-bases id {}, peer holds id {held_id}",
                self.base_id
            )));
        }
        if base.rows != self.base_rows {
            return Err(wire_err(format!(
                "snapshot delta expects a {}-row base, cached snapshot has {} rows",
                self.base_rows, base.rows
            )));
        }
        if base.rows > 0 && self.tail.rows > 0 && base.cols != self.tail.cols {
            return Err(wire_err(format!(
                "snapshot delta width {} does not match the cached base width {}",
                self.tail.cols, base.cols
            )));
        }
        let cols = if base.rows > 0 { base.cols } else { self.tail.cols };
        let mut data = Vec::with_capacity((self.base_rows + self.tail.rows) * cols);
        data.extend_from_slice(&base.data[..self.base_rows * base.cols.min(cols)]);
        data.extend_from_slice(&self.tail.data);
        Ok(Matrix { rows: self.base_rows + self.tail.rows, cols, data })
    }
}

/// Serialize a snapshot delta (no frame header).
pub fn encode_snapshot_delta(d: &SnapshotDelta) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, d.id);
    put_u64(&mut b, d.base_id);
    put_usize(&mut b, d.base_rows);
    put_matrix(&mut b, &d.tail);
    b
}

/// A complete snapshot-delta frame, ready to write.
pub fn snapshot_delta_frame(d: &SnapshotDelta) -> Result<Vec<u8>> {
    frame(KIND_SNAPSHOT_DELTA, encode_snapshot_delta(d))
}

/// Deserialize a snapshot delta.
pub fn decode_snapshot_delta(payload: &[u8]) -> Result<SnapshotDelta> {
    let mut r = Reader::new(payload);
    let id = r.u64()?;
    let base_id = r.u64()?;
    let base_rows = r.usize()?;
    let tail = get_matrix(&mut r)?;
    r.finish()?;
    Ok(SnapshotDelta { id, base_id, base_rows, tail })
}

// ---------------------------------------------------------------------------
// Streaming ingest: chunks, acks, queries (`occd serve` client sessions)
// ---------------------------------------------------------------------------

/// One client chunk offered for admission: a client-chosen sequence number
/// (echoed in the ack, so a pipelining client can match acks to chunks)
/// and the points themselves. A 0-row matrix is the end-of-stream marker.
#[derive(Debug, Clone, PartialEq)]
pub struct Ingest {
    /// Client-chosen chunk sequence number, echoed verbatim in the ack.
    pub seq: u64,
    /// Points offered for admission; 0 rows = end-of-stream.
    pub points: Matrix,
}

impl Ingest {
    /// True if this chunk is the end-of-stream marker.
    pub fn is_eos(&self) -> bool {
        self.points.rows == 0
    }
}

/// Typed admission outcome carried in a [`KIND_INGEST_ACK`] frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IngestStatus {
    /// The chunk was admitted; ack `detail` = total points admitted so far.
    Accepted,
    /// The bounded admission queue is full; the chunk was **not** admitted
    /// (re-send it). Ack `detail` = the configured queue bound.
    Throttled,
    /// The payload failed to decode or validate; the chunk was not
    /// admitted and will never be (`message` says why). Framing stayed
    /// intact, so the session survives.
    Rejected,
}

impl IngestStatus {
    fn code(self) -> u8 {
        match self {
            IngestStatus::Accepted => 0,
            IngestStatus::Throttled => 1,
            IngestStatus::Rejected => 2,
        }
    }
    fn from_code(c: u8) -> Result<IngestStatus> {
        match c {
            0 => Ok(IngestStatus::Accepted),
            1 => Ok(IngestStatus::Throttled),
            2 => Ok(IngestStatus::Rejected),
            other => Err(wire_err(format!("unknown ingest-ack status {other}"))),
        }
    }
    /// Status name (logs / errors).
    pub fn name(self) -> &'static str {
        match self {
            IngestStatus::Accepted => "accepted",
            IngestStatus::Throttled => "throttled",
            IngestStatus::Rejected => "rejected",
        }
    }
}

/// The gateway's per-chunk admission acknowledgement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IngestAck {
    /// The chunk's `seq`, echoed verbatim.
    pub seq: u64,
    /// Typed admission outcome.
    pub status: IngestStatus,
    /// Status-dependent detail (admitted total / queue bound / 0).
    pub detail: u64,
    /// Human-readable rejection reason (empty otherwise).
    pub message: String,
}

/// Serialize an ingest chunk (no frame header).
pub fn encode_ingest(i: &Ingest) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, i.seq);
    put_matrix(&mut b, &i.points);
    b
}

/// A complete ingest frame, ready to write.
pub fn ingest_frame(i: &Ingest) -> Result<Vec<u8>> {
    frame(KIND_INGEST, encode_ingest(i))
}

/// Deserialize an ingest chunk. Geometry is validated (a rows×cols
/// overflow or truncated payload is a typed error, never a panic) — the
/// gateway turns such errors into `Rejected` acks rather than dropping
/// the session.
pub fn decode_ingest(payload: &[u8]) -> Result<Ingest> {
    let mut r = Reader::new(payload);
    let seq = r.u64()?;
    let points = get_matrix(&mut r)?;
    r.finish()?;
    Ok(Ingest { seq, points })
}

/// Serialize an admission acknowledgement (no frame header).
pub fn encode_ingest_ack(a: &IngestAck) -> Vec<u8> {
    let mut b = Vec::new();
    put_u64(&mut b, a.seq);
    put_u8(&mut b, a.status.code());
    put_u64(&mut b, a.detail);
    put_str(&mut b, &a.message);
    b
}

/// A complete ingest-ack frame, ready to write.
pub fn ingest_ack_frame(a: &IngestAck) -> Result<Vec<u8>> {
    frame(KIND_INGEST_ACK, encode_ingest_ack(a))
}

/// Deserialize an admission acknowledgement.
pub fn decode_ingest_ack(payload: &[u8]) -> Result<IngestAck> {
    let mut r = Reader::new(payload);
    let seq = r.u64()?;
    let status = IngestStatus::from_code(r.u8()?)?;
    let detail = r.u64()?;
    let message = get_str(&mut r)?;
    r.finish()?;
    Ok(IngestAck { seq, status, detail, message })
}

/// A complete (empty-payload) model-query frame, ready to write.
pub fn query_frame() -> Result<Vec<u8>> {
    frame(KIND_QUERY, Vec::new())
}

// ---------------------------------------------------------------------------
// JobOutput encoding
// ---------------------------------------------------------------------------

const OUT_NEAREST: u8 = 0;
const OUT_SUFFSTATS: u8 = 1;
const OUT_BP_DESCEND: u8 = 2;
const OUT_BP_STATS: u8 = 3;
const OUT_PAIR_CACHE: u8 = 4;

/// Serialize a job output payload (no frame header).
pub fn encode_output(out: &JobOutput) -> Vec<u8> {
    let mut b = Vec::new();
    match out {
        JobOutput::Nearest { idx, d2 } => {
            put_u8(&mut b, OUT_NEAREST);
            put_u32_slice(&mut b, idx);
            put_f32_slice(&mut b, d2);
        }
        JobOutput::SuffStats { chunks } => {
            put_u8(&mut b, OUT_SUFFSTATS);
            put_usize(&mut b, chunks.len());
            for (id, sums, counts) in chunks {
                put_usize(&mut b, *id);
                put_matrix(&mut b, sums);
                put_u64_slice(&mut b, counts);
            }
        }
        JobOutput::BpDescend { z, k, residuals, r2 } => {
            put_u8(&mut b, OUT_BP_DESCEND);
            put_bool_slice(&mut b, z);
            put_usize(&mut b, *k);
            put_f32_slice(&mut b, residuals);
            put_f32_slice(&mut b, r2);
        }
        JobOutput::BpStats { chunks } => {
            put_u8(&mut b, OUT_BP_STATS);
            put_usize(&mut b, chunks.len());
            for (id, ztz, ztx) in chunks {
                put_usize(&mut b, *id);
                put_matrix(&mut b, ztz);
                put_matrix(&mut b, ztx);
            }
        }
        JobOutput::PairCache { pairs } => {
            put_u8(&mut b, OUT_PAIR_CACHE);
            put_usize(&mut b, pairs.len());
            for (a, x, d) in pairs {
                put_u32(&mut b, *a);
                put_u32(&mut b, *x);
                put_f32(&mut b, *d);
            }
        }
    }
    b
}

/// Deserialize a job output payload.
pub fn decode_output(r: &mut Reader) -> Result<JobOutput> {
    Ok(match r.u8()? {
        OUT_NEAREST => {
            let idx = get_u32_vec(r)?;
            let d2 = get_f32_vec(r)?;
            if idx.len() != d2.len() {
                return Err(wire_err("nearest idx/d2 length mismatch"));
            }
            JobOutput::Nearest { idx, d2 }
        }
        OUT_SUFFSTATS => {
            let n = r.len_of(8)?;
            let mut chunks = Vec::with_capacity(n);
            for _ in 0..n {
                let id = r.usize()?;
                let sums = get_matrix(r)?;
                let counts = get_u64_vec(r)?;
                chunks.push((id, sums, counts));
            }
            JobOutput::SuffStats { chunks }
        }
        OUT_BP_DESCEND => {
            let z = get_bool_vec(r)?;
            let k = r.usize()?;
            let residuals = get_f32_vec(r)?;
            let r2 = get_f32_vec(r)?;
            JobOutput::BpDescend { z, k, residuals, r2 }
        }
        OUT_BP_STATS => {
            let n = r.len_of(8)?;
            let mut chunks = Vec::with_capacity(n);
            for _ in 0..n {
                let id = r.usize()?;
                let ztz = get_matrix(r)?;
                let ztx = get_matrix(r)?;
                chunks.push((id, ztz, ztx));
            }
            JobOutput::BpStats { chunks }
        }
        OUT_PAIR_CACHE => {
            let n = r.len_of(12)?;
            let mut pairs = Vec::with_capacity(n);
            for _ in 0..n {
                let a = r.u32()?;
                let b = r.u32()?;
                let d = r.f32()?;
                pairs.push((a, b, d));
            }
            JobOutput::PairCache { pairs }
        }
        other => return Err(wire_err(format!("unknown output tag {other}"))),
    })
}

// ---------------------------------------------------------------------------
// Frames
// ---------------------------------------------------------------------------

/// Append a complete framed message to `out`, building the payload in
/// place with `build` — the amortized-zero-allocation twin of [`frame`]
/// for the reusable per-session encode buffers on the TCP hot path. The
/// 12-byte header goes down first with a length placeholder, the
/// payload is encoded directly behind it, and the length is patched
/// afterwards; the bytes produced are identical to [`frame`]'s. Returns
/// the payload length.
pub fn frame_into(
    out: &mut Vec<u8>,
    kind: u16,
    build: impl FnOnce(&mut Vec<u8>),
) -> Result<usize> {
    let head = out.len();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes()); // patched below
    let start = out.len();
    build(out);
    let len = out.len() - start;
    if len > MAX_FRAME as usize {
        return Err(wire_err(format!("oversized frame: {len} bytes")));
    }
    out[head + 8..head + 12].copy_from_slice(&(len as u32).to_le_bytes());
    Ok(len)
}

/// Wrap a payload in a framed message.
pub fn frame(kind: u16, payload: Vec<u8>) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    frame_into(&mut out, kind, |b| b.extend_from_slice(&payload))?;
    Ok(out)
}

/// Append a complete dataset-block frame to `out`, encoding `rows`
/// points of width `cols` straight from the dataset's backing slice —
/// no intermediate `Matrix` copy. Byte-identical to
/// [`data_frame`] over `Matrix { rows, cols, data: data.to_vec() }`.
pub fn data_rows_frame_into(
    out: &mut Vec<u8>,
    offset: usize,
    rows: usize,
    cols: usize,
    data: &[f32],
) -> Result<usize> {
    debug_assert_eq!(data.len(), rows * cols);
    frame_into(out, KIND_DATA, |b| {
        put_usize(b, offset);
        put_usize(b, rows);
        put_usize(b, cols);
        for &x in data {
            put_f32(b, x);
        }
    })
}

/// Append a complete full-snapshot frame to `out` (see
/// [`snapshot_frame`]).
pub fn snapshot_frame_into(out: &mut Vec<u8>, id: u64, m: &Matrix) -> Result<usize> {
    frame_into(out, KIND_SNAPSHOT, |b| {
        put_u64(b, id);
        put_matrix(b, m);
    })
}

/// Append a complete snapshot-delta frame to `out` (see
/// [`snapshot_delta_frame`]).
pub fn snapshot_delta_frame_into(out: &mut Vec<u8>, d: &SnapshotDelta) -> Result<usize> {
    frame_into(out, KIND_SNAPSHOT_DELTA, |b| {
        put_u64(b, d.id);
        put_u64(b, d.base_id);
        put_usize(b, d.base_rows);
        put_matrix(b, &d.tail);
    })
}

/// Append a complete snapshot-referencing job frame to `out` (see
/// [`snapref_job_frame`]).
pub fn snapref_job_frame_into(out: &mut Vec<u8>, job: &Job, snap_id: u64) -> Result<usize> {
    let payload = encode_snapref_job(job, snap_id)?;
    frame_into(out, KIND_JOB, |b| b.extend_from_slice(&payload))
}

/// A complete job frame, ready to write.
pub fn job_frame(job: &Job) -> Result<Vec<u8>> {
    frame(KIND_JOB, encode_job(job))
}

/// A complete reply frame for a peer's result.
pub fn reply_frame(
    worker: u32,
    busy: Duration,
    output: &Result<JobOutput>,
) -> Result<Vec<u8>> {
    let mut b = Vec::new();
    put_u32(&mut b, worker);
    put_u64(&mut b, busy.as_micros().min(u128::from(u64::MAX)) as u64);
    match output {
        Ok(out) => {
            b.extend_from_slice(&encode_output(out));
            frame(KIND_REPLY_OK, b)
        }
        Err(e) => {
            put_str(&mut b, &e.to_string());
            frame(KIND_REPLY_ERR, b)
        }
    }
}

/// Read one frame without judging its version: `(version, kind, payload)`.
/// Fails with a typed error on EOF, bad magic or an oversized length.
///
/// This exists for the two handshake reads — the peer's first frame and
/// the master's ack read — where a *foreign* version must still be parsed
/// far enough to report it (the `Hello`/`HelloAck` payload layout is the
/// frozen negotiation anchor across versions). Everything mid-session uses
/// [`read_frame`], which rejects a foreign version outright.
pub fn read_frame_any_version(r: &mut impl Read) -> Result<(u16, u16, Vec<u8>)> {
    let mut head = [0u8; HEADER_LEN];
    r.read_exact(&mut head)
        .map_err(|e| wire_err(format!("truncated frame header: {e}")))?;
    let magic = u32::from_le_bytes(head[0..4].try_into().expect("4 bytes"));
    let version = u16::from_le_bytes(head[4..6].try_into().expect("2 bytes"));
    let kind = u16::from_le_bytes(head[6..8].try_into().expect("2 bytes"));
    let len = u32::from_le_bytes(head[8..12].try_into().expect("4 bytes"));
    if magic != MAGIC {
        return Err(wire_err(format!("bad magic {magic:#010x}")));
    }
    if len > MAX_FRAME {
        return Err(wire_err(format!("oversized frame: {len} bytes")));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| wire_err(format!("truncated frame payload: {e}")))?;
    Ok((version, kind, payload))
}

/// Read one frame: `(kind, payload)`. Fails with a typed error on EOF,
/// bad magic, version mismatch or an oversized length.
pub fn read_frame(r: &mut impl Read) -> Result<(u16, Vec<u8>)> {
    let (version, kind, payload) = read_frame_any_version(r)?;
    if version != VERSION {
        return Err(wire_err(format!("wire version {version}, expected {VERSION}")));
    }
    Ok((kind, payload))
}

/// Incremental frame parser for readiness-polled reads: try to pop one
/// complete frame off the front of `buf` (bytes accumulated from a
/// nonblocking socket). Returns `Ok(None)` while the buffered prefix is an
/// incomplete-but-valid frame, `Ok(Some((kind, payload)))` once a whole
/// frame is buffered (the frame's bytes are drained from `buf`), and a
/// typed error on bad magic, foreign version or an oversized length — the
/// same rejections [`read_frame`] makes, just without blocking. This is
/// what lets the master's gather retire replies in arrival order instead
/// of peer order (see [`super::tcp`]).
pub fn poll_frame(buf: &mut Vec<u8>) -> Result<Option<(u16, Vec<u8>)>> {
    if buf.len() < HEADER_LEN {
        // An invalid magic is detectable as soon as 4 bytes arrive; fail
        // early rather than waiting for a header that can never be valid.
        if buf.len() >= 4 {
            let magic = u32::from_le_bytes(buf[0..4].try_into().expect("4 bytes"));
            if magic != MAGIC {
                return Err(wire_err(format!("bad magic {magic:#010x}")));
            }
        }
        return Ok(None);
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().expect("4 bytes"));
    let version = u16::from_le_bytes(buf[4..6].try_into().expect("2 bytes"));
    let kind = u16::from_le_bytes(buf[6..8].try_into().expect("2 bytes"));
    let len = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
    if magic != MAGIC {
        return Err(wire_err(format!("bad magic {magic:#010x}")));
    }
    if version != VERSION {
        return Err(wire_err(format!("wire version {version}, expected {VERSION}")));
    }
    if len > MAX_FRAME {
        return Err(wire_err(format!("oversized frame: {len} bytes")));
    }
    let total = HEADER_LEN + len as usize;
    if buf.len() < total {
        return Ok(None);
    }
    let payload = buf[HEADER_LEN..total].to_vec();
    buf.drain(..total);
    Ok(Some((kind, payload)))
}

/// Peer side: read one frame and decode the job it must carry.
pub fn read_job(r: &mut impl Read) -> Result<Job> {
    let (kind, payload) = read_frame(r)?;
    if kind != KIND_JOB {
        return Err(wire_err(format!("expected a job frame, got kind {kind}")));
    }
    decode_job(&payload)
}

/// Decode a reply payload read off the wire.
pub fn decode_reply(kind: u16, payload: &[u8]) -> Result<JobReply> {
    let mut r = Reader::new(payload);
    let worker = r.u32()? as usize;
    let busy = Duration::from_micros(r.u64()?);
    match kind {
        KIND_REPLY_OK => {
            let output = decode_output(&mut r)?;
            r.finish()?;
            Ok(JobReply { worker, output: Ok(output), busy })
        }
        KIND_REPLY_ERR => {
            let msg = get_str(&mut r)?;
            r.finish()?;
            Ok(JobReply { worker, output: Err(Error::Coordinator(msg)), busy })
        }
        other => Err(wire_err(format!("expected a reply frame, got kind {other}"))),
    }
}

/// Peer side: frame and write one reply.
pub fn write_reply(
    w: &mut impl Write,
    worker: u32,
    busy: Duration,
    output: &Result<JobOutput>,
) -> Result<()> {
    let bytes = reply_frame(worker, busy, output)?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}
