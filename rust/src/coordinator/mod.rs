//! The OCC coordinator — the paper's system contribution (L3), organized
//! as three planes.
//!
//! The paper's pattern (§1.1) is: workers run *optimistic transactions*
//! against a replicated snapshot of the global state; a master *validates*
//! the epoch's proposals serially and repairs the optimistic assumptions
//! that failed. This crate separates the machinery into three orthogonal
//! planes, each swappable without touching the others:
//!
//! ## 1. The scheduling plane — *when* steps run
//!
//! [`scheduler`] owns the epoch loop: the depth-K speculative **wave
//! engine**. Each epoch is a wave (`Scattered → Gathered → Validating →
//! Committed | Respun`) driven by an event loop that reacts to transport
//! readiness, with validation on a dedicated thread behind a bounded
//! commit queue — so epoch `t`'s validation, epoch `t+1`'s gather and
//! epoch `t+2`'s scatter proceed concurrently. The `speculation = K` knob
//! sets how many epochs may be resident at once (1 = the paper's Fig 5
//! barrier, 2 = the classic two-stage pipeline, higher depths hide longer
//! validation tails); DP-means/OFL waves are delta-patched across however
//! many commits they speculated past, and a conflicting BP-means commit
//! cancels and respins every in-flight descendant — all preserving the
//! Theorem 3.1 serial order bit for bit, at every depth. [`driver`]
//! supplies the per-algorithm epoch hooks (job construction, merge,
//! validation — OCC DP-means Alg 3, OFL Alg 4, BP-means Alg 6) plus the
//! §4.2 bootstrap and the mean-recompute phases.
//!
//! ## 2. The transport plane — *where* messages move
//!
//! [`transport`] hides the cluster behind per-plane `PlaneIo` endpoints
//! reached through the split `Cluster` facade: scatter one [`engine::Job`]
//! per peer, gather one reply per peer, on either of two peer groups
//! (compute workers and validator shards) — with a **multi-wave pending
//! set**, so up to `speculation` waves are outstanding at once and retire
//! by wave id in readiness order. The two plane handles are independently
//! owned: the wave engine's event loop drives `cluster.compute` while the
//! validation thread owns `cluster.validate`. In-proc planes keep the
//! zero-copy fast path (`mpsc` channels, `Arc` snapshots); [`tcp`] puts
//! every peer behind a socket and moves jobs, snapshots, replies *and the
//! dataset itself* through [`wire`] — an explicit, versioned,
//! length-prefixed format with bit-exact f32 encoding. A `Topology`
//! decides where the TCP peers live: loopback threads of this process
//! behind persistent listeners (the default, and what CI sweeps), or
//! standalone `occd worker` processes addressed by `peers =
//! ["host:port", ...]` — the multi-host deployment (see the README
//! runbook). Sessions open with a versioned `Hello` handshake; workers are
//! shipped exactly the point ranges their jobs read; a dropped peer —
//! loopback or remote — is retried under one bounded reconnect policy and
//! poisons only the waves it still owes.
//! The per-epoch hot path is on a wire diet (default; `frugal_wire =
//! false` restores the embed-everything shape): epoch snapshots ship as
//! versioned *delta frames* against a per-session snapshot cache — only
//! the rows validation appended, with automatic full-snapshot re-base on a
//! rewrite or a replacement peer — validator shards receive only the
//! proposal rows their conflict-key range reads (`O(M·d)` total instead of
//! `O(V·M·d)`), and `gather` retires replies in arrival order through a
//! readiness-polled loop instead of fixed peer order. All three are
//! bit-exactness-preserving by construction. Under `io = "reactor"`
//! (the default) every blocking wait on this plane lands in
//! [`reactor`] — one epoll/poll(2) readiness queue over all peer
//! sockets plus the validation thread's commit wakeup — and writes go
//! out as vectored batches from per-peer pending-write queues; `io =
//! "poll"` keeps the legacy sleep-slice loops as the A/B baseline.
//! [`engine`] holds the job types, the shared job executor and the
//! in-process `WorkerPool`.
//!
//! ## 3. The validation plane — *what commits*
//!
//! [`validator`] is the master's epoch-boundary step: `DPValidate` (Alg 2),
//! `OFLValidate` (Alg 5), `BPValidate` (Alg 8), consuming proposals in
//! point-index order — exactly the serial permutation of Theorem 3.1 /
//! Appendix B. The expensive conflict pre-computation is sharded by
//! conflict key: shards are *peers on the transport* (each owns a
//! conflict-key range and returns a per-shard conflict cache; the master
//! combines caches with a deterministic tree reduce in point-index order)
//! or, for the thread-local fallback, scoped threads computing the same
//! caches. Either way the serial merge replays the exact Thm 3.1 decision
//! sequence from bit-identical cached distances. [`soft`] adds the §6
//! relaxed-consistency knob on top.
//!
//! ## Determinism
//!
//! For a fixed dataset, seed, and epoch size `P·b`, the result is
//! *identical for every worker count `P`* — proposals are merged and
//! validated in point-index order, and block boundaries depend only on
//! `P·b` (`rust/tests/serializability.rs`). The same invariant holds
//! across scheduling policies and speculation depths
//! (`rust/tests/scheduler_equivalence.rs`) and across transports
//! (`rust/tests/transport_equivalence.rs`): BSP vs the wave engine at any
//! `speculation`, in-proc vs TCP — all produce bit-identical models,
//! because every validation call receives byte-identical inputs in the
//! identical order no matter how the bytes travelled or how far the
//! pipeline speculated.

pub mod driver;
pub mod engine;
pub mod reactor;
pub mod scheduler;
pub mod serve;
pub mod soft;
pub mod tcp;
pub mod transport;
pub mod validator;
pub mod wire;

pub use driver::{run, run_with, Model, RunOutput};
pub use tcp::serve_peer;
pub use transport::{Cluster, PlaneHandle, PlaneIo, Topology, ValidatePlane};

#[cfg(test)]
mod no_sleep_tests {
    /// Every `thread::sleep` on a coordinator path must be a *declared*
    /// poll-mode (or stub) arm, tagged with a trailing `// poll-mode`
    /// marker — under `io = "reactor"` nothing may hard-sleep; blocking
    /// moments belong in [`super::reactor::Reactor::wait`]. This is the
    /// grep the reviewer would run, frozen as a unit test. Test modules
    /// (everything from their `mod tests` line on) are exempt: tests
    /// sleep to stage races.
    #[test]
    fn every_coordinator_sleep_is_a_declared_poll_mode_arm() {
        let sources: &[(&str, &str)] = &[
            ("driver.rs", include_str!("driver.rs")),
            ("reactor.rs", include_str!("reactor.rs")),
            ("scheduler.rs", include_str!("scheduler.rs")),
            ("serve.rs", include_str!("serve.rs")),
            ("tcp.rs", include_str!("tcp.rs")),
            ("transport.rs", include_str!("transport.rs")),
        ];
        for (name, src) in sources {
            let non_test = src.split("mod tests").next().expect("split never empties");
            for (lineno, line) in non_test.lines().enumerate() {
                if line.contains("thread::sleep") && !line.trim_start().starts_with("//") {
                    assert!(
                        line.contains("// poll-mode"),
                        "{name}:{}: undeclared thread::sleep on a coordinator \
                         path — park in the reactor, or tag the line with \
                         `// poll-mode` if it IS the poll-mode arm:\n    {line}",
                        lineno + 1
                    );
                }
            }
        }
    }
}
