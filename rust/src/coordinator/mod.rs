//! The OCC coordinator — the paper's system contribution (L3).
//!
//! Implements the OCC pattern of §1.1 as a bulk-synchronous master/worker
//! engine:
//!
//! * [`engine`] — a persistent pool of P worker threads; each epoch the
//!   master scatters per-block jobs (nearest-center assignment, BP
//!   coordinate descent, sufficient statistics) and gathers results at the
//!   epoch barrier. Workers run the numeric hot path through a
//!   [`crate::runtime::ComputeBackend`] (native kernels or AOT XLA
//!   artifacts) — *optimistic transactions*.
//! * [`validator`] — the serial validation step executed by the master at
//!   each epoch boundary: `DPValidate` (Alg 2), `OFLValidate` (Alg 5) and
//!   `BPValidate` (Alg 8). Proposals are validated in point-index order,
//!   which realizes exactly the serial permutation of Theorem 3.1 /
//!   Appendix B.
//! * [`driver`] — assembles epochs, validation, the §4.2 bootstrap, the
//!   mean-recompute phases and metrics into full runs of OCC DP-means
//!   (Alg 3), OCC OFL (Alg 4) and OCC BP-means (Alg 6).
//! * [`scheduler`] — epoch scheduling policies: the classic BSP barrier
//!   and a pipelined schedule that overlaps epoch `t+1`'s worker compute
//!   with epoch `t`'s master-side validation while preserving the Thm 3.1
//!   serial order bit for bit.
//!
//! ## Determinism
//!
//! For a fixed dataset, seed, and epoch size `P·b`, the result is
//! *identical for every worker count `P`* — proposals are merged and
//! validated in point-index order, and block boundaries depend only on
//! `P·b`. This is the practical content of serializability and is enforced
//! by `rust/tests/serializability.rs`. The same invariant holds across
//! scheduling policies: `rust/tests/scheduler_equivalence.rs` checks that
//! BSP and pipelined runs produce bit-identical models.

pub mod driver;
pub mod engine;
pub mod scheduler;
pub mod soft;
pub mod validator;

pub use driver::{run, run_with, Model, RunOutput};
