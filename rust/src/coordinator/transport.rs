//! The cluster's communication planes, behind per-plane [`PlaneIo`]
//! endpoints.
//!
//! The coordinator talks to two groups of peers — *compute workers* (epoch
//! jobs: nearest-center assignment, coordinate descent, reductions) and
//! *validator shards* (conflict pre-computation for the master's validation
//! step). Each group is one *plane*: scatter one [`Job`] per peer, gather
//! one reply per peer. How the messages move is the plane's business:
//!
//! * in-proc — peers are threads in this process; jobs and snapshots cross
//!   the boundary by pointer ([`WorkerPool`]: `mpsc` channels + `Arc`).
//!   This is the zero-copy fast path and the default.
//! * [`super::tcp::TcpPlane`] — peers sit behind TCP sockets: loopback
//!   threads of this process by default, or standalone `occd worker`
//!   processes on other machines when a [`Topology`] lists `host:port`
//!   addresses. Every job, snapshot, reply — and the dataset itself, as
//!   demand-shipped block frames — is serialized through the explicit
//!   length-prefixed wire format of [`super::wire`]. Same coordinator,
//!   same bits.
//!
//! Both implement [`PlaneIo`], which is **multi-wave**: a scatter returns a
//! [`WaveId`], several waves may be in flight per plane at once, and waves
//! are retired by id — blocking ([`PlaneIo::gather`]) or polled
//! ([`PlaneIo::try_ready`]). That is what lets the wave-engine scheduler
//! keep `speculation = K` epochs resident and react to readiness instead
//! of blocking in epoch order.
//!
//! [`Cluster`] is the coordinator-facing facade. Unlike the earlier
//! single-object transport it is *split*: [`Cluster::compute`] and
//! [`Cluster::validate`] are independently borrowable (and `Send`)
//! endpoints, so the scheduler's event loop can drive compute waves on one
//! thread while the dedicated validation thread owns the validation plane.
//! Wire accounting is a [`SharedStats`] atomic block both planes write
//! into, so [`Cluster::stats`] (and per-epoch deltas of it) keep seeing the
//! whole transport. Serializability does not depend on any of this — all
//! state mutation stays in the master's validation step, and
//! `rust/tests/transport_equivalence.rs` checks models are bit-identical
//! across `{inproc, tcp} × speculation depths`.

use super::engine::{Job, JobOutput, JobReply, WorkerPool, WAKER_SENTINEL};
use crate::config::{IoKind, StoreKind, TransportKind};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::ComputeBackend;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub use super::engine::WaveId;

/// Cumulative wire-level accounting for a transport.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Bytes written to + read from the wire (frames, both directions).
    pub wire_bytes: u64,
    /// Bytes that passed through the encoder exactly once: `wire_bytes`
    /// minus every duplicated copy of an already-encoded payload (spliced
    /// shared job payloads, a snapshot frame written to P sockets). The gap
    /// between the two columns is the fan-out redundancy — what splicing
    /// and delta-shipping save the *encoder*, as opposed to the wire.
    pub unique_payload_bytes: u64,
    /// Master-side time spent encoding jobs and decoding replies.
    pub ser_time: Duration,
    /// Dataset-block payload bytes shipped to peers (a subset of
    /// `wire_bytes`; zero in-proc and on the validation plane, whose jobs
    /// carry their vectors inline).
    pub dataset_bytes: u64,
    /// Snapshot-delta payload bytes shipped (a subset of `wire_bytes`):
    /// the appended rows that replaced full per-epoch snapshot copies.
    pub delta_bytes: u64,
    /// Full-snapshot frames shipped because no delta was possible: a cold
    /// peer cache (first wave, reconnected replacement) or a committed
    /// state whose prefix was rewritten (mean recompute, BP re-estimate).
    pub full_snapshot_fallbacks: u64,
    /// Wall-clock spent in peer session handshakes — the initial `Hello`
    /// exchange per peer at spawn, plus any reconnect re-handshakes.
    pub handshake_time: Duration,
    /// Wall-clock the readiness-polled gather spent idle, waiting for the
    /// next reply to become readable (zero in-proc, whose gather blocks on
    /// a channel). Under `io = "reactor"` this is *true block time* in
    /// the OS readiness wait; under `io = "poll"` it is the sum of sleep
    /// slices.
    pub gather_wait_time: Duration,
    /// Times the event loop's blocking wait returned: reactor wait
    /// returns under `io = "reactor"`, sleep slices under `io = "poll"`
    /// (zero in-proc). The reactor strictly shrinks this for the same
    /// run — wakeups track events, not elapsed time ÷ sleep quantum.
    pub reactor_wakeups: u64,
    /// Successful vectored (`writev`) flushes on the TCP hot path: each
    /// batch replaces what used to be several per-frame `write_all`
    /// syscalls (zero in-proc).
    pub writev_batches: u64,
    /// Peak modeled resident dataset footprint of any single peer's
    /// session store, in bytes (zero in-proc, where peers share the
    /// dataset by `Arc`). A *gauge*, not a counter: under
    /// `store = "dense"` it is the full grown `n × d × 4` a session
    /// allocates; under `store = "sparse"` only the panel-aligned blocks
    /// its shipped coverage touches. [`TransportStats::since`] passes it
    /// through undifferenced.
    pub resident_data_bytes: u64,
}

impl TransportStats {
    /// Stats accumulated since an earlier snapshot of the same transport.
    pub fn since(&self, earlier: &TransportStats) -> TransportStats {
        TransportStats {
            wire_bytes: self.wire_bytes.saturating_sub(earlier.wire_bytes),
            unique_payload_bytes: self
                .unique_payload_bytes
                .saturating_sub(earlier.unique_payload_bytes),
            ser_time: self.ser_time.saturating_sub(earlier.ser_time),
            dataset_bytes: self.dataset_bytes.saturating_sub(earlier.dataset_bytes),
            delta_bytes: self.delta_bytes.saturating_sub(earlier.delta_bytes),
            full_snapshot_fallbacks: self
                .full_snapshot_fallbacks
                .saturating_sub(earlier.full_snapshot_fallbacks),
            handshake_time: self.handshake_time.saturating_sub(earlier.handshake_time),
            gather_wait_time: self.gather_wait_time.saturating_sub(earlier.gather_wait_time),
            reactor_wakeups: self.reactor_wakeups.saturating_sub(earlier.reactor_wakeups),
            writev_batches: self.writev_batches.saturating_sub(earlier.writev_batches),
            // A gauge (current peak), not a cumulative counter —
            // differencing it would report ~0 for every epoch after the
            // first ship.
            resident_data_bytes: self.resident_data_bytes,
        }
    }
}

/// Shared, thread-safe accounting block both planes write into. The
/// compute plane lives on the scheduler's event loop and the validation
/// plane on the dedicated validation thread, so the counters are atomics;
/// [`SharedStats::snapshot`] renders them as one [`TransportStats`].
/// In-proc planes move no bytes and simply never write.
#[derive(Debug, Default)]
pub struct SharedStats {
    wire_bytes: AtomicU64,
    unique_payload_bytes: AtomicU64,
    ser_nanos: AtomicU64,
    dataset_bytes: AtomicU64,
    delta_bytes: AtomicU64,
    full_snapshot_fallbacks: AtomicU64,
    handshake_nanos: AtomicU64,
    gather_wait_nanos: AtomicU64,
    reactor_wakeups: AtomicU64,
    writev_batches: AtomicU64,
    resident_data_bytes: AtomicU64,
}

impl SharedStats {
    /// Bytes that crossed the wire (unconditionally).
    pub fn add_wire(&self, n: u64) {
        self.wire_bytes.fetch_add(n, Ordering::Relaxed);
    }
    /// Bytes that passed the encoder exactly once (splice/delta reuse
    /// across peers writes the same bytes again without re-encoding —
    /// those copies count in `wire_bytes` only).
    pub fn add_unique(&self, n: u64) {
        self.unique_payload_bytes.fetch_add(n, Ordering::Relaxed);
    }
    /// Bytes that crossed the wire *and* passed the encoder once.
    pub fn add_bytes(&self, n: u64) {
        self.add_wire(n);
        self.add_unique(n);
    }
    /// Master-side encode/decode wall-clock.
    pub fn add_ser(&self, d: Duration) {
        self.ser_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
    /// Dataset-block payload bytes shipped.
    pub fn add_dataset(&self, n: u64) {
        self.dataset_bytes.fetch_add(n, Ordering::Relaxed);
    }
    /// Snapshot-delta payload bytes shipped.
    pub fn add_delta(&self, n: u64) {
        self.delta_bytes.fetch_add(n, Ordering::Relaxed);
    }
    /// One full-snapshot frame shipped because no delta was possible.
    pub fn add_full_snapshot_fallback(&self) {
        self.full_snapshot_fallbacks.fetch_add(1, Ordering::Relaxed);
    }
    /// Handshake wall-clock.
    pub fn add_handshake(&self, d: Duration) {
        self.handshake_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
    /// Gather idle-wait wall-clock.
    pub fn add_gather_wait(&self, d: Duration) {
        self.gather_wait_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
    /// One blocking-wait return on the event loop (a reactor wakeup, or
    /// one poll-mode sleep slice).
    pub fn add_reactor_wakeup(&self) {
        self.reactor_wakeups.fetch_add(1, Ordering::Relaxed);
    }
    /// One successful vectored write batch flushed to a peer socket.
    pub fn add_writev_batch(&self) {
        self.writev_batches.fetch_add(1, Ordering::Relaxed);
    }
    /// Record one peer session's modeled resident dataset footprint;
    /// the gauge keeps the peak across peers and ships (`fetch_max`).
    pub fn note_resident(&self, bytes: u64) {
        self.resident_data_bytes.fetch_max(bytes, Ordering::Relaxed);
    }
    /// Render the counters as one coherent [`TransportStats`].
    pub fn snapshot(&self) -> TransportStats {
        TransportStats {
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            unique_payload_bytes: self.unique_payload_bytes.load(Ordering::Relaxed),
            ser_time: Duration::from_nanos(self.ser_nanos.load(Ordering::Relaxed)),
            dataset_bytes: self.dataset_bytes.load(Ordering::Relaxed),
            delta_bytes: self.delta_bytes.load(Ordering::Relaxed),
            full_snapshot_fallbacks: self.full_snapshot_fallbacks.load(Ordering::Relaxed),
            handshake_time: Duration::from_nanos(self.handshake_nanos.load(Ordering::Relaxed)),
            gather_wait_time: Duration::from_nanos(
                self.gather_wait_nanos.load(Ordering::Relaxed),
            ),
            reactor_wakeups: self.reactor_wakeups.load(Ordering::Relaxed),
            writev_batches: self.writev_batches.load(Ordering::Relaxed),
            resident_data_bytes: self.resident_data_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Where a cluster's peers live: per plane, a list of `host:port`
/// addresses (standalone `occd worker` processes) or — when the list is
/// empty — a count of loopback peers to spawn in this process.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Compute peers when `compute_peers` is empty.
    pub procs: usize,
    /// Validator peers when `validator_peers` is empty.
    pub validators: usize,
    /// Remote compute-peer addresses; non-empty lists define the plane
    /// size.
    pub compute_peers: Vec<String>,
    /// Remote validator-peer addresses.
    pub validator_peers: Vec<String>,
    /// Bounded reconnect budget for a dropped peer (0 = fail fast). Since
    /// the wave-engine refactor this covers loopback thread peers too —
    /// their listeners persist, so a broken session re-opens like a remote
    /// worker's.
    pub reconnect_attempts: usize,
    /// Wire-frugal shipping (the default): snapshots travel as versioned
    /// delta frames against each peer's session cache, and validator peers
    /// receive only the proposal rows their conflict-key range reads.
    /// `false` restores the PR 3 shape — full snapshot embedded in every
    /// job frame, full proposal matrix to every active validator — kept as
    /// the A/B baseline for `benches/schedulers.rs`.
    pub frugal_wire: bool,
    /// Event-loop blocking mode for the planes this topology spawns:
    /// readiness reactor (default) vs the legacy sleep-slice poller.
    pub io: IoKind,
    /// Which structure TCP peer sessions assemble shipped dataset blocks
    /// into: the offset-keyed sparse block store (default, resident
    /// footprint proportional to shipped coverage) or the dense `n × d`
    /// matrix baseline. Bit-identical models either way; ignored in-proc
    /// (workers share the dataset by `Arc`).
    pub store: StoreKind,
}

/// Default reconnect budget for dropped peers.
pub const DEFAULT_RECONNECT_ATTEMPTS: usize = 3;

impl Default for Topology {
    fn default() -> Topology {
        Topology::local(0, 0)
    }
}

impl Topology {
    /// An all-loopback topology (every peer in this process).
    pub fn local(procs: usize, validators: usize) -> Topology {
        Topology {
            procs,
            validators,
            compute_peers: Vec::new(),
            validator_peers: Vec::new(),
            reconnect_attempts: DEFAULT_RECONNECT_ATTEMPTS,
            frugal_wire: true,
            io: IoKind::from_env(),
            store: StoreKind::from_env(),
        }
    }

    /// The topology a run config names, with the validation-plane size the
    /// caller resolved (algorithms cap it — BP-means uses a single
    /// placeholder validator). Validator addresses beyond that cap are
    /// dropped with a stderr notice; the surplus workers simply never
    /// receive a session.
    pub fn of_config(cfg: &crate::config::RunConfig, validators: usize) -> Topology {
        let mut validator_peers = cfg.validator_peers.clone();
        if validator_peers.len() > validators {
            eprintln!(
                "occml: this algorithm uses {validators} validator peer(s); dropping {}: {}",
                validator_peers.len() - validators,
                validator_peers[validators..].join(", ")
            );
        }
        validator_peers.truncate(validators);
        Topology {
            procs: cfg.procs,
            validators,
            compute_peers: cfg.peers.clone(),
            validator_peers,
            reconnect_attempts: cfg.reconnect_attempts,
            frugal_wire: cfg.frugal_wire,
            io: cfg.io,
            store: cfg.store,
        }
    }

    /// Compute-plane size this topology resolves to.
    pub fn effective_procs(&self) -> usize {
        if self.compute_peers.is_empty() { self.procs } else { self.compute_peers.len() }
    }

    /// Validation-plane size this topology resolves to.
    pub fn effective_validators(&self) -> usize {
        if self.validator_peers.is_empty() {
            self.validators
        } else {
            self.validator_peers.len()
        }
    }

    /// True if any plane addresses remote peers.
    pub fn has_remote_peers(&self) -> bool {
        !self.compute_peers.is_empty() || !self.validator_peers.is_empty()
    }
}

/// One plane's scatter/gather endpoint — a thread-confined (`Send`, not
/// `Sync`) object the owning thread drives exclusively.
///
/// Contract: `scatter` takes exactly one job per peer of the plane and
/// returns the wave's id; several waves may be outstanding, and each is
/// retired exactly once by `gather` (by id, any order — outputs are always
/// sorted by peer id, plus the critical-path busy time). On a peer-side
/// *job* failure the wave is still fully drained before its `gather`
/// returns the error, so the plane stays usable. An unrecoverable peer
/// (reconnect budget exhausted, in-proc thread gone) surfaces as a typed
/// error on the affected waves, likewise drained.
pub trait PlaneIo: Send {
    /// Number of peers on the plane.
    fn peers(&self) -> usize;

    /// Send one job per peer without waiting for results.
    fn scatter(&mut self, jobs: Vec<Job>) -> Result<WaveId>;

    /// Non-blocking: pump whatever replies are readable, then report
    /// whether every reply of `wave` has arrived.
    fn try_ready(&mut self, wave: WaveId) -> Result<bool>;

    /// Readiness of `wave` from already-buffered replies only — no
    /// channel/socket pump, no syscalls. One `try_ready` call updates
    /// every in-flight wave's slots, so a caller polling several waves
    /// pairs one `try_ready` with `ready_hint` probes for the rest (false
    /// for unknown ids).
    fn ready_hint(&self, wave: WaveId) -> bool;

    /// Retire one outstanding wave, blocking until fully drained.
    fn gather(&mut self, wave: WaveId) -> Result<(Vec<JobOutput>, Duration)>;

    /// Block until the plane has input to process (a readable peer
    /// socket, a buffered reply, a waker signal) or `timeout` lapses.
    /// `Ok(true)` means "state may have advanced — re-check your waves";
    /// spurious `true`s are allowed and harmless. The default is a plain
    /// nap that cannot be cut short — planes with a real readiness
    /// source override it.
    fn wait_input(&mut self, timeout: Duration) -> Result<bool> {
        std::thread::sleep(timeout); // poll-mode: default nap, no readiness source
        Ok(false)
    }

    /// A cross-thread handle that interrupts [`PlaneIo::wait_input`]
    /// early, if the plane has one (`None` = waits always run to their
    /// timeout). The validation thread holds one for the compute plane
    /// and signals it after every commit.
    fn waker(&self) -> Option<Arc<dyn PlaneWaker>> {
        None
    }

    /// Account one event-loop block-and-resume that happened *outside*
    /// the plane (the legacy `io = "poll"` scheduler arms sleep or spin
    /// on `recv_timeout` without ever entering the plane). Planes that
    /// meter wakeups tick their `reactor_wakeups` counter here so the
    /// reactor-vs-poll comparison counts every blocking point under
    /// both modes; the default (and the in-proc plane, whose transport
    /// stats stay zero by invariant) is a no-op.
    fn note_idle_wait(&self) {}
}

/// A cheap `Send + Sync` handle that cuts a plane's blocking
/// [`PlaneIo::wait_input`] short from another thread. Signals coalesce;
/// waking a plane that is not waiting is a no-op.
pub trait PlaneWaker: Send + Sync {
    /// Interrupt the plane's current (or next) blocking wait.
    fn wake(&self);
}

impl PlaneWaker for super::reactor::Wakeup {
    fn wake(&self) {
        super::reactor::Wakeup::wake(self);
    }
}

/// [`PlaneWaker`] for the in-proc [`WorkerPool`]: pushes a
/// [`WAKER_SENTINEL`] reply through the pool's own reply channel, which
/// interrupts [`WorkerPool::wait_reply`] and routes to nothing.
struct PoolWaker {
    tx: std::sync::Mutex<std::sync::mpsc::Sender<JobReply>>,
}

impl PlaneWaker for PoolWaker {
    fn wake(&self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(JobReply {
                worker: WAKER_SENTINEL,
                output: Ok(JobOutput::PairCache { pairs: Vec::new() }),
                busy: Duration::ZERO,
            });
        }
    }
}

impl PlaneIo for WorkerPool {
    fn peers(&self) -> usize {
        self.procs
    }
    fn scatter(&mut self, jobs: Vec<Job>) -> Result<WaveId> {
        WorkerPool::scatter(self, jobs)
    }
    fn try_ready(&mut self, wave: WaveId) -> Result<bool> {
        WorkerPool::try_ready(self, wave)
    }
    fn ready_hint(&self, wave: WaveId) -> bool {
        WorkerPool::ready_hint(self, wave)
    }
    fn gather(&mut self, wave: WaveId) -> Result<(Vec<JobOutput>, Duration)> {
        WorkerPool::gather_wave(self, wave)
    }
    fn wait_input(&mut self, timeout: Duration) -> Result<bool> {
        WorkerPool::wait_reply(self, timeout)
    }
    fn waker(&self) -> Option<Arc<dyn PlaneWaker>> {
        Some(Arc::new(PoolWaker { tx: std::sync::Mutex::new(self.reply_sender()) }))
    }
}

/// The compute-plane endpoint schedulers drive: a boxed [`PlaneIo`] plus
/// the plane size and a handle on the cluster-wide [`SharedStats`] (for
/// per-epoch accounting deltas).
pub struct PlaneHandle {
    io: Box<dyn PlaneIo>,
    stats: Arc<SharedStats>,
    /// Peers on this plane (the paper's P for the compute plane).
    pub procs: usize,
}

impl PlaneHandle {
    /// Wrap a plane endpoint.
    pub fn new(io: Box<dyn PlaneIo>, stats: Arc<SharedStats>) -> PlaneHandle {
        let procs = io.peers();
        PlaneHandle { io, stats, procs }
    }

    /// Scatter one job per peer; several waves may be in flight.
    pub fn scatter(&mut self, jobs: Vec<Job>) -> Result<WaveId> {
        self.io.scatter(jobs)
    }

    /// Non-blocking readiness poll for one wave (pumps the plane).
    pub fn try_ready(&mut self, wave: WaveId) -> Result<bool> {
        self.io.try_ready(wave)
    }

    /// Pump-free readiness probe from already-buffered replies.
    pub fn ready_hint(&self, wave: WaveId) -> bool {
        self.io.ready_hint(wave)
    }

    /// Retire one wave (blocking).
    pub fn gather(&mut self, wave: WaveId) -> Result<(Vec<JobOutput>, Duration)> {
        self.io.gather(wave)
    }

    /// Block until the plane has input or `timeout` lapses (see
    /// [`PlaneIo::wait_input`]).
    pub fn wait_input(&mut self, timeout: Duration) -> Result<bool> {
        self.io.wait_input(timeout)
    }

    /// The plane's cross-thread waker, if it has one (see
    /// [`PlaneIo::waker`]).
    pub fn waker(&self) -> Option<Arc<dyn PlaneWaker>> {
        self.io.waker()
    }

    /// Account one out-of-plane event-loop block (see
    /// [`PlaneIo::note_idle_wait`]).
    pub fn note_idle_wait(&self) {
        self.io.note_idle_wait()
    }

    /// Scatter one job per peer and gather the replies — the BSP barrier.
    pub fn scatter_gather(&mut self, jobs: Vec<Job>) -> Result<(Vec<JobOutput>, Duration)> {
        let wave = self.io.scatter(jobs)?;
        self.io.gather(wave)
    }

    /// Cumulative transport accounting — cluster-wide (both planes), since
    /// the counters are shared.
    pub fn stats(&self) -> TransportStats {
        self.stats.snapshot()
    }
}

/// The validation-plane endpoint: owned by whichever thread runs
/// validation (the dedicated validation thread under the wave engine), so
/// conflict pre-computation can proceed while the event loop drives
/// compute waves.
pub struct ValidatePlane {
    io: Box<dyn PlaneIo>,
    /// Validator-shard peers.
    pub validators: usize,
    /// Row-subset shipping for `PairCache` jobs (see
    /// [`Topology::frugal_wire`]): each validator peer receives only the
    /// proposal rows its conflict-key range reads.
    frugal: bool,
}

impl ValidatePlane {
    /// Wrap a plane endpoint. `frugal_wire` must match how the plane was
    /// built (see [`Topology::frugal_wire`]) so the validator row-subset
    /// decision stays consistent with the snapshot-shipping mode.
    pub fn new(io: Box<dyn PlaneIo>, frugal_wire: bool) -> ValidatePlane {
        let validators = io.peers();
        ValidatePlane { io, validators, frugal: frugal_wire }
    }

    /// Compute per-shard conflict caches on the validation plane.
    ///
    /// `shard_lists` are conflict-key buckets in key order (see
    /// [`super::validator::shard_positions`]); each validator peer is
    /// handed a contiguous *range* of buckets — its conflict-key range —
    /// bundled with the proposal vectors as one
    /// [`Job::PairCache`] job. Returns one sorted pair list per peer, in
    /// peer order, ready for
    /// [`super::validator::ConflictCache::tree_reduce`]. Buckets with
    /// fewer than two proposals produce no pairs and are dropped from the
    /// payload, and peers left with nothing receive an empty job.
    ///
    /// Wire-cost note: under frugal shipping (the tcp default; in-proc
    /// peers share the full matrix by `Arc` at zero copy cost, so the
    /// subset build never engages there) each active
    /// peer receives only the proposal rows its conflict-key range reads,
    /// with a local→global position map, so the plane's TCP traffic for
    /// this step is `O(M · d)` *total* per epoch (every proposal belongs
    /// to exactly one bucket, every bucket to exactly one peer) instead of
    /// the PR 3 `O(V · M · d)`. The subset rows are bit-copies and the
    /// position map is strictly monotone, so peer outputs — global pair
    /// keys, sorted order, distance bits — are identical to the
    /// full-matrix form on any transport.
    pub fn pair_cache(
        &mut self,
        vectors: Arc<Matrix>,
        shard_lists: Vec<Vec<u32>>,
    ) -> Result<Vec<Vec<(u32, u32, f32)>>> {
        let v = self.validators;
        let s = shard_lists.len();
        let mut groups: Vec<Vec<Vec<u32>>> = Vec::with_capacity(v);
        let mut it = shard_lists.into_iter();
        for p in 0..v {
            let lo = p * s / v;
            let hi = (p + 1) * s / v;
            groups.push(it.by_ref().take(hi - lo).filter(|l| l.len() >= 2).collect());
        }
        let empty = Arc::new(Matrix::zeros(0, vectors.cols));
        let jobs: Vec<Job> = groups
            .into_iter()
            .map(|g| {
                if g.is_empty() {
                    Job::PairCache { vectors: empty.clone(), positions: vec![], shards: vec![] }
                } else if self.frugal {
                    // Row subset: the union of this peer's buckets, in
                    // global position order. Buckets partition positions,
                    // so the union is duplicate-free.
                    let mut positions: Vec<u32> = g.iter().flatten().copied().collect();
                    positions.sort_unstable();
                    debug_assert!(positions.windows(2).all(|w| w[0] < w[1]));
                    let mut sub = Matrix {
                        rows: 0,
                        cols: vectors.cols,
                        data: Vec::with_capacity(positions.len() * vectors.cols),
                    };
                    for &p in &positions {
                        sub.push_row(vectors.row(p as usize));
                    }
                    Job::PairCache { vectors: Arc::new(sub), positions, shards: g }
                } else {
                    Job::PairCache { vectors: vectors.clone(), positions: vec![], shards: g }
                }
            })
            .collect();
        let wave = self.io.scatter(jobs)?;
        let (outs, _busy) = self.io.gather(wave)?;
        let mut lists = Vec::with_capacity(outs.len());
        for out in outs {
            let JobOutput::PairCache { pairs } = out else {
                return Err(Error::Coordinator(
                    "unexpected job output on the validation plane".into(),
                ));
            };
            lists.push(pairs);
        }
        Ok(lists)
    }
}

/// The coordinator's handle to its peers: the two plane endpoints plus the
/// resolved plane sizes and the shared accounting. The fields are public
/// so callers can split the borrows — the scheduler's event loop takes
/// `&mut cluster.compute` while the per-pass algorithm state (validated on
/// the dedicated validation thread) takes `&mut cluster.validate`.
pub struct Cluster {
    /// Compute-plane endpoint: epoch waves and reduction barriers.
    pub compute: PlaneHandle,
    /// Validation-plane endpoint: conflict-cache jobs.
    pub validate: ValidatePlane,
    stats: Arc<SharedStats>,
    name: &'static str,
    /// Compute workers (the paper's P).
    pub procs: usize,
    /// Validator-shard peers.
    pub validators: usize,
}

impl Cluster {
    /// Spawn the transport a config names, with `procs` loopback compute
    /// peers and `validators` loopback validation peers.
    pub fn spawn(
        kind: TransportKind,
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        procs: usize,
        validators: usize,
    ) -> Result<Cluster> {
        Cluster::spawn_topology(kind, data, backend, &Topology::local(procs, validators))
    }

    /// Spawn the transport a config names over an explicit peer topology:
    /// remote `host:port` peers where the topology lists addresses,
    /// loopback peers elsewhere. Remote peers require the TCP transport.
    pub fn spawn_topology(
        kind: TransportKind,
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        topo: &Topology,
    ) -> Result<Cluster> {
        Cluster::spawn_topology_cell(
            kind,
            Arc::new(crate::data::DataCell::new(data)),
            backend,
            topo,
        )
    }

    /// [`Cluster::spawn_topology`] over a shared, *growable* dataset cell
    /// — the streaming ingest (`occd serve`) entry point. Requires the
    /// TCP transport: in-proc workers capture an `Arc` snapshot of the
    /// dataset at spawn and would never observe growth, while TCP peers
    /// are shipped blocks from the generation current at each encode.
    pub fn spawn_topology_cell(
        kind: TransportKind,
        cell: Arc<crate::data::DataCell>,
        backend: Arc<dyn ComputeBackend>,
        topo: &Topology,
    ) -> Result<Cluster> {
        let data = cell.get();
        let procs = topo.effective_procs();
        let validators = topo.effective_validators().max(1);
        assert!(procs >= 1, "a cluster needs at least one compute peer");
        let stats = Arc::new(SharedStats::default());
        // Row subsets are a *wire* diet: in-proc peers share the proposal
        // matrix by `Arc` at zero copy cost, so the subset build would be
        // pure overhead there — it engages only where bytes actually move.
        let frugal = topo.frugal_wire && kind == TransportKind::Tcp;
        let (name, compute_io, validate_io): (&'static str, Box<dyn PlaneIo>, Box<dyn PlaneIo>) =
            match kind {
                TransportKind::InProc => {
                    if topo.has_remote_peers() {
                        return Err(Error::config(
                            "peers = [...] requires transport = \"tcp\" — the in-proc \
                             transport has no wire to reach them over",
                        ));
                    }
                    (
                        "inproc",
                        Box::new(WorkerPool::spawn(data.clone(), backend.clone(), procs)),
                        Box::new(WorkerPool::spawn(data, backend, validators)),
                    )
                }
                TransportKind::Tcp => {
                    let mut topo = topo.clone();
                    topo.validators = validators;
                    let (c, v) =
                        super::tcp::spawn_planes_cell(cell, backend, &topo, stats.clone())?;
                    ("tcp", Box::new(c), Box::new(v))
                }
            };
        Ok(Cluster {
            compute: PlaneHandle::new(compute_io, stats.clone()),
            validate: ValidatePlane::new(validate_io, frugal),
            stats,
            name,
            procs,
            validators,
        })
    }

    /// Transport name (metrics / logs).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Cumulative transport accounting, both planes (zero for in-proc).
    pub fn stats(&self) -> TransportStats {
        self.stats.snapshot()
    }

    /// Scatter one job per compute worker and gather all replies — the BSP
    /// barrier (reduction phases, embedders).
    pub fn scatter_gather(&mut self, jobs: Vec<Job>) -> Result<(Vec<JobOutput>, Duration)> {
        self.compute.scatter_gather(jobs)
    }

    /// Compute per-shard conflict caches on the validation plane — see
    /// [`ValidatePlane::pair_cache`].
    pub fn pair_cache(
        &mut self,
        vectors: Arc<Matrix>,
        shard_lists: Vec<Vec<u32>>,
    ) -> Result<Vec<Vec<(u32, u32, f32)>>> {
        self.validate.pair_cache(vectors, shard_lists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{dp_clusters, GenConfig};
    use crate::runtime::native::NativeBackend;

    fn cluster(kind: TransportKind, procs: usize, validators: usize) -> (Arc<Dataset>, Cluster) {
        let data = Arc::new(dp_clusters(&GenConfig { n: 100, dim: 8, theta: 1.0, seed: 1 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let c = Cluster::spawn(kind, data.clone(), backend, procs, validators).unwrap();
        (data, c)
    }

    fn nearest_jobs(data: &Dataset, procs: usize) -> (Arc<Matrix>, Vec<Job>) {
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        centers.push_row(data.point(50));
        let centers = Arc::new(centers);
        let jobs = super::super::engine::split_range(0..100, procs)
            .into_iter()
            .map(|range| Job::Nearest { range, centers: centers.clone() })
            .collect();
        (centers, jobs)
    }

    #[test]
    fn inproc_cluster_matches_direct_nearest_and_reports_zero_wire() {
        let (data, mut c) = cluster(TransportKind::InProc, 3, 2);
        assert_eq!(c.name(), "inproc");
        assert_eq!(c.procs, 3);
        assert_eq!(c.validators, 2);
        let (centers, jobs) = nearest_jobs(&data, 3);
        let (outs, busy) = c.scatter_gather(jobs).unwrap();
        assert!(busy > Duration::ZERO);
        let ranges = super::super::engine::split_range(0..100, 3);
        for (w, out) in outs.iter().enumerate() {
            let JobOutput::Nearest { idx, d2 } = out else { panic!("wrong kind") };
            for (off, i) in ranges[w].clone().enumerate() {
                let (bi, bd) = crate::linalg::nearest(data.point(i), &centers);
                assert_eq!(idx[off], bi as u32);
                assert!((d2[off] - bd).abs() < 1e-4);
            }
        }
        assert_eq!(c.stats(), TransportStats::default(), "in-proc moves no bytes");
    }

    /// The split planes are independently drivable: waves on the compute
    /// plane stay in flight while the validation plane serves a pair-cache
    /// round — the shape the wave engine's two threads rely on.
    #[test]
    fn planes_are_independent_endpoints() {
        let (data, mut c) = cluster(TransportKind::InProc, 2, 2);
        let (_, jobs) = nearest_jobs(&data, 2);
        let wave = c.compute.scatter(jobs).unwrap();
        // With the compute wave still outstanding, run validation traffic.
        let mut vectors = Matrix::zeros(0, 2);
        for i in 0..4 {
            vectors.push_row(&[i as f32, 0.0]);
        }
        let lists = c.validate.pair_cache(Arc::new(vectors), vec![vec![0, 1, 2, 3]]).unwrap();
        assert_eq!(lists.iter().map(|l| l.len()).sum::<usize>(), 6);
        let (outs, _) = c.compute.gather(wave).unwrap();
        assert_eq!(outs.len(), 2);
    }

    /// The in-proc plane's readiness wait: times out clean when idle, is
    /// interrupted by its waker (whose sentinel routes to no wave), and
    /// returns true when real replies land — after which waves gather
    /// normally.
    #[test]
    fn pool_wait_input_times_out_and_waker_interrupts() {
        let (data, mut c) = cluster(TransportKind::InProc, 2, 1);
        assert!(!c.compute.wait_input(Duration::from_millis(5)).unwrap());
        let w = c.compute.waker().expect("in-proc plane has a waker");
        w.wake();
        w.wake(); // coalescing second signal must not corrupt routing
        assert!(c.compute.wait_input(Duration::from_millis(500)).unwrap());
        let (_, jobs) = nearest_jobs(&data, 2);
        let wave = c.compute.scatter(jobs).unwrap();
        assert!(c.compute.wait_input(Duration::from_millis(500)).unwrap());
        let (outs, _) = c.compute.gather(wave).unwrap();
        assert_eq!(outs.len(), 2);
    }

    #[test]
    fn pair_cache_partitions_key_ranges_and_covers_all_pairs() {
        let (_, mut c) = cluster(TransportKind::InProc, 2, 3);
        let mut vectors = Matrix::zeros(0, 2);
        for i in 0..9 {
            vectors.push_row(&[i as f32, 0.0]);
        }
        let vectors = Arc::new(vectors);
        // 5 buckets over 3 peers: ranges [0..1), [1..3), [3..5).
        let shard_lists: Vec<Vec<u32>> =
            vec![vec![0, 1], vec![2], vec![3, 4, 5], vec![], vec![6, 7, 8]];
        let lists = c.pair_cache(vectors, shard_lists).unwrap();
        assert_eq!(lists.len(), 3, "one cache per validator peer");
        // Peer 0: bucket {0,1} → 1 pair. Peer 1: buckets {2}, {3,4,5} → 3
        // pairs. Peer 2: buckets {}, {6,7,8} → 3 pairs.
        assert_eq!(lists[0].len(), 1);
        assert_eq!(lists[1].len(), 3);
        assert_eq!(lists[2].len(), 3);
        let total: usize = lists.iter().map(|l| l.len()).sum();
        assert_eq!(total, 7);
        for l in &lists {
            assert!(l.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)));
        }
    }

    /// Row-subset shipping must not change a single bit of the pair lists:
    /// frugal and full-matrix shipping agree on both transports.
    #[test]
    fn pair_cache_row_subset_matches_full_shipping() {
        let data = Arc::new(dp_clusters(&GenConfig { n: 40, dim: 8, theta: 1.0, seed: 2 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let mut vectors = Matrix::zeros(0, 3);
        for i in 0..12 {
            vectors.push_row(&[i as f32, (i * i) as f32 * 0.5, -(i as f32)]);
        }
        let vectors = Arc::new(vectors);
        let shard_lists: Vec<Vec<u32>> =
            vec![vec![0, 4, 8], vec![1, 5], vec![2, 6, 10, 11], vec![3], vec![7, 9]];
        let mut results = Vec::new();
        for kind in [TransportKind::InProc, TransportKind::Tcp] {
            for frugal in [true, false] {
                let topo = Topology { frugal_wire: frugal, ..Topology::local(2, 2) };
                let mut c =
                    Cluster::spawn_topology(kind, data.clone(), backend.clone(), &topo).unwrap();
                results.push(c.pair_cache(vectors.clone(), shard_lists.clone()).unwrap());
            }
        }
        for other in &results[1..] {
            assert_eq!(results[0].len(), other.len());
            for (a, b) in results[0].iter().zip(other) {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!((x.0, x.1), (y.0, y.1));
                    assert_eq!(x.2.to_bits(), y.2.to_bits());
                }
            }
        }
    }

    #[test]
    fn transport_stats_delta() {
        let a = TransportStats {
            wire_bytes: 100,
            unique_payload_bytes: 80,
            ser_time: Duration::from_millis(5),
            dataset_bytes: 10,
            delta_bytes: 4,
            full_snapshot_fallbacks: 1,
            handshake_time: Duration::from_millis(1),
            gather_wait_time: Duration::from_millis(2),
            reactor_wakeups: 6,
            writev_batches: 3,
            resident_data_bytes: 4096,
        };
        let b = TransportStats {
            wire_bytes: 250,
            unique_payload_bytes: 170,
            ser_time: Duration::from_millis(8),
            dataset_bytes: 70,
            delta_bytes: 24,
            full_snapshot_fallbacks: 3,
            handshake_time: Duration::from_millis(4),
            gather_wait_time: Duration::from_millis(9),
            reactor_wakeups: 20,
            writev_batches: 10,
            resident_data_bytes: 8192,
        };
        let d = b.since(&a);
        assert_eq!(d.wire_bytes, 150);
        assert_eq!(d.unique_payload_bytes, 90);
        assert_eq!(d.ser_time, Duration::from_millis(3));
        assert_eq!(d.dataset_bytes, 60);
        assert_eq!(d.delta_bytes, 20);
        assert_eq!(d.full_snapshot_fallbacks, 2);
        assert_eq!(d.handshake_time, Duration::from_millis(3));
        assert_eq!(d.gather_wait_time, Duration::from_millis(7));
        assert_eq!(d.reactor_wakeups, 14);
        assert_eq!(d.writev_batches, 7);
        assert_eq!(d.resident_data_bytes, 8192, "gauge passes through undifferenced");
    }

    #[test]
    fn shared_stats_accumulate_and_snapshot() {
        let s = SharedStats::default();
        s.add_bytes(10);
        s.add_wire(5);
        s.add_unique(2);
        s.add_ser(Duration::from_micros(3));
        s.add_dataset(7);
        s.add_delta(4);
        s.add_full_snapshot_fallback();
        s.add_handshake(Duration::from_micros(9));
        s.add_gather_wait(Duration::from_micros(11));
        s.add_reactor_wakeup();
        s.add_reactor_wakeup();
        s.add_writev_batch();
        s.note_resident(640);
        s.note_resident(512); // peak gauge: a smaller peer never lowers it
        let t = s.snapshot();
        assert_eq!(t.wire_bytes, 15);
        assert_eq!(t.unique_payload_bytes, 12);
        assert_eq!(t.ser_time, Duration::from_micros(3));
        assert_eq!(t.dataset_bytes, 7);
        assert_eq!(t.delta_bytes, 4);
        assert_eq!(t.full_snapshot_fallbacks, 1);
        assert_eq!(t.handshake_time, Duration::from_micros(9));
        assert_eq!(t.gather_wait_time, Duration::from_micros(11));
        assert_eq!(t.reactor_wakeups, 2);
        assert_eq!(t.writev_batches, 1);
        assert_eq!(t.resident_data_bytes, 640);
    }

    #[test]
    fn topology_resolution() {
        let t = Topology::local(4, 2);
        assert_eq!(t.effective_procs(), 4);
        assert_eq!(t.effective_validators(), 2);
        assert!(!t.has_remote_peers());
        let t = Topology {
            procs: 4,
            validators: 2,
            compute_peers: vec!["h:1".into(), "h:2".into(), "h:3".into()],
            validator_peers: vec!["h:4".into()],
            reconnect_attempts: 1,
            frugal_wire: true,
            io: IoKind::Reactor,
            store: StoreKind::Sparse,
        };
        assert_eq!(t.effective_procs(), 3, "addresses define the plane size");
        assert_eq!(t.effective_validators(), 1);
        assert!(t.has_remote_peers());
    }

    #[test]
    fn inproc_rejects_remote_peers() {
        let data = Arc::new(dp_clusters(&GenConfig { n: 10, dim: 4, theta: 1.0, seed: 1 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let topo = Topology {
            procs: 1,
            validators: 1,
            compute_peers: vec!["127.0.0.1:1".into()],
            validator_peers: vec![],
            reconnect_attempts: 0,
            frugal_wire: true,
            io: IoKind::Reactor,
            store: StoreKind::Sparse,
        };
        let err = Cluster::spawn_topology(TransportKind::InProc, data, backend, &topo)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tcp"), "{err}");
    }
}
