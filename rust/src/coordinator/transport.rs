//! The cluster's communication plane, behind a [`Transport`] trait.
//!
//! The coordinator talks to two groups of peers — *compute workers* (epoch
//! jobs: nearest-center assignment, coordinate descent, reductions) and
//! *validator shards* (conflict pre-computation for the master's validation
//! step). Both groups are addressed through the same abstraction: scatter
//! one [`Job`] per peer on a [`Plane`], gather one reply per peer. How the
//! messages move is the transport's business:
//!
//! * [`InProc`] — peers are threads in this process; jobs and snapshots
//!   cross the boundary by pointer (`mpsc` channels + `Arc`). This is the
//!   zero-copy fast path and the default.
//! * [`super::tcp::Tcp`] — peers sit behind localhost TCP sockets; every
//!   job, snapshot and reply is serialized through the explicit
//!   length-prefixed wire format of [`super::wire`]. Same coordinator, same
//!   bits — but the message boundary is real, which is the stepping stone
//!   to peers on other machines.
//!
//! [`Cluster`] is the coordinator-facing facade: it owns the boxed
//! transport, knows the peer counts, and provides the scatter/gather calls
//! the schedulers and validators drive. Serializability does not depend on
//! the transport — all state mutation stays in the master, and
//! `rust/tests/transport_equivalence.rs` checks models are bit-identical
//! across `{inproc, tcp} × {bsp, pipelined}`.

use super::engine::{Job, JobOutput, WorkerPool};
use crate::config::TransportKind;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::ComputeBackend;
use std::sync::Arc;
use std::time::Duration;

/// Which peer group a scatter/gather addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    /// The epoch-compute workers (P peers).
    Compute,
    /// The validator shards (V peers).
    Validate,
}

impl Plane {
    /// Index into per-plane storage.
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Plane::Compute => 0,
            Plane::Validate => 1,
        }
    }
}

/// Cumulative wire-level accounting for a transport.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Bytes written to + read from the wire (frames, both directions).
    pub wire_bytes: u64,
    /// Master-side time spent encoding jobs and decoding replies.
    pub ser_time: Duration,
}

impl TransportStats {
    /// Stats accumulated since an earlier snapshot of the same transport.
    pub fn since(&self, earlier: &TransportStats) -> TransportStats {
        TransportStats {
            wire_bytes: self.wire_bytes.saturating_sub(earlier.wire_bytes),
            ser_time: self.ser_time.saturating_sub(earlier.ser_time),
        }
    }
}

/// A cluster transport: moves jobs to peers and replies back.
///
/// Contract (identical to [`WorkerPool`]'s): `scatter` takes exactly one
/// job per peer of the plane; at most one wave may be outstanding per
/// plane and `gather` retires it, returning outputs sorted by peer id
/// plus the critical-path busy time. On a peer-side *job* failure the
/// wave is still fully drained before `gather` returns the error, so the
/// transport stays usable. A *scatter* failure (dead peer, unencodable
/// job) instead poisons the plane — some peers own jobs whose replies
/// belong to no wave — and every later scatter on it reports the
/// poisoning rather than risking stale-reply misattribution.
pub trait Transport: Send {
    /// Transport name (metrics / logs).
    fn name(&self) -> &'static str;

    /// Number of peers on a plane.
    fn peers(&self, plane: Plane) -> usize;

    /// Send one job per peer of `plane` without waiting for results.
    fn scatter(&self, plane: Plane, jobs: Vec<Job>) -> Result<()>;

    /// Gather the plane's outstanding wave.
    fn gather(&self, plane: Plane) -> Result<(Vec<JobOutput>, Duration)>;

    /// Cumulative serialization accounting (all-zero for in-proc).
    fn stats(&self) -> TransportStats;
}

/// The in-process transport: each plane is a [`WorkerPool`] — today's
/// channels and `Arc`-shared snapshots, preserved as the zero-copy fast
/// path. No bytes are moved, so [`Transport::stats`] stays zero.
pub struct InProc {
    planes: [WorkerPool; 2],
}

impl InProc {
    /// Spawn `procs` compute workers and `validators` validator peers over
    /// a shared dataset and backend.
    pub fn spawn(
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        procs: usize,
        validators: usize,
    ) -> InProc {
        InProc {
            planes: [
                WorkerPool::spawn(data.clone(), backend.clone(), procs),
                WorkerPool::spawn(data, backend, validators),
            ],
        }
    }
}

impl Transport for InProc {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn peers(&self, plane: Plane) -> usize {
        self.planes[plane.idx()].procs
    }

    fn scatter(&self, plane: Plane, jobs: Vec<Job>) -> Result<()> {
        self.planes[plane.idx()].scatter(jobs)
    }

    fn gather(&self, plane: Plane) -> Result<(Vec<JobOutput>, Duration)> {
        self.planes[plane.idx()].gather()
    }

    fn stats(&self) -> TransportStats {
        TransportStats::default()
    }
}

/// The coordinator's handle to its peers: a boxed [`Transport`] plus the
/// plane sizes. Schedulers drive the compute plane through
/// [`Cluster::scatter`] / [`Cluster::gather`]; validators drive the
/// validation plane through [`Cluster::pair_cache`].
pub struct Cluster {
    transport: Box<dyn Transport>,
    /// Compute workers (the paper's P).
    pub procs: usize,
    /// Validator-shard peers.
    pub validators: usize,
}

impl Cluster {
    /// Spawn the transport a config names, with `procs` compute peers and
    /// `validators` validation peers.
    pub fn spawn(
        kind: TransportKind,
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        procs: usize,
        validators: usize,
    ) -> Result<Cluster> {
        assert!(procs >= 1, "a cluster needs at least one compute peer");
        let validators = validators.max(1);
        let transport: Box<dyn Transport> = match kind {
            TransportKind::InProc => Box::new(InProc::spawn(data, backend, procs, validators)),
            TransportKind::Tcp => {
                Box::new(super::tcp::Tcp::spawn(data, backend, procs, validators)?)
            }
        };
        Ok(Cluster { transport, procs, validators })
    }

    /// Wrap an existing transport (tests / custom deployments).
    pub fn from_transport(transport: Box<dyn Transport>) -> Cluster {
        let procs = transport.peers(Plane::Compute);
        let validators = transport.peers(Plane::Validate);
        Cluster { transport, procs, validators }
    }

    /// Transport name (metrics / logs).
    pub fn name(&self) -> &'static str {
        self.transport.name()
    }

    /// Scatter one job per compute worker without waiting for results. At
    /// most one compute wave may be outstanding.
    pub fn scatter(&self, jobs: Vec<Job>) -> Result<()> {
        self.transport.scatter(Plane::Compute, jobs)
    }

    /// Gather the outstanding compute wave: outputs sorted by peer id plus
    /// the critical-path busy time.
    pub fn gather(&self) -> Result<(Vec<JobOutput>, Duration)> {
        self.transport.gather(Plane::Compute)
    }

    /// Scatter one job per compute worker and gather all replies — the BSP
    /// barrier.
    pub fn scatter_gather(&self, jobs: Vec<Job>) -> Result<(Vec<JobOutput>, Duration)> {
        self.scatter(jobs)?;
        self.gather()
    }

    /// Cumulative transport accounting (zero for in-proc).
    pub fn stats(&self) -> TransportStats {
        self.transport.stats()
    }

    /// Compute per-shard conflict caches on the validation plane.
    ///
    /// `shard_lists` are conflict-key buckets in key order (see
    /// [`super::validator::shard_positions`]); each validator peer is
    /// handed a contiguous *range* of buckets — its conflict-key range —
    /// bundled with the proposal vectors as one
    /// [`Job::PairCache`] job. Returns one sorted pair list per peer, in
    /// peer order, ready for
    /// [`super::validator::ConflictCache::tree_reduce`]. Buckets with
    /// fewer than two proposals produce no pairs and are dropped from the
    /// payload, and peers left with nothing receive an empty job.
    ///
    /// Wire-cost note: every *active* peer currently receives the full
    /// proposal matrix (positions are global), so TCP traffic for this
    /// step is `O(V · M · d)` per epoch. Shipping only each peer's
    /// referenced rows plus an index remap would cut that to `O(M · d)`
    /// total; tracked in ROADMAP under cross-machine validation.
    pub fn pair_cache(
        &self,
        vectors: Arc<Matrix>,
        shard_lists: Vec<Vec<u32>>,
    ) -> Result<Vec<Vec<(u32, u32, f32)>>> {
        let v = self.validators;
        let s = shard_lists.len();
        let mut groups: Vec<Vec<Vec<u32>>> = Vec::with_capacity(v);
        let mut it = shard_lists.into_iter();
        for p in 0..v {
            let lo = p * s / v;
            let hi = (p + 1) * s / v;
            groups.push(
                it.by_ref().take(hi - lo).filter(|l| l.len() >= 2).collect(),
            );
        }
        let empty = Arc::new(Matrix::zeros(0, vectors.cols));
        let jobs: Vec<Job> = groups
            .into_iter()
            .map(|g| {
                if g.is_empty() {
                    Job::PairCache { vectors: empty.clone(), shards: vec![] }
                } else {
                    Job::PairCache { vectors: vectors.clone(), shards: g }
                }
            })
            .collect();
        self.transport.scatter(Plane::Validate, jobs)?;
        let (outs, _busy) = self.transport.gather(Plane::Validate)?;
        let mut lists = Vec::with_capacity(outs.len());
        for out in outs {
            let JobOutput::PairCache { pairs } = out else {
                return Err(Error::Coordinator(
                    "unexpected job output on the validation plane".into(),
                ));
            };
            lists.push(pairs);
        }
        Ok(lists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{dp_clusters, GenConfig};
    use crate::runtime::native::NativeBackend;

    fn cluster(kind: TransportKind, procs: usize, validators: usize) -> (Arc<Dataset>, Cluster) {
        let data = Arc::new(dp_clusters(&GenConfig { n: 100, dim: 8, theta: 1.0, seed: 1 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let c = Cluster::spawn(kind, data.clone(), backend, procs, validators).unwrap();
        (data, c)
    }

    fn nearest_jobs(data: &Dataset, procs: usize) -> (Arc<Matrix>, Vec<Job>) {
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        centers.push_row(data.point(50));
        let centers = Arc::new(centers);
        let jobs = super::super::engine::split_range(0..100, procs)
            .into_iter()
            .map(|range| Job::Nearest { range, centers: centers.clone() })
            .collect();
        (centers, jobs)
    }

    #[test]
    fn inproc_cluster_matches_direct_nearest_and_reports_zero_wire() {
        let (data, c) = cluster(TransportKind::InProc, 3, 2);
        assert_eq!(c.name(), "inproc");
        assert_eq!(c.procs, 3);
        assert_eq!(c.validators, 2);
        let (centers, jobs) = nearest_jobs(&data, 3);
        let (outs, busy) = c.scatter_gather(jobs).unwrap();
        assert!(busy > Duration::ZERO);
        let ranges = super::super::engine::split_range(0..100, 3);
        for (w, out) in outs.iter().enumerate() {
            let JobOutput::Nearest { idx, d2 } = out else { panic!("wrong kind") };
            for (off, i) in ranges[w].clone().enumerate() {
                let (bi, bd) = crate::linalg::nearest(data.point(i), &centers);
                assert_eq!(idx[off], bi as u32);
                assert!((d2[off] - bd).abs() < 1e-4);
            }
        }
        assert_eq!(c.stats(), TransportStats::default(), "in-proc moves no bytes");
    }

    #[test]
    fn pair_cache_partitions_key_ranges_and_covers_all_pairs() {
        let (_, c) = cluster(TransportKind::InProc, 2, 3);
        let mut vectors = Matrix::zeros(0, 2);
        for i in 0..9 {
            vectors.push_row(&[i as f32, 0.0]);
        }
        let vectors = Arc::new(vectors);
        // 5 buckets over 3 peers: ranges [0..1), [1..3), [3..5).
        let shard_lists: Vec<Vec<u32>> =
            vec![vec![0, 1], vec![2], vec![3, 4, 5], vec![], vec![6, 7, 8]];
        let lists = c.pair_cache(vectors, shard_lists).unwrap();
        assert_eq!(lists.len(), 3, "one cache per validator peer");
        // Peer 0: bucket {0,1} → 1 pair. Peer 1: buckets {2}, {3,4,5} → 3
        // pairs. Peer 2: buckets {}, {6,7,8} → 3 pairs.
        assert_eq!(lists[0].len(), 1);
        assert_eq!(lists[1].len(), 3);
        assert_eq!(lists[2].len(), 3);
        let total: usize = lists.iter().map(|l| l.len()).sum();
        assert_eq!(total, 7);
        for l in &lists {
            assert!(l.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)));
        }
    }

    #[test]
    fn transport_stats_delta() {
        let a = TransportStats { wire_bytes: 100, ser_time: Duration::from_millis(5) };
        let b = TransportStats { wire_bytes: 250, ser_time: Duration::from_millis(8) };
        let d = b.since(&a);
        assert_eq!(d.wire_bytes, 150);
        assert_eq!(d.ser_time, Duration::from_millis(3));
    }
}
