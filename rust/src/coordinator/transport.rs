//! The cluster's communication plane, behind a [`Transport`] trait.
//!
//! The coordinator talks to two groups of peers — *compute workers* (epoch
//! jobs: nearest-center assignment, coordinate descent, reductions) and
//! *validator shards* (conflict pre-computation for the master's validation
//! step). Both groups are addressed through the same abstraction: scatter
//! one [`Job`] per peer on a [`Plane`], gather one reply per peer. How the
//! messages move is the transport's business:
//!
//! * [`InProc`] — peers are threads in this process; jobs and snapshots
//!   cross the boundary by pointer (`mpsc` channels + `Arc`). This is the
//!   zero-copy fast path and the default.
//! * [`super::tcp::Tcp`] — peers sit behind TCP sockets: loopback threads
//!   of this process by default, or standalone `occd worker` processes on
//!   other machines when a [`Topology`] lists `host:port` addresses. Every
//!   job, snapshot, reply — and the dataset itself, as demand-shipped block
//!   frames — is serialized through the explicit length-prefixed wire
//!   format of [`super::wire`]. Same coordinator, same bits.
//!
//! [`Cluster`] is the coordinator-facing facade: it owns the boxed
//! transport, knows the peer counts, and provides the scatter/gather calls
//! the schedulers and validators drive. Serializability does not depend on
//! the transport — all state mutation stays in the master, and
//! `rust/tests/transport_equivalence.rs` checks models are bit-identical
//! across `{inproc, tcp} × {bsp, pipelined}`.

use super::engine::{Job, JobOutput, WorkerPool};
use crate::config::TransportKind;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::runtime::ComputeBackend;
use std::sync::Arc;
use std::time::Duration;

/// Which peer group a scatter/gather addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    /// The epoch-compute workers (P peers).
    Compute,
    /// The validator shards (V peers).
    Validate,
}

impl Plane {
    /// Index into per-plane storage.
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Plane::Compute => 0,
            Plane::Validate => 1,
        }
    }
}

/// Cumulative wire-level accounting for a transport.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Bytes written to + read from the wire (frames, both directions).
    pub wire_bytes: u64,
    /// Bytes that passed through the encoder exactly once: `wire_bytes`
    /// minus every duplicated copy of an already-encoded payload (spliced
    /// shared job payloads, a snapshot frame written to P sockets). The gap
    /// between the two columns is the fan-out redundancy — what splicing
    /// and delta-shipping save the *encoder*, as opposed to the wire.
    pub unique_payload_bytes: u64,
    /// Master-side time spent encoding jobs and decoding replies.
    pub ser_time: Duration,
    /// Dataset-block payload bytes shipped to peers (a subset of
    /// `wire_bytes`; zero in-proc and on the validation plane, whose jobs
    /// carry their vectors inline).
    pub dataset_bytes: u64,
    /// Snapshot-delta payload bytes shipped (a subset of `wire_bytes`):
    /// the appended rows that replaced full per-epoch snapshot copies.
    pub delta_bytes: u64,
    /// Full-snapshot frames shipped because no delta was possible: a cold
    /// peer cache (first wave, reconnected replacement) or a committed
    /// state whose prefix was rewritten (mean recompute, BP re-estimate).
    pub full_snapshot_fallbacks: u64,
    /// Wall-clock spent in peer session handshakes — the initial `Hello`
    /// exchange per peer at spawn, plus any reconnect re-handshakes.
    pub handshake_time: Duration,
    /// Wall-clock the readiness-polled gather spent idle, waiting for the
    /// next reply to become readable (zero in-proc, whose gather blocks on
    /// a channel).
    pub gather_wait_time: Duration,
}

impl TransportStats {
    /// Stats accumulated since an earlier snapshot of the same transport.
    pub fn since(&self, earlier: &TransportStats) -> TransportStats {
        TransportStats {
            wire_bytes: self.wire_bytes.saturating_sub(earlier.wire_bytes),
            unique_payload_bytes: self
                .unique_payload_bytes
                .saturating_sub(earlier.unique_payload_bytes),
            ser_time: self.ser_time.saturating_sub(earlier.ser_time),
            dataset_bytes: self.dataset_bytes.saturating_sub(earlier.dataset_bytes),
            delta_bytes: self.delta_bytes.saturating_sub(earlier.delta_bytes),
            full_snapshot_fallbacks: self
                .full_snapshot_fallbacks
                .saturating_sub(earlier.full_snapshot_fallbacks),
            handshake_time: self.handshake_time.saturating_sub(earlier.handshake_time),
            gather_wait_time: self.gather_wait_time.saturating_sub(earlier.gather_wait_time),
        }
    }
}

/// Where a cluster's peers live: per plane, a list of `host:port`
/// addresses (standalone `occd worker` processes) or — when the list is
/// empty — a count of loopback peers to spawn in this process.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Compute peers when `compute_peers` is empty.
    pub procs: usize,
    /// Validator peers when `validator_peers` is empty.
    pub validators: usize,
    /// Remote compute-peer addresses; non-empty lists define the plane
    /// size.
    pub compute_peers: Vec<String>,
    /// Remote validator-peer addresses.
    pub validator_peers: Vec<String>,
    /// Bounded reconnect budget for a dropped remote peer (0 = fail fast).
    pub reconnect_attempts: usize,
    /// Wire-frugal shipping (the default): snapshots travel as versioned
    /// delta frames against each peer's session cache, and validator peers
    /// receive only the proposal rows their conflict-key range reads.
    /// `false` restores the PR 3 shape — full snapshot embedded in every
    /// job frame, full proposal matrix to every active validator — kept as
    /// the A/B baseline for `benches/schedulers.rs`.
    pub frugal_wire: bool,
}

/// Default reconnect budget for dropped remote peers.
pub const DEFAULT_RECONNECT_ATTEMPTS: usize = 3;

impl Default for Topology {
    fn default() -> Topology {
        Topology::local(0, 0)
    }
}

impl Topology {
    /// An all-loopback topology (every peer in this process).
    pub fn local(procs: usize, validators: usize) -> Topology {
        Topology {
            procs,
            validators,
            compute_peers: Vec::new(),
            validator_peers: Vec::new(),
            reconnect_attempts: DEFAULT_RECONNECT_ATTEMPTS,
            frugal_wire: true,
        }
    }

    /// The topology a run config names, with the validation-plane size the
    /// caller resolved (algorithms cap it — BP-means uses a single
    /// placeholder validator). Validator addresses beyond that cap are
    /// dropped with a stderr notice; the surplus workers simply never
    /// receive a session.
    pub fn of_config(cfg: &crate::config::RunConfig, validators: usize) -> Topology {
        let mut validator_peers = cfg.validator_peers.clone();
        if validator_peers.len() > validators {
            eprintln!(
                "occml: this algorithm uses {validators} validator peer(s); dropping {}: {}",
                validator_peers.len() - validators,
                validator_peers[validators..].join(", ")
            );
        }
        validator_peers.truncate(validators);
        Topology {
            procs: cfg.procs,
            validators,
            compute_peers: cfg.peers.clone(),
            validator_peers,
            reconnect_attempts: cfg.reconnect_attempts,
            frugal_wire: cfg.frugal_wire,
        }
    }

    /// Compute-plane size this topology resolves to.
    pub fn effective_procs(&self) -> usize {
        if self.compute_peers.is_empty() { self.procs } else { self.compute_peers.len() }
    }

    /// Validation-plane size this topology resolves to.
    pub fn effective_validators(&self) -> usize {
        if self.validator_peers.is_empty() {
            self.validators
        } else {
            self.validator_peers.len()
        }
    }

    /// True if any plane addresses remote peers.
    pub fn has_remote_peers(&self) -> bool {
        !self.compute_peers.is_empty() || !self.validator_peers.is_empty()
    }
}

/// A cluster transport: moves jobs to peers and replies back.
///
/// Contract (identical to [`WorkerPool`]'s): `scatter` takes exactly one
/// job per peer of the plane; at most one wave may be outstanding per
/// plane and `gather` retires it, returning outputs sorted by peer id
/// plus the critical-path busy time. On a peer-side *job* failure the
/// wave is still fully drained before `gather` returns the error, so the
/// transport stays usable. A *scatter* failure (dead peer, unencodable
/// job) instead poisons the plane — some peers own jobs whose replies
/// belong to no wave — and every later scatter on it reports the
/// poisoning rather than risking stale-reply misattribution.
pub trait Transport: Send {
    /// Transport name (metrics / logs).
    fn name(&self) -> &'static str;

    /// Number of peers on a plane.
    fn peers(&self, plane: Plane) -> usize;

    /// Send one job per peer of `plane` without waiting for results.
    fn scatter(&self, plane: Plane, jobs: Vec<Job>) -> Result<()>;

    /// Gather the plane's outstanding wave.
    fn gather(&self, plane: Plane) -> Result<(Vec<JobOutput>, Duration)>;

    /// Cumulative serialization accounting (all-zero for in-proc).
    fn stats(&self) -> TransportStats;
}

/// The in-process transport: each plane is a [`WorkerPool`] — today's
/// channels and `Arc`-shared snapshots, preserved as the zero-copy fast
/// path. No bytes are moved, so [`Transport::stats`] stays zero.
pub struct InProc {
    planes: [WorkerPool; 2],
}

impl InProc {
    /// Spawn `procs` compute workers and `validators` validator peers over
    /// a shared dataset and backend.
    pub fn spawn(
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        procs: usize,
        validators: usize,
    ) -> InProc {
        InProc {
            planes: [
                WorkerPool::spawn(data.clone(), backend.clone(), procs),
                WorkerPool::spawn(data, backend, validators),
            ],
        }
    }
}

impl Transport for InProc {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn peers(&self, plane: Plane) -> usize {
        self.planes[plane.idx()].procs
    }

    fn scatter(&self, plane: Plane, jobs: Vec<Job>) -> Result<()> {
        self.planes[plane.idx()].scatter(jobs)
    }

    fn gather(&self, plane: Plane) -> Result<(Vec<JobOutput>, Duration)> {
        self.planes[plane.idx()].gather()
    }

    fn stats(&self) -> TransportStats {
        TransportStats::default()
    }
}

/// The coordinator's handle to its peers: a boxed [`Transport`] plus the
/// plane sizes. Schedulers drive the compute plane through
/// [`Cluster::scatter`] / [`Cluster::gather`]; validators drive the
/// validation plane through [`Cluster::pair_cache`].
pub struct Cluster {
    transport: Box<dyn Transport>,
    /// Compute workers (the paper's P).
    pub procs: usize,
    /// Validator-shard peers.
    pub validators: usize,
    /// Row-subset shipping for `PairCache` jobs (see
    /// [`Topology::frugal_wire`]): each validator peer receives only the
    /// proposal rows its conflict-key range reads.
    frugal: bool,
}

impl Cluster {
    /// Spawn the transport a config names, with `procs` loopback compute
    /// peers and `validators` loopback validation peers.
    pub fn spawn(
        kind: TransportKind,
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        procs: usize,
        validators: usize,
    ) -> Result<Cluster> {
        Cluster::spawn_topology(kind, data, backend, &Topology::local(procs, validators))
    }

    /// Spawn the transport a config names over an explicit peer topology:
    /// remote `host:port` peers where the topology lists addresses,
    /// loopback peers elsewhere. Remote peers require the TCP transport.
    pub fn spawn_topology(
        kind: TransportKind,
        data: Arc<Dataset>,
        backend: Arc<dyn ComputeBackend>,
        topo: &Topology,
    ) -> Result<Cluster> {
        let procs = topo.effective_procs();
        let validators = topo.effective_validators().max(1);
        assert!(procs >= 1, "a cluster needs at least one compute peer");
        let transport: Box<dyn Transport> = match kind {
            TransportKind::InProc => {
                if topo.has_remote_peers() {
                    return Err(Error::config(
                        "peers = [...] requires transport = \"tcp\" — the in-proc \
                         transport has no wire to reach them over",
                    ));
                }
                Box::new(InProc::spawn(data, backend, procs, validators))
            }
            TransportKind::Tcp => {
                let mut topo = topo.clone();
                topo.validators = validators;
                Box::new(super::tcp::Tcp::spawn_topology(data, backend, &topo)?)
            }
        };
        // Row subsets are a *wire* diet: in-proc peers share the proposal
        // matrix by `Arc` at zero copy cost, so the subset build would be
        // pure overhead there — it engages only where bytes actually move.
        let frugal = topo.frugal_wire && kind == TransportKind::Tcp;
        Ok(Cluster { transport, procs, validators, frugal })
    }

    /// Wrap an existing transport (tests / custom deployments).
    /// `frugal_wire` must match how the transport was built (see
    /// [`Topology::frugal_wire`]) so the validator row-subset decision
    /// stays consistent with the snapshot-shipping mode.
    pub fn from_transport(transport: Box<dyn Transport>, frugal_wire: bool) -> Cluster {
        let procs = transport.peers(Plane::Compute);
        let validators = transport.peers(Plane::Validate);
        Cluster { transport, procs, validators, frugal: frugal_wire }
    }

    /// Transport name (metrics / logs).
    pub fn name(&self) -> &'static str {
        self.transport.name()
    }

    /// Scatter one job per compute worker without waiting for results. At
    /// most one compute wave may be outstanding.
    pub fn scatter(&self, jobs: Vec<Job>) -> Result<()> {
        self.transport.scatter(Plane::Compute, jobs)
    }

    /// Gather the outstanding compute wave: outputs sorted by peer id plus
    /// the critical-path busy time.
    pub fn gather(&self) -> Result<(Vec<JobOutput>, Duration)> {
        self.transport.gather(Plane::Compute)
    }

    /// Scatter one job per compute worker and gather all replies — the BSP
    /// barrier.
    pub fn scatter_gather(&self, jobs: Vec<Job>) -> Result<(Vec<JobOutput>, Duration)> {
        self.scatter(jobs)?;
        self.gather()
    }

    /// Cumulative transport accounting (zero for in-proc).
    pub fn stats(&self) -> TransportStats {
        self.transport.stats()
    }

    /// Compute per-shard conflict caches on the validation plane.
    ///
    /// `shard_lists` are conflict-key buckets in key order (see
    /// [`super::validator::shard_positions`]); each validator peer is
    /// handed a contiguous *range* of buckets — its conflict-key range —
    /// bundled with the proposal vectors as one
    /// [`Job::PairCache`] job. Returns one sorted pair list per peer, in
    /// peer order, ready for
    /// [`super::validator::ConflictCache::tree_reduce`]. Buckets with
    /// fewer than two proposals produce no pairs and are dropped from the
    /// payload, and peers left with nothing receive an empty job.
    ///
    /// Wire-cost note: under frugal shipping (the tcp default; in-proc
    /// peers share the full matrix by `Arc` at zero copy cost, so the
    /// subset build never engages there) each active
    /// peer receives only the proposal rows its conflict-key range reads,
    /// with a local→global position map, so the plane's TCP traffic for
    /// this step is `O(M · d)` *total* per epoch (every proposal belongs
    /// to exactly one bucket, every bucket to exactly one peer) instead of
    /// the PR 3 `O(V · M · d)`. The subset rows are bit-copies and the
    /// position map is strictly monotone, so peer outputs — global pair
    /// keys, sorted order, distance bits — are identical to the
    /// full-matrix form on any transport.
    pub fn pair_cache(
        &self,
        vectors: Arc<Matrix>,
        shard_lists: Vec<Vec<u32>>,
    ) -> Result<Vec<Vec<(u32, u32, f32)>>> {
        let v = self.validators;
        let s = shard_lists.len();
        let mut groups: Vec<Vec<Vec<u32>>> = Vec::with_capacity(v);
        let mut it = shard_lists.into_iter();
        for p in 0..v {
            let lo = p * s / v;
            let hi = (p + 1) * s / v;
            groups.push(
                it.by_ref().take(hi - lo).filter(|l| l.len() >= 2).collect(),
            );
        }
        let empty = Arc::new(Matrix::zeros(0, vectors.cols));
        let jobs: Vec<Job> = groups
            .into_iter()
            .map(|g| {
                if g.is_empty() {
                    Job::PairCache { vectors: empty.clone(), positions: vec![], shards: vec![] }
                } else if self.frugal {
                    // Row subset: the union of this peer's buckets, in
                    // global position order. Buckets partition positions,
                    // so the union is duplicate-free.
                    let mut positions: Vec<u32> = g.iter().flatten().copied().collect();
                    positions.sort_unstable();
                    debug_assert!(positions.windows(2).all(|w| w[0] < w[1]));
                    let mut sub = Matrix {
                        rows: 0,
                        cols: vectors.cols,
                        data: Vec::with_capacity(positions.len() * vectors.cols),
                    };
                    for &p in &positions {
                        sub.push_row(vectors.row(p as usize));
                    }
                    Job::PairCache { vectors: Arc::new(sub), positions, shards: g }
                } else {
                    Job::PairCache { vectors: vectors.clone(), positions: vec![], shards: g }
                }
            })
            .collect();
        self.transport.scatter(Plane::Validate, jobs)?;
        let (outs, _busy) = self.transport.gather(Plane::Validate)?;
        let mut lists = Vec::with_capacity(outs.len());
        for out in outs {
            let JobOutput::PairCache { pairs } = out else {
                return Err(Error::Coordinator(
                    "unexpected job output on the validation plane".into(),
                ));
            };
            lists.push(pairs);
        }
        Ok(lists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::{dp_clusters, GenConfig};
    use crate::runtime::native::NativeBackend;

    fn cluster(kind: TransportKind, procs: usize, validators: usize) -> (Arc<Dataset>, Cluster) {
        let data = Arc::new(dp_clusters(&GenConfig { n: 100, dim: 8, theta: 1.0, seed: 1 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let c = Cluster::spawn(kind, data.clone(), backend, procs, validators).unwrap();
        (data, c)
    }

    fn nearest_jobs(data: &Dataset, procs: usize) -> (Arc<Matrix>, Vec<Job>) {
        let mut centers = Matrix::zeros(0, 8);
        centers.push_row(data.point(0));
        centers.push_row(data.point(50));
        let centers = Arc::new(centers);
        let jobs = super::super::engine::split_range(0..100, procs)
            .into_iter()
            .map(|range| Job::Nearest { range, centers: centers.clone() })
            .collect();
        (centers, jobs)
    }

    #[test]
    fn inproc_cluster_matches_direct_nearest_and_reports_zero_wire() {
        let (data, c) = cluster(TransportKind::InProc, 3, 2);
        assert_eq!(c.name(), "inproc");
        assert_eq!(c.procs, 3);
        assert_eq!(c.validators, 2);
        let (centers, jobs) = nearest_jobs(&data, 3);
        let (outs, busy) = c.scatter_gather(jobs).unwrap();
        assert!(busy > Duration::ZERO);
        let ranges = super::super::engine::split_range(0..100, 3);
        for (w, out) in outs.iter().enumerate() {
            let JobOutput::Nearest { idx, d2 } = out else { panic!("wrong kind") };
            for (off, i) in ranges[w].clone().enumerate() {
                let (bi, bd) = crate::linalg::nearest(data.point(i), &centers);
                assert_eq!(idx[off], bi as u32);
                assert!((d2[off] - bd).abs() < 1e-4);
            }
        }
        assert_eq!(c.stats(), TransportStats::default(), "in-proc moves no bytes");
    }

    #[test]
    fn pair_cache_partitions_key_ranges_and_covers_all_pairs() {
        let (_, c) = cluster(TransportKind::InProc, 2, 3);
        let mut vectors = Matrix::zeros(0, 2);
        for i in 0..9 {
            vectors.push_row(&[i as f32, 0.0]);
        }
        let vectors = Arc::new(vectors);
        // 5 buckets over 3 peers: ranges [0..1), [1..3), [3..5).
        let shard_lists: Vec<Vec<u32>> =
            vec![vec![0, 1], vec![2], vec![3, 4, 5], vec![], vec![6, 7, 8]];
        let lists = c.pair_cache(vectors, shard_lists).unwrap();
        assert_eq!(lists.len(), 3, "one cache per validator peer");
        // Peer 0: bucket {0,1} → 1 pair. Peer 1: buckets {2}, {3,4,5} → 3
        // pairs. Peer 2: buckets {}, {6,7,8} → 3 pairs.
        assert_eq!(lists[0].len(), 1);
        assert_eq!(lists[1].len(), 3);
        assert_eq!(lists[2].len(), 3);
        let total: usize = lists.iter().map(|l| l.len()).sum();
        assert_eq!(total, 7);
        for l in &lists {
            assert!(l.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)));
        }
    }

    /// Row-subset shipping must not change a single bit of the pair lists:
    /// frugal and full-matrix shipping agree on both transports.
    #[test]
    fn pair_cache_row_subset_matches_full_shipping() {
        let data = Arc::new(dp_clusters(&GenConfig { n: 40, dim: 8, theta: 1.0, seed: 2 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let mut vectors = Matrix::zeros(0, 3);
        for i in 0..12 {
            vectors.push_row(&[i as f32, (i * i) as f32 * 0.5, -(i as f32)]);
        }
        let vectors = Arc::new(vectors);
        let shard_lists: Vec<Vec<u32>> =
            vec![vec![0, 4, 8], vec![1, 5], vec![2, 6, 10, 11], vec![3], vec![7, 9]];
        let mut results = Vec::new();
        for kind in [TransportKind::InProc, TransportKind::Tcp] {
            for frugal in [true, false] {
                let topo = Topology { frugal_wire: frugal, ..Topology::local(2, 2) };
                let c =
                    Cluster::spawn_topology(kind, data.clone(), backend.clone(), &topo).unwrap();
                results.push(c.pair_cache(vectors.clone(), shard_lists.clone()).unwrap());
            }
        }
        for other in &results[1..] {
            assert_eq!(results[0].len(), other.len());
            for (a, b) in results[0].iter().zip(other) {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!((x.0, x.1), (y.0, y.1));
                    assert_eq!(x.2.to_bits(), y.2.to_bits());
                }
            }
        }
    }

    #[test]
    fn transport_stats_delta() {
        let a = TransportStats {
            wire_bytes: 100,
            unique_payload_bytes: 80,
            ser_time: Duration::from_millis(5),
            dataset_bytes: 10,
            delta_bytes: 4,
            full_snapshot_fallbacks: 1,
            handshake_time: Duration::from_millis(1),
            gather_wait_time: Duration::from_millis(2),
        };
        let b = TransportStats {
            wire_bytes: 250,
            unique_payload_bytes: 170,
            ser_time: Duration::from_millis(8),
            dataset_bytes: 70,
            delta_bytes: 24,
            full_snapshot_fallbacks: 3,
            handshake_time: Duration::from_millis(4),
            gather_wait_time: Duration::from_millis(9),
        };
        let d = b.since(&a);
        assert_eq!(d.wire_bytes, 150);
        assert_eq!(d.unique_payload_bytes, 90);
        assert_eq!(d.ser_time, Duration::from_millis(3));
        assert_eq!(d.dataset_bytes, 60);
        assert_eq!(d.delta_bytes, 20);
        assert_eq!(d.full_snapshot_fallbacks, 2);
        assert_eq!(d.handshake_time, Duration::from_millis(3));
        assert_eq!(d.gather_wait_time, Duration::from_millis(7));
    }

    #[test]
    fn topology_resolution() {
        let t = Topology::local(4, 2);
        assert_eq!(t.effective_procs(), 4);
        assert_eq!(t.effective_validators(), 2);
        assert!(!t.has_remote_peers());
        let t = Topology {
            procs: 4,
            validators: 2,
            compute_peers: vec!["h:1".into(), "h:2".into(), "h:3".into()],
            validator_peers: vec!["h:4".into()],
            reconnect_attempts: 1,
            frugal_wire: true,
        };
        assert_eq!(t.effective_procs(), 3, "addresses define the plane size");
        assert_eq!(t.effective_validators(), 1);
        assert!(t.has_remote_peers());
    }

    #[test]
    fn inproc_rejects_remote_peers() {
        let data = Arc::new(dp_clusters(&GenConfig { n: 10, dim: 4, theta: 1.0, seed: 1 }));
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new());
        let topo = Topology {
            procs: 1,
            validators: 1,
            compute_peers: vec!["127.0.0.1:1".into()],
            validator_peers: vec![],
            reconnect_attempts: 0,
            frugal_wire: true,
        };
        let err = Cluster::spawn_topology(TransportKind::InProc, data, backend, &topo)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tcp"), "{err}");
    }
}
