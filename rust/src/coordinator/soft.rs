//! Soft (relaxed) conflict detection — the §6 "control knob".
//!
//! The paper's discussion proposes treating the conflict-detection
//! mechanism as a knob that "softly switch[es] between stable,
//! theoretically sound algorithms and potentially faster coordination-free
//! algorithms". This module implements that extension for DP-means:
//! validation accepts a proposal that lands within `(1 − slack)·λ … λ` of an
//! already-accepted center with probability `slack_accept` — deliberately
//! admitting *bounded* non-serializable acceptances in exchange for less
//! correcting computation.
//!
//! * `slack = 0` → exact `DPValidate` (Alg 2): fully serializable.
//! * `slack = 1, slack_accept = 1` → accept everything the workers propose:
//!   exactly the coordination-free merge.
//!
//! The invariant that survives relaxation (tested below): every accepted
//! pair of centers is separated by at least `(1 − slack)·λ`, so the
//! objective degradation is bounded by the λ-penalty of the extra centers —
//! the "laws of large numbers" style argument §6 anticipates.

use super::validator::{DpOutcome, DpProposal};
use crate::linalg::{sqdist, Matrix};
use crate::rng::Pcg64;

/// Knob configuration for soft validation.
#[derive(Debug, Clone, Copy)]
pub struct SoftKnob {
    /// Fraction of λ the separation requirement is relaxed by, in [0, 1].
    pub slack: f64,
    /// Probability of accepting a proposal inside the relaxed band.
    pub slack_accept: f64,
}

impl SoftKnob {
    /// The exact-OCC setting (no relaxation).
    pub fn exact() -> Self {
        SoftKnob { slack: 0.0, slack_accept: 0.0 }
    }
    /// The coordination-free extreme (accept everything).
    pub fn coordination_free() -> Self {
        SoftKnob { slack: 1.0, slack_accept: 1.0 }
    }
}

/// `DPValidate` with the §6 soft knob. With [`SoftKnob::exact`] this is
/// byte-for-byte the behaviour of [`super::validator::dp_validate`].
pub fn dp_validate_soft(
    centers: &mut Matrix,
    base: usize,
    proposals: &[DpProposal],
    lambda: f64,
    knob: SoftKnob,
    rng: &mut Pcg64,
) -> DpOutcome {
    let lambda2 = (lambda * lambda) as f32;
    let hard2 = ((1.0 - knob.slack) * lambda).powi(2) as f32;
    let mut out = DpOutcome::default();
    for p in proposals {
        let mut best = f32::INFINITY;
        let mut best_k = usize::MAX;
        for k in base..centers.rows {
            let d = sqdist(&p.center, centers.row(k));
            if d < best {
                best = d;
                best_k = k;
            }
        }
        let accept = if best >= lambda2 {
            true // no conflict at all
        } else if best >= hard2 {
            // Inside the relaxed band: probabilistically admit the
            // non-serializable acceptance.
            knob.slack_accept > 0.0 && rng.next_f64() < knob.slack_accept
        } else {
            false // hard conflict: always correct
        };
        if accept {
            centers.push_row(&p.center);
            out.resolved.push((p.idx, (centers.rows - 1) as u32));
            out.accepted += 1;
        } else {
            out.resolved.push((p.idx, best_k as u32));
            out.rejected += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::validator::dp_validate;

    fn proposals(points: &[(f32, f32)]) -> Vec<DpProposal> {
        points
            .iter()
            .enumerate()
            .map(|(i, &(x, y))| DpProposal { idx: i as u32, center: vec![x, y] })
            .collect()
    }

    #[test]
    fn zero_slack_equals_exact_validation() {
        let props = proposals(&[(0.0, 0.0), (0.5, 0.0), (2.0, 0.0), (2.3, 0.0), (9.0, 0.0)]);
        let mut rng = Pcg64::new(1);
        let mut soft_c = Matrix::zeros(0, 2);
        let soft = dp_validate_soft(&mut soft_c, 0, &props, 1.0, SoftKnob::exact(), &mut rng);
        let mut hard_c = Matrix::zeros(0, 2);
        let hard = dp_validate(&mut hard_c, 0, &props, 1.0);
        assert_eq!(soft.resolved, hard.resolved);
        assert_eq!(soft_c.data, hard_c.data);
    }

    #[test]
    fn coordination_free_extreme_accepts_everything() {
        let props = proposals(&[(0.0, 0.0), (0.1, 0.0), (0.2, 0.0)]);
        let mut rng = Pcg64::new(2);
        let mut c = Matrix::zeros(0, 2);
        let out =
            dp_validate_soft(&mut c, 0, &props, 1.0, SoftKnob::coordination_free(), &mut rng);
        assert_eq!(out.accepted, 3);
        assert_eq!(out.rejected, 0);
    }

    #[test]
    fn relaxed_band_respects_hard_floor() {
        // slack = 0.5: conflicts closer than 0.5·λ are ALWAYS corrected,
        // conflicts in [0.5λ, λ) are admitted with probability 1 here.
        let knob = SoftKnob { slack: 0.5, slack_accept: 1.0 };
        let props = proposals(&[
            (0.0, 0.0),
            (0.7, 0.0),  // d = 0.7 ∈ [0.5, 1) → admitted
            (0.1, 0.0),  // d = 0.1 < 0.5 → corrected
        ]);
        let mut rng = Pcg64::new(3);
        let mut c = Matrix::zeros(0, 2);
        let out = dp_validate_soft(&mut c, 0, &props, 1.0, knob, &mut rng);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.rejected, 1);
        // Separation invariant: all accepted pairs ≥ (1−slack)·λ apart.
        for a in 0..c.rows {
            for b in 0..a {
                assert!(sqdist(c.row(a), c.row(b)) >= 0.25 - 1e-6);
            }
        }
    }

    #[test]
    fn intermediate_slack_accept_is_probabilistic() {
        let knob = SoftKnob { slack: 1.0, slack_accept: 0.5 };
        let mut admitted = 0;
        let trials = 2000;
        let mut rng = Pcg64::new(4);
        for _ in 0..trials {
            let props = proposals(&[(0.0, 0.0), (0.5, 0.0)]);
            let mut c = Matrix::zeros(0, 2);
            let out = dp_validate_soft(&mut c, 0, &props, 1.0, knob, &mut rng);
            admitted += out.accepted - 1; // first always accepted
        }
        let rate = admitted as f64 / trials as f64;
        assert!((rate - 0.5).abs() < 0.05, "rate={rate}");
    }
}
