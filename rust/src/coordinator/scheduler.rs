//! Epoch scheduling policies: when workers compute and when the master
//! validates.
//!
//! The driver owns *what* an epoch does (jobs, merge, validation — the
//! [`EpochAlgo`] hooks); a [`Scheduler`] owns *when* those steps run
//! relative to each other. Two policies are provided:
//!
//! * [`Bsp`] — the paper's bulk-synchronous structure (Fig 5): scatter
//!   epoch `t`, barrier, validate epoch `t`, repeat. The master idles while
//!   workers compute and the workers idle while the master validates.
//! * [`Pipelined`] — software pipelining of the epoch loop: while the
//!   master validates epoch `t`, the workers already compute epoch `t+1`
//!   against the *stale* snapshot `C^{t-1}`. The pipeline is bounded at two
//!   epochs in flight (one at the workers, one at the master); the bound
//!   falls out of [`Cluster::gather`] being the only way to retire a
//!   wave, which is the backpressure point.
//!
//! Schedulers are transport-agnostic: they drive a [`Cluster`] (in-proc
//! threads or TCP peers — see [`super::transport`]) and never see how jobs
//! and replies actually move.
//!
//! ## Why pipelining preserves Theorem 3.1
//!
//! Thm 3.1 says the distributed execution equals a serial one because all
//! state mutation happens at the master, in point-index order. The
//! pipelined scheduler does not move any mutation: validation still runs
//! serially per epoch, in epoch order, in point-index order within the
//! epoch. What changes is only that epoch `t+1`'s *optimistic transactions*
//! execute against `C^{t-1}` instead of `C^{t}`. Before epoch `t+1` is
//! validated, the scheduler restores the exact BSP-visible state:
//!
//! * **Patchable algorithms** (DP-means, OFL — per-point nearest-center
//!   queries): the master computes each point's nearest center among the
//!   *delta* rows `C^{t} \ C^{t-1}` and folds it into the stale result with
//!   a strict `<` comparison. Per-(point, center) distances in the blocked
//!   kernel depend only on the pair — not on which other centers share the
//!   call — and the fold mirrors the kernel's first-minimum tie-break
//!   (delta rows have strictly higher indices and win only on strictly
//!   smaller distance), so the patched `(idx, d²)` equals a fresh scan of
//!   `C^{t}` *bit for bit*. Validation then sees byte-identical inputs in
//!   the identical order, and Thm 3.1's serial equivalence carries over
//!   unchanged. (The patch itself runs on the master, overlapped with the
//!   next wave's compute.)
//! * **Unpatchable algorithms** (BP-means — coordinate descent is a joint
//!   optimization over the feature set, not a per-row reduction): the
//!   speculative result is only used when the previous epoch committed
//!   nothing (the delta is empty, so the "stale" snapshot *is* `C^{t}`).
//!   Otherwise the scheduler redoes the epoch against the committed
//!   snapshot — a pipeline bubble, counted in
//!   [`EpochRecord::respins`] — which is literally the BSP computation.
//!   Acceptances decay geometrically over a run (Thm 3.2 / Fig 3), so late
//!   epochs overlap at full efficiency.
//!
//! In both cases the inputs reaching each validation call, and the order of
//! validation calls, are exactly those of the BSP schedule — so the models
//! produced are bit-identical (`rust/tests/scheduler_equivalence.rs`
//! enforces this across algorithms, worker counts and block sizes).
//!
//! Within an epoch, validation itself is sharded by conflict key
//! ([`super::validator::dp_validate_sharded`]): same-key proposal pairs get
//! their conflict distances precomputed in parallel, and a final serial
//! merge in point-index order replays the exact Thm 3.1 serial decision
//! sequence from cached (bit-identical) distances.

use super::engine::{split_range, Job, JobOutput};
use super::transport::Cluster;
use crate::error::Result;
use crate::linalg::Matrix;
use crate::metrics::{EpochRecord, MetricsSink, Stopwatch};
use std::ops::Range;
use std::sync::Arc;
use std::time::Duration;

/// What one epoch's validation reported back to the scheduler.
#[derive(Debug, Clone, Copy, Default)]
pub struct EpochCounts {
    /// Proposals the merge extracted from worker outputs.
    pub proposed: usize,
    /// Proposals accepted as new centers/features.
    pub accepted: usize,
    /// Proposals rejected (corrected to existing state).
    pub rejected: usize,
    /// Global state rows after this epoch committed.
    pub state_rows: usize,
}

/// Algorithm-specific hooks one pass's epochs are driven through.
///
/// Implementations own the committed global state (centers/features and
/// assignments) and all merge/validation logic; schedulers only decide when
/// each hook runs and against which snapshot.
pub trait EpochAlgo {
    /// Clone of the committed global state, to ship to workers.
    fn snapshot(&self) -> Arc<Matrix>;

    /// Rows of the committed global state (cheap; used to detect staleness).
    fn committed_rows(&self) -> usize;

    /// One worker job per range, against snapshot `snap`.
    fn make_jobs(&self, snap: &Arc<Matrix>, ranges: &[Range<usize>]) -> Vec<Job>;

    /// Whether outputs computed against a stale snapshot can be patched at
    /// the master into exactly what a fresh compute would return (DP/OFL
    /// nearest-center queries: yes; BP coordinate descent: no).
    fn can_patch(&self) -> bool;

    /// Patch `outs` (computed against the first `stale_rows` committed
    /// rows) to equal, bit for bit, a compute against the full committed
    /// state. Only called when `can_patch()` and the state actually grew.
    fn patch(
        &mut self,
        outs: &mut [JobOutput],
        ranges: &[Range<usize>],
        stale_rows: usize,
    ) -> Result<()>;

    /// Merge worker outputs and validate the epoch's proposals in
    /// point-index order, mutating the committed state.
    fn validate(&mut self, outs: &[JobOutput], ranges: &[Range<usize>]) -> Result<EpochCounts>;
}

/// An epoch scheduling policy.
pub trait Scheduler {
    /// Policy name (metrics / logs).
    fn name(&self) -> &'static str;

    /// Drive one pass's epochs (contiguous point ranges, in order) through
    /// `algo` on `cluster`, emitting one [`EpochRecord`] per epoch.
    /// Transport accounting (`wire_bytes`, `ser_ms`) is recorded as
    /// per-epoch deltas of [`Cluster::stats`]; under the pipelined policy
    /// the speculative scatter of epoch `t+1` is attributed to the epoch
    /// whose validation it overlaps.
    fn run_pass(
        &self,
        cluster: &Cluster,
        algo: &mut dyn EpochAlgo,
        epochs: &[Range<usize>],
        pass: usize,
        sink: &mut MetricsSink,
        log: &mut Vec<EpochRecord>,
    ) -> Result<()>;
}

/// Build the scheduler a config names.
pub fn make(kind: crate::config::SchedulerKind) -> Box<dyn Scheduler> {
    match kind {
        crate::config::SchedulerKind::Bsp => Box::new(Bsp),
        crate::config::SchedulerKind::Pipelined => Box::new(Pipelined),
    }
}

/// Scatter one epoch against the current committed snapshot; returns the
/// per-worker ranges and the snapshot's row count (for staleness checks).
fn scatter_epoch(
    cluster: &Cluster,
    algo: &dyn EpochAlgo,
    epoch: &Range<usize>,
) -> Result<(Vec<Range<usize>>, usize)> {
    let snap = algo.snapshot();
    let ranges = split_range(epoch.clone(), cluster.procs);
    cluster.scatter(algo.make_jobs(&snap, &ranges))?;
    Ok((ranges, snap.rows))
}

/// The bulk-synchronous schedule (the seed's behavior, extracted).
pub struct Bsp;

impl Scheduler for Bsp {
    fn name(&self) -> &'static str {
        "bsp"
    }

    fn run_pass(
        &self,
        cluster: &Cluster,
        algo: &mut dyn EpochAlgo,
        epochs: &[Range<usize>],
        pass: usize,
        sink: &mut MetricsSink,
        log: &mut Vec<EpochRecord>,
    ) -> Result<()> {
        for (t, epoch) in epochs.iter().enumerate() {
            let net0 = cluster.stats();
            let epoch_sw = Stopwatch::start();
            let (ranges, _) = scatter_epoch(cluster, &*algo, epoch)?;
            let (outs, worker_time) = cluster.gather()?;
            let master_sw = Stopwatch::start();
            let counts = algo.validate(&outs, &ranges)?;
            let master_time = master_sw.elapsed();
            let net = cluster.stats().since(&net0);
            let rec = EpochRecord {
                iteration: pass,
                epoch: t,
                points: epoch.len(),
                proposed: counts.proposed,
                accepted: counts.accepted,
                rejected: counts.rejected,
                centers: counts.state_rows,
                worker_time,
                master_time,
                total_time: epoch_sw.elapsed(),
                overlap_time: Duration::ZERO,
                queue_depth: 1,
                respins: 0,
                wire_bytes: net.wire_bytes,
                unique_payload_bytes: net.unique_payload_bytes,
                delta_bytes: net.delta_bytes,
                full_snapshot_fallbacks: net.full_snapshot_fallbacks,
                ser_time: net.ser_time,
                gather_wait_time: net.gather_wait_time,
                dataset_bytes: net.dataset_bytes,
                handshake_time: net.handshake_time,
            };
            sink.emit(&rec);
            log.push(rec);
        }
        Ok(())
    }
}

/// The pipelined schedule: overlap epoch `t`'s validation with epoch
/// `t+1`'s compute. See the module docs for the equivalence argument.
pub struct Pipelined;

impl Scheduler for Pipelined {
    fn name(&self) -> &'static str {
        "pipelined"
    }

    fn run_pass(
        &self,
        cluster: &Cluster,
        algo: &mut dyn EpochAlgo,
        epochs: &[Range<usize>],
        pass: usize,
        sink: &mut MetricsSink,
        log: &mut Vec<EpochRecord>,
    ) -> Result<()> {
        if epochs.is_empty() {
            return Ok(());
        }
        let mut net0 = cluster.stats();
        let mut inflight = Some(scatter_epoch(cluster, &*algo, &epochs[0])?);
        for (t, epoch) in epochs.iter().enumerate() {
            let epoch_sw = Stopwatch::start();
            let (ranges, stale_rows) = inflight.take().expect("pipeline wave missing");
            let (mut outs, mut worker_time) = cluster.gather()?;
            let stale = stale_rows < algo.committed_rows();
            let mut respins = 0;
            // Single-wave compute time, for the overlap estimate below
            // (worker_time itself accumulates the redo wave on a respin).
            let mut wave_time = worker_time;
            if stale && !algo.can_patch() {
                // Speculation conflict on an unpatchable algorithm: redo
                // the epoch against the committed snapshot (the BSP
                // computation) before anything else enters the queue.
                respins = 1;
                let snap = algo.snapshot();
                cluster.scatter(algo.make_jobs(&snap, &ranges))?;
                let (fresh, wt) = cluster.gather()?;
                outs = fresh;
                worker_time += wt;
                wave_time = wt;
            }
            // Speculative scatter of epoch t+1 against the still-uncommitted
            // state — this is what overlaps the master work below.
            let speculating = t + 1 < epochs.len();
            if speculating {
                inflight = Some(scatter_epoch(cluster, &*algo, &epochs[t + 1])?);
            }
            let master_sw = Stopwatch::start();
            if stale && algo.can_patch() {
                algo.patch(&mut outs, &ranges, stale_rows)?;
            }
            let counts = algo.validate(&outs, &ranges)?;
            let master_time = master_sw.elapsed();
            // Wire accounting between consecutive record points: includes
            // this epoch's gather, its redo wave if any, the speculative
            // scatter of epoch t+1, and any validation-plane traffic.
            let net_now = cluster.stats();
            let net = net_now.since(&net0);
            net0 = net_now;
            let rec = EpochRecord {
                iteration: pass,
                epoch: t,
                points: epoch.len(),
                proposed: counts.proposed,
                accepted: counts.accepted,
                rejected: counts.rejected,
                centers: counts.state_rows,
                worker_time,
                master_time,
                total_time: epoch_sw.elapsed(),
                // Master work hidden behind the in-flight wave. The next
                // wave's completion time isn't known yet, so estimate
                // conservatively with this epoch's single-wave critical-path
                // compute time (waves are homogeneous in size): validation
                // beyond that likely ran against an already-drained pool.
                overlap_time: if speculating {
                    master_time.min(wave_time)
                } else {
                    Duration::ZERO
                },
                queue_depth: 1 + usize::from(speculating),
                respins,
                wire_bytes: net.wire_bytes,
                unique_payload_bytes: net.unique_payload_bytes,
                delta_bytes: net.delta_bytes,
                full_snapshot_fallbacks: net.full_snapshot_fallbacks,
                ser_time: net.ser_time,
                gather_wait_time: net.gather_wait_time,
                dataset_bytes: net.dataset_bytes,
                handshake_time: net.handshake_time,
            };
            sink.emit(&rec);
            log.push(rec);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic EpochAlgo that records the exact call sequence and
    /// snapshot rows it was driven with, growing its "state" by one row per
    /// validated epoch so staleness is exercised.
    struct Scripted {
        state: Matrix,
        calls: Vec<String>,
        patchable: bool,
        grow_on_validate: bool,
    }

    impl Scripted {
        fn new(patchable: bool, grow_on_validate: bool) -> Scripted {
            Scripted {
                state: Matrix::zeros(0, 2),
                calls: Vec::new(),
                patchable,
                grow_on_validate,
            }
        }
    }

    impl EpochAlgo for Scripted {
        fn snapshot(&self) -> Arc<Matrix> {
            Arc::new(self.state.clone())
        }
        fn committed_rows(&self) -> usize {
            self.state.rows
        }
        fn make_jobs(&self, snap: &Arc<Matrix>, ranges: &[Range<usize>]) -> Vec<Job> {
            ranges
                .iter()
                .map(|r| Job::Nearest { range: r.clone(), centers: snap.clone() })
                .collect()
        }
        fn can_patch(&self) -> bool {
            self.patchable
        }
        fn patch(
            &mut self,
            _outs: &mut [JobOutput],
            _ranges: &[Range<usize>],
            stale_rows: usize,
        ) -> Result<()> {
            self.calls.push(format!("patch({stale_rows}->{})", self.state.rows));
            Ok(())
        }
        fn validate(
            &mut self,
            _outs: &[JobOutput],
            _ranges: &[Range<usize>],
        ) -> Result<EpochCounts> {
            self.calls.push(format!("validate(rows={})", self.state.rows));
            if self.grow_on_validate {
                self.state.push_row(&[self.state.rows as f32, 0.0]);
            }
            Ok(EpochCounts {
                proposed: 1,
                accepted: usize::from(self.grow_on_validate),
                rejected: usize::from(!self.grow_on_validate),
                state_rows: self.state.rows,
            })
        }
    }

    fn cluster2() -> Cluster {
        let data = Arc::new(crate::data::generators::dp_clusters(
            &crate::data::generators::GenConfig { n: 64, dim: 2, theta: 1.0, seed: 1 },
        ));
        let backend: Arc<dyn crate::runtime::ComputeBackend> =
            Arc::new(crate::runtime::native::NativeBackend::new());
        Cluster::spawn(crate::config::TransportKind::InProc, data, backend, 2, 1).unwrap()
    }

    fn drive(sched: &dyn Scheduler, algo: &mut Scripted) -> Vec<EpochRecord> {
        let cluster = cluster2();
        let epochs = vec![0..16, 16..32, 32..48, 48..64];
        let mut sink = MetricsSink::Null;
        let mut log = Vec::new();
        sched.run_pass(&cluster, algo, &epochs, 0, &mut sink, &mut log).unwrap();
        log
    }

    #[test]
    fn bsp_validates_every_epoch_without_overlap() {
        let mut algo = Scripted::new(true, true);
        let log = drive(&Bsp, &mut algo);
        assert_eq!(log.len(), 4);
        assert!(log.iter().all(|r| r.overlap_time == Duration::ZERO && r.queue_depth == 1));
        // BSP never sees a stale snapshot, so never patches.
        assert!(algo.calls.iter().all(|c| c.starts_with("validate")));
    }

    #[test]
    fn pipelined_patches_stale_epochs_and_reports_overlap() {
        let mut algo = Scripted::new(true, true);
        let log = drive(&Pipelined, &mut algo);
        assert_eq!(log.len(), 4);
        // Epoch 0 ran against the fresh initial state; epochs 1..3 were
        // computed one commit behind and must have been patched.
        let patches = algo.calls.iter().filter(|c| c.starts_with("patch")).count();
        assert_eq!(patches, 3, "calls: {:?}", algo.calls);
        // Patch always precedes the epoch's validate.
        assert!(algo.calls[0].starts_with("validate"));
        assert!(algo.calls[1].starts_with("patch"));
        // All but the last epoch validated with the next wave in flight.
        assert!(log[..3].iter().all(|r| r.queue_depth == 2));
        assert_eq!(log[3].queue_depth, 1);
        assert!(log.iter().all(|r| r.respins == 0));
    }

    #[test]
    fn pipelined_respins_unpatchable_epochs_on_conflict() {
        let mut algo = Scripted::new(false, true);
        let log = drive(&Pipelined, &mut algo);
        // Every epoch after the first hits a grown state and must respin.
        assert_eq!(log.iter().map(|r| r.respins).sum::<usize>(), 3);
        assert!(algo.calls.iter().all(|c| c.starts_with("validate")), "{:?}", algo.calls);
    }

    #[test]
    fn pipelined_speculation_hits_when_state_is_quiet() {
        // No acceptances ⇒ snapshots never go stale ⇒ no patches, no
        // respins, full overlap.
        let mut algo = Scripted::new(false, false);
        let log = drive(&Pipelined, &mut algo);
        assert_eq!(log.iter().map(|r| r.respins).sum::<usize>(), 0);
        assert!(algo.calls.iter().all(|c| c.starts_with("validate")));
        assert!(log[..3].iter().all(|r| r.queue_depth == 2));
    }

    #[test]
    fn empty_pass_is_a_noop() {
        let cluster = cluster2();
        let mut algo = Scripted::new(true, true);
        let mut sink = MetricsSink::Null;
        let mut log = Vec::new();
        Pipelined.run_pass(&cluster, &mut algo, &[], 0, &mut sink, &mut log).unwrap();
        assert!(log.is_empty());
    }

    #[test]
    fn inproc_epochs_record_zero_wire_traffic() {
        let mut algo = Scripted::new(true, true);
        let log = drive(&Bsp, &mut algo);
        assert!(log.iter().all(|r| r.wire_bytes == 0 && r.ser_time == Duration::ZERO));
    }

    #[test]
    fn factory_maps_config_kinds() {
        assert_eq!(make(crate::config::SchedulerKind::Bsp).name(), "bsp");
        assert_eq!(make(crate::config::SchedulerKind::Pipelined).name(), "pipelined");
    }
}
